"""Serving engine: continuous batching + greedy consistency."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.model import build_model
from repro.serve.engine import ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def test_single_request(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    eng.submit(np.asarray([1, 5, 9], np.int32), max_new_tokens=4)
    done = eng.run()
    assert len(done) == 1
    assert len(done[0].output) == 4
    assert all(0 <= t < cfg.vocab for t in done[0].output)


def test_continuous_batching_mixed_lengths(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    for i in range(5):   # more requests than slots -> queueing
        eng.submit(rng.integers(0, cfg.vocab, size=3 + i), max_new_tokens=3)
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in done)


def test_greedy_matches_direct_decode(small_model):
    cfg, model, params = small_model
    import jax.numpy as jnp
    prompt = np.asarray([2, 7, 11], np.int32)
    eng = ServeEngine(model, params, max_batch=1, max_len=32)
    eng.submit(prompt, max_new_tokens=4)
    out_engine = eng.run()[0].output

    # direct greedy loop
    cache = model.init_cache(1, 32, dtype=jnp.float32)
    toks = list(prompt)
    for t in range(len(prompt) - 1):
        _, cache = model.decode_step(params, jnp.asarray([[toks[t]]]),
                                     cache, jnp.asarray([[t]]))
    out = []
    pos = len(prompt) - 1
    cur = toks[-1]
    for _ in range(4):
        lg, cache = model.decode_step(params, jnp.asarray([[cur]]), cache,
                                      jnp.asarray([[pos]]))
        cur = int(jnp.argmax(lg[0, 0]))
        out.append(cur)
        pos += 1
    assert out == out_engine


def test_empty_prompt_rejected(small_model):
    """An empty prompt used to IndexError in _decode_step (prompt[-1]) and
    poison slot_pos with -1; it must be rejected at submit."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.asarray([], np.int32))
    # the engine stays healthy for real traffic afterwards
    eng.submit(np.asarray([3, 1], np.int32), max_new_tokens=2)
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 2


def test_handcrafted_empty_request_drained_not_crashing(small_model):
    """A Request built around submit() must not crash the whole batch."""
    from repro.serve.engine import Request
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    eng.queue.append(Request(0, np.asarray([], np.int32), 4))
    eng.submit(np.asarray([5], np.int32), max_new_tokens=2)
    done = eng.run()
    assert len(done) == 2
    empty = next(r for r in done if r.prompt.size == 0)
    assert empty.done and empty.output == []
    real = next(r for r in done if r.prompt.size == 1)
    assert len(real.output) == 2


def test_run_returns_submission_order(small_model):
    """run() contract: results come back in submission order (ascending
    rid), even when short requests complete before long earlier ones —
    trace replay and batched clients zip prompts with results."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    # rid 0 wants 8 tokens, rids 1..3 want 2: completion order differs
    eng.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=8)
    for i in range(3):
        eng.submit(np.asarray([4 + i], np.int32), max_new_tokens=2)
    done = eng.run()
    assert [r.rid for r in done] == [0, 1, 2, 3]
    # completion order is preserved separately, and genuinely differs here
    assert [r.rid for r in eng.completed] != [0, 1, 2, 3]
    assert len(done[0].output) == 8


def test_step_timer_injectable_and_listeners_fire(small_model):
    """StepTimer protocol: a fake clock makes step durations exact."""
    from repro.serve.engine import StepRecord

    cfg, model, params = small_model

    class FakeClock:
        t = 0.0

        def __call__(self):
            self.t += 0.5e-3          # every timer read advances 0.5ms
            return self.t

    eng = ServeEngine(model, params, max_batch=2, max_len=64,
                      step_timer=FakeClock())
    records = []
    eng.add_step_listener(records.append)
    eng.submit(np.asarray([1, 5], np.int32), max_new_tokens=3)
    eng.submit(np.asarray([2], np.int32), max_new_tokens=3)
    done = eng.run()
    assert len(done) == 2
    assert records, "listeners never fired"
    assert all(isinstance(r, StepRecord) for r in records)
    # one t0 + one t1 read per timed step: duration is exactly one tick
    assert all(abs(r.duration_s - 0.5e-3) < 1e-12 for r in records)
    assert [r.index for r in records] == sorted({r.index for r in records})
    assert all(1 <= r.active <= 2 for r in records)


def test_online_tuner_attached_to_engine(small_model, tmp_path):
    """End-to-end serve-path integration: the tuner's trial configs are
    applied around decode steps via the override stack, measurements flow
    back, and a faster trial gets promoted — all on a fake clock."""
    from repro.core.space import Workload, build_space
    from repro.tuning import OnlineTuner, TunerSession, attach
    from repro.tuning.online import ranked_candidates
    from repro.tuning.sweep import config_key

    cfg, model, params = small_model
    # n=256 keeps the space multi-config (block_q/block_k in {128, 256});
    # at n=128 every block knob is pinned and there is no trial to run
    wl = Workload(op="attention", n=256, batch=2, variant="flash")
    session = TunerSession(db_path=str(tmp_path / "serve_db.json"))
    prior = session.resolve_raw(wl)
    fast = ranked_candidates(build_space(wl), 1,
                             exclude=(config_key(prior),))[0]
    tuner = OnlineTuner(wl, session, candidates=[fast], budget=8,
                        min_samples=2, samples_per_trial=3, store=True)

    class ConfigClock:
        """Step duration depends on the config live during the step."""
        t = 0.0

        def __call__(self):
            key = config_key(tuner.config())
            self.t += (0.5e-3 if key == config_key(fast) else 1.0e-3)
            return self.t

    eng = ServeEngine(model, params, max_batch=2, max_len=64,
                      step_timer=ConfigClock())
    attach(eng, tuner)
    rng = np.random.default_rng(0)
    for _ in range(6):
        eng.submit(rng.integers(0, cfg.vocab, size=3), max_new_tokens=4)
    eng.run()
    assert tuner.steps > 0 and tuner.measured <= 8
    assert tuner.promotions == 1                  # the fast config won
    assert tuner.incumbent.config == fast
    assert session.lookup(wl) == fast             # persisted mid-traffic
    # the jitted decode was re-traced per distinct config fragment, so the
    # trial knobs genuinely reached trace-time config resolution (a single
    # baked executable would measure identical code for every "trial")
    assert len(eng._decode_variants) == 3         # no-ov, prior, fast


def test_decode_variant_reused_on_config_revisit(small_model):
    """Returning to a previously-applied config must be a jit-cache hit,
    not a recompile (rollback to incumbent happens constantly)."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    frag_a = {"scan": {"radix": 4}}
    frag_b = {"scan": {"radix": 8}}
    eng._select_decode_variant(frag_a)
    fn_a = eng._decode
    eng._select_decode_variant(frag_b)
    assert eng._decode is not fn_a
    eng._select_decode_variant({"scan": {"radix": 4}})   # revisit, new dict
    assert eng._decode is fn_a
    eng._select_decode_variant(None)
    assert len(eng._decode_variants) == 3                # None, a, b


def test_untimed_engine_has_no_hook_state(small_model):
    """No listeners -> the timing branch never runs (the <5% overhead
    premise benchmarks/bench_online.py measures)."""
    cfg, model, params = small_model

    def exploding_timer():
        raise AssertionError("timer must not be read without listeners")

    eng = ServeEngine(model, params, max_batch=2, max_len=64,
                      step_timer=exploding_timer)
    eng.submit(np.asarray([3, 1], np.int32), max_new_tokens=2)
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 2


def test_single_token_prompt(small_model):
    """prompt[:-1] is empty for a 1-token prompt — no replay steps, decode
    starts straight from the prompt token at position 0."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=32)
    eng.submit(np.asarray([7], np.int32), max_new_tokens=3)
    done = eng.run()
    assert len(done) == 1
    assert len(done[0].output) == 3
    assert all(0 <= t < cfg.vocab for t in done[0].output)

    # greedy consistency against a direct decode loop
    import jax.numpy as jnp
    cache = model.init_cache(1, 32, dtype=jnp.float32)
    out, cur, pos = [], 7, 0
    for _ in range(3):
        lg, cache = model.decode_step(params, jnp.asarray([[cur]]), cache,
                                      jnp.asarray([[pos]]))
        cur = int(jnp.argmax(lg[0, 0]))
        out.append(cur)
        pos += 1
    assert out == done[0].output


def test_finish_reason_stop_vs_length(small_model):
    """finish_reason distinguishes a natural budget stop from hitting the
    context-length ceiling."""
    cfg, model, params = small_model
    from repro.serve.engine import FINISH_LENGTH, FINISH_STOP

    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    eng.submit(np.asarray([3, 1, 4], np.int32), max_new_tokens=5)
    done = eng.run()
    assert done[0].finish_reason == FINISH_STOP
    assert len(done[0].output) == 5

    # prompt fills 10 of 16 positions; the lane runs out of context after
    # 6 decode steps, long before the 50-token budget
    eng = ServeEngine(model, params, max_batch=2, max_len=16)
    eng.submit(np.arange(1, 11, dtype=np.int32), max_new_tokens=50)
    done = eng.run()
    assert done[0].finish_reason == FINISH_LENGTH
    assert len(done[0].output) == 6


def test_decode_variant_table_capped(small_model):
    """A long alternating trial/rollback sequence must not grow the jit
    table without bound: LRU-capped at max_variants, with the baseline
    (None) pinned and the current incumbent always resident."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64,
                      max_variants=4)
    incumbent = {"scan": {"radix": 2}}
    eng._select_decode_variant(incumbent)
    incumbent_fn = eng._decode

    for radix in (4, 8, 16, 32, 64, 128):      # six distinct trial frags
        eng._select_decode_variant({"scan": {"radix": radix}})
        # rollback to incumbent after every trial, as the tuner does
        eng._select_decode_variant(incumbent)

    assert len(eng._decode_variants) <= 4
    assert None in eng._decode_variants        # baseline pinned
    # incumbent survived every eviction round and is still a cache hit
    eng._select_decode_variant({"scan": {"radix": 2}})
    assert eng._decode is incumbent_fn
    # the engine still serves correctly after evictions
    eng._select_decode_variant(None)
    eng.submit(np.asarray([5, 9], np.int32), max_new_tokens=2)
    assert len(eng.run()[0].output) == 2


def test_admit_threshold_batches_admissions(small_model):
    """admit_threshold holds admissions until enough slots free so prompts
    share prefill scans; results still arrive in submission order."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=4, max_len=64,
                      prefill_chunk=8, admit_threshold=4)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (9, 5, 8, 3)]
    for p in prompts:
        eng.submit(p, max_new_tokens=3)
    done = eng.run()
    assert [r.rid for r in done] == [0, 1, 2, 3]
    assert all(len(r.output) == 3 for r in done)
    # the whole co-admitted group shared ceil(max(plen-1)/chunk) dispatches
    assert eng.prefill_calls == 1
