"""Pallas TPU kernel: tiled matmul with tunable (block_m, block_n, block_k).

The demonstration target for applying the paper's tuning methodologies to an
MXU-bound kernel (the prefix ops are VPU/DMA-bound). K is the sequential
grid dimension; partial products accumulate in an f32 VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def matmul_pallas(a: jax.Array, b: jax.Array, *, block_m: int = 256,
                  block_n: int = 256, block_k: int = 256,
                  interpret: bool = False) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    block_m, block_n, block_k = min(block_m, m), min(block_n, n), min(block_k, k)
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, t: (i, t)),
            pl.BlockSpec((block_k, block_n), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
