"""Hardware layer: parametric machine models (see repro.hw.profiles)."""
from repro.hw.profiles import (  # noqa: F401
    CPU_INTERPRET,
    GPU_SM,
    TPU_V5E,
    HardwareProfile,
    active_profile,
    get_profile,
    profile_distance,
    profiles,
    register_profile,
)

__all__ = [
    "HardwareProfile", "TPU_V5E", "GPU_SM", "CPU_INTERPRET",
    "register_profile", "get_profile", "profiles", "active_profile",
    "profile_distance",
]
