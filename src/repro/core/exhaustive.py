"""Exhaustive and random searches (the paper's ground truth + sanity baseline)."""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.bayesian import TuneResult
from repro.core.objective import Objective, PENALTY_TIME
from repro.core.space import Config, SearchSpace


class ExhaustiveSearch:
    """Evaluates every valid configuration. Guarantees the optimum; used to
    compute the paper's Phi metric denominators.

    Runs on the ``repro.tuning.sweep`` engine: candidates are evaluated in
    vectorized batches through ``Objective.batch_eval``; with
    ``journal_dir`` each chunk checkpoints to a per-(workload, objective)
    JSONL journal so interrupted sweeps resume instead of restarting, and
    ``prune="analytical"`` measures only the ``top_k`` model-ranked
    candidates (``stopped_by`` then truthfully reports ``"pruned"`` —
    a pruned sweep no longer guarantees the optimum).

    ``policy`` picks the winner from the sweep's Pareto front instead of
    the fastest config (see ``repro.core.policy``); the journal stays
    keyed by the RAW objective, so one sweep's measurements serve every
    policy.
    """

    name = "exhaustive"

    def __init__(self, journal_dir: Optional[str] = None,
                 prune: Optional[str] = None, top_k: Optional[int] = None,
                 chunk: int = 1024, policy=None):
        self.journal_dir = journal_dir
        self.prune = prune
        self.top_k = top_k
        self.chunk = chunk
        self.policy = policy

    def tune(self, space: SearchSpace, objective: Objective) -> TuneResult:
        # deferred import: repro.tuning.session imports this module
        from repro.tuning.sweep import SweepJournal, run_sweep

        journal = None
        if self.journal_dir:
            journal = SweepJournal.for_workload(self.journal_dir,
                                                space.workload, objective)
        result = run_sweep(space, objective, journal=journal,
                           prune=self.prune, top_k=self.top_k,
                           chunk=self.chunk, policy=self.policy)
        return result.as_tune_result()


class RandomSearch:
    """Uniform random sampling without replacement — the bar any smarter
    search must beat (cf. the paper's citation of [35])."""

    name = "random"

    def __init__(self, max_evals: int = 16, seed: int = 0):
        self.max_evals = max_evals
        self.seed = seed

    def tune(self, space: SearchSpace, objective: Objective) -> TuneResult:
        rng = np.random.default_rng(self.seed)
        candidates = space.enumerate_valid()
        if not candidates:
            raise ValueError(f"empty search space for {space.workload.key}")
        order = rng.permutation(len(candidates))[: self.max_evals]
        history: List[Tuple[Config, float]] = []
        best_cfg, best_t = None, float("inf")
        for idx in order:
            cfg = candidates[int(idx)]
            m = objective(space, cfg)
            t = m.time_s if m.valid else PENALTY_TIME
            history.append((cfg, t))
            if t < best_t:
                best_cfg, best_t = cfg, t
        # same semantics as BayesianTuner: "max_evals" only when the budget
        # was the binding constraint; a full enumeration is "exhausted"
        stopped_by = "max_evals" if len(history) >= self.max_evals \
            else "exhausted"
        return TuneResult(best_cfg, best_t, len(history), history, stopped_by)
