"""Vectorized, resumable exhaustive sweeps (the Phi-denominator engine).

Exhaustive search is the load-bearing wall of the paper's evaluation: it
supplies the optimum every other methodology is scored against, and the
dense (config, time) pairs the ML predictor trains on.  This module
replaces the seed's serial per-config Python loop with:

  * **batched evaluation** — the whole candidate set goes through
    ``Objective.batch_eval`` (a handful of numpy array ops on the cost
    model) instead of thousands of Python calls;
  * **a resumable journal** — one JSONL file per (workload, objective)
    with atomic line appends, so a long wall-clock sweep survives
    interruption and a re-run only evaluates what is missing;
  * **analytical-dominance pruning** — ``prune="analytical"`` keeps the
    top-k candidates ranked by the zero-evaluation expert model (the
    model-steered pruning lever of Schoonhoven et al.), recording how many
    candidates were dropped.

``run_sweep`` is what ``ExhaustiveSearch.tune`` (and therefore
``strategy="exhaustive"``) executes; ``repro.tuning.ml.dataset`` consumes
the same journals directly as training rows.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bayesian import TuneResult
from repro.core.objective import Objective
from repro.core.space import Config, SearchSpace, Workload

# v2 adds the hardware-profile name to the header; v1 journals (pre-profile,
# all measured on the tpu_v5e model) stay readable — the objective-signature
# check already rejects cross-profile resumption, since the profile name is
# embedded in every cost-model signature.
JOURNAL_VERSION = 2

# default kept-set size for prune="analytical"; expensive objectives can
# pass an explicit top_k
DEFAULT_TOP_K = 64


def config_key(cfg: Config) -> str:
    """Canonical, order-independent identity of a config inside one space."""
    return ",".join(f"{k}={cfg[k]}" for k in sorted(cfg))


def _safe(token: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.=-]+", "_", token)


def journal_path(journal_dir: str, wl: Workload, objective: Objective) -> str:
    """Per-(workload, objective) journal file inside ``journal_dir``."""
    return os.path.join(journal_dir,
                        f"{_safe(wl.key)}__{_safe(objective.signature())}.jsonl")


class SweepJournal:
    """Append-only JSONL checkpoint for one (workload, objective) sweep.

    Line 1 is a header carrying the workload fields and the objective
    signature; every subsequent line is one completed evaluation.  Appends
    go through a single ``os.write`` on an ``O_APPEND`` descriptor per
    chunk, so a killed sweep leaves at most one torn trailing line — which
    ``load`` skips — and concurrent writers never interleave mid-line.
    """

    def __init__(self, path: str):
        self.path = path

    @classmethod
    def for_workload(cls, journal_dir: str, wl: Workload,
                     objective: Objective) -> "SweepJournal":
        os.makedirs(journal_dir, exist_ok=True)
        return cls(journal_path(journal_dir, wl, objective))

    # -- reading ------------------------------------------------------------

    def load(self, wl: Optional[Workload] = None,
             objective: Optional[Objective] = None) -> Dict[str, float]:
        """Completed {config_key: time_s}; {} when the journal is absent.

        When ``wl``/``objective`` are given, a header that does not match
        raises — silently resuming someone else's numbers would corrupt
        the optimum.
        """
        if not os.path.exists(self.path):
            return {}
        done: Dict[str, float] = {}
        header_ok = False
        with open(self.path, "r") as f:
            for i, line in enumerate(f):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue     # torn trailing line from a killed run
                if not isinstance(rec, dict):
                    continue     # parseable but not a record (e.g. "123")
                if i == 0 and rec.get("kind") == "header":
                    self._check_header(rec, wl, objective)
                    header_ok = True
                    continue
                if "k" in rec and "t" in rec:
                    done[rec["k"]] = float(rec["t"])
        if not header_ok and (wl is not None or objective is not None):
            # a torn/missing header means the entries cannot be validated
            # against this (workload, objective) — never resume them.
            # Quarantine the bytes and let the sweep start a fresh journal.
            self._quarantine()
            return {}
        return done

    def read_header(self) -> Optional[Dict]:
        if not os.path.exists(self.path):
            return None
        with open(self.path, "r") as f:
            first = f.readline().strip()
        if not first:
            return None
        try:
            rec = json.loads(first)
        except json.JSONDecodeError:
            return None
        return rec if isinstance(rec, dict) and rec.get("kind") == "header" \
            else None

    def entries(self) -> List[Tuple[Config, float]]:
        """Completed (config, time) pairs, first-completion order.

        Deduplicated by config (last line wins, matching ``load``):
        concurrent writers that both loaded before either appended can
        legally write the same config twice.
        """
        if not os.path.exists(self.path):
            return []
        seen: Dict[str, int] = {}
        out: List[Tuple[Config, float]] = []
        with open(self.path, "r") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if not isinstance(rec, dict) or rec.get("kind") == "header" \
                        or "cfg" not in rec:
                    continue
                cfg = {k: int(v) for k, v in rec["cfg"].items()}
                key = config_key(cfg)
                pair = (cfg, float(rec["t"]))
                if key in seen:
                    out[seen[key]] = pair
                else:
                    seen[key] = len(out)
                    out.append(pair)
        return out

    @staticmethod
    def _check_header(rec: Dict, wl: Optional[Workload],
                      objective: Optional[Objective]) -> None:
        if wl is not None and rec.get("workload", {}).get("key") != wl.key:
            raise ValueError(
                f"sweep journal is for workload "
                f"{rec.get('workload', {}).get('key')!r}, not {wl.key!r}")
        if objective is not None and rec.get("objective") != objective.signature():
            raise ValueError(
                f"sweep journal was measured with objective "
                f"{rec.get('objective')!r}, not {objective.signature()!r}")
        if objective is not None and rec.get("profile") is not None:
            want = getattr(getattr(objective, "spec", None), "name", None)
            if want is not None and rec["profile"] != want:
                raise ValueError(
                    f"sweep journal was measured on profile "
                    f"{rec.get('profile')!r}, not {want!r}")

    # -- writing ------------------------------------------------------------

    def _quarantine(self) -> None:
        """Set a corrupt journal aside (bytes preserved for post-mortem)."""
        target = self.path + ".corrupt"
        try:
            os.replace(self.path, target)
        except OSError:
            os.unlink(self.path)

    def _ensure_header(self, wl: Workload, objective: Objective,
                       space_size: int, pruned: int = 0) -> None:
        if os.path.exists(self.path) and os.path.getsize(self.path):
            if self.read_header() is not None:
                return
            # non-empty but headerless (e.g. the very first os.write was
            # torn): unusable — quarantine and re-journal from scratch
            self._quarantine()
        # space_size is the FULL valid-space size; a pruned sweep records
        # how much it dropped so journal consumers (dataset export) can
        # tell "complete enumeration" from "model-steered subset"
        header = {"kind": "header", "version": JOURNAL_VERSION,
                  "workload": {"key": wl.key, "op": wl.op, "n": wl.n,
                               "batch": wl.batch, "dtype": wl.dtype,
                               "variant": wl.variant},
                  "objective": objective.signature(),
                  # device the times were measured on (None for objectives
                  # that carry no hardware model, e.g. wallclock runners)
                  "profile": getattr(getattr(objective, "spec", None),
                                     "name", None),
                  "space_size": space_size,
                  "pruned": int(pruned)}
        self._append_lines([json.dumps(header, sort_keys=True)])

    def append(self, wl: Workload, objective: Objective, space_size: int,
               entries: Sequence[Tuple[Config, float]],
               pruned: int = 0) -> None:
        self._ensure_header(wl, objective, space_size, pruned)
        self._append_lines(
            json.dumps({"k": config_key(cfg), "cfg": cfg, "t": float(t)},
                       sort_keys=True)
            for cfg, t in entries)

    def _append_lines(self, lines) -> None:
        payload = "".join(line + "\n" for line in lines).encode()
        if not payload:
            return
        if self._tail_torn():
            # a previous writer died mid-line: appending directly would glue
            # our first record onto the torn bytes and lose BOTH lines to
            # the json parse. Terminate the torn line first — load() skips
            # it, and every entry in this payload stays parseable.
            payload = b"\n" + payload
        fd = os.open(self.path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, payload)
        finally:
            os.close(fd)

    def _tail_torn(self) -> bool:
        """True when the journal ends mid-line (a writer was killed inside
        its os.write) — the next append must not extend that line."""
        try:
            with open(self.path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                return f.read(1) != b"\n"
        except (OSError, ValueError):   # absent or empty file
            return False


# ---------------------------------------------------------------------------
# Pruning
# ---------------------------------------------------------------------------

def prune_candidates(space: SearchSpace, cands: List[Config],
                     top_k: int) -> Tuple[List[Config], int]:
    """Keep the ``top_k`` analytically-ranked candidates, enumeration order.

    The expert model ranks for free (no objective evaluations); measuring
    only its favourites is the Prajapati-style "rank before you measure"
    lever for objectives where every evaluation is minutes of wall clock.
    """
    if top_k >= len(cands):
        return cands, 0
    from repro.core.analytical import score
    order = sorted(range(len(cands)),
                   key=lambda i: score(space, cands[i]).key(), reverse=True)
    kept_idx = sorted(order[:top_k])          # preserve enumeration order
    return [cands[i] for i in kept_idx], len(cands) - top_k


# ---------------------------------------------------------------------------
# The sweep
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    best_config: Config
    best_time: float
    evaluations: int                     # fresh objective evaluations
    resumed: int                         # configs answered by the journal
    pruned: int                          # candidates dropped before measuring
    total: int                           # candidates actually swept
    history: List[Tuple[Config, float]]  # enumeration order, penalty-clamped
    stopped_by: str                      # "exhausted" | "pruned"
    journal: Optional[str] = None        # journal path, when journaled

    def as_tune_result(self) -> TuneResult:
        return TuneResult(self.best_config, self.best_time,
                          self.evaluations + self.resumed, self.history,
                          self.stopped_by)


def run_sweep(space: SearchSpace, objective: Objective, *,
              journal: Optional[SweepJournal] = None,
              prune: Optional[str] = None, top_k: Optional[int] = None,
              chunk: int = 1024) -> SweepResult:
    """Evaluate the (optionally pruned) valid space; resume from ``journal``.

    Evaluation happens in ``chunk``-sized batches through
    ``objective.batch_eval``; each completed chunk is journaled before the
    next starts, so an interrupted sweep re-run skips everything already
    measured and still returns the identical winner.
    """
    wl = space.workload
    cands = space.enumerate_valid()
    if not cands:
        raise ValueError(f"empty search space for {wl.key}")
    full_size = len(cands)

    pruned = 0
    if prune is not None:
        if prune != "analytical":
            raise ValueError(f"unknown prune mode {prune!r}; "
                             f"supported: 'analytical'")
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        cands, pruned = prune_candidates(
            space, cands, top_k if top_k is not None else DEFAULT_TOP_K)

    times = np.full(len(cands), np.nan)
    resumed = 0
    if journal is not None:
        done = journal.load(wl, objective)
        pending: List[int] = []
        for i, cand in enumerate(cands):
            t = done.get(config_key(cand)) if done else None
            if t is None:
                pending.append(i)
            else:
                times[i] = t
                resumed += 1
    else:
        pending = list(range(len(cands)))

    chunk = max(int(chunk), 1)
    for lo in range(0, len(pending), chunk):
        idx = pending[lo: lo + chunk]
        ts = objective.batch_eval(space, [cands[i] for i in idx],
                                  assume_valid=True)
        times[idx] = ts
        if journal is not None:
            journal.append(wl, objective, full_size,
                           [(cands[i], float(t)) for i, t in zip(idx, ts)],
                           pruned=pruned)

    best_i = int(np.argmin(times))
    return SweepResult(
        best_config=cands[best_i],
        best_time=float(times[best_i]),
        evaluations=len(pending),
        resumed=resumed,
        pruned=pruned,
        total=len(cands),
        history=list(zip(cands, times.tolist())),
        stopped_by="pruned" if pruned else "exhausted",
        journal=journal.path if journal is not None else None,
    )
