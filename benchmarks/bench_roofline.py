"""Roofline-table benchmark: summarizes the dry-run artifacts into CSV
(reads artifacts/roofline/*.json — run launch.roofline --all first; cells
missing artifacts are reported as such rather than recomputed, since each
compile takes minutes)."""
from __future__ import annotations

import json
import os

from repro.configs.base import SHAPES, all_archs

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "roofline")


def run(emit) -> None:
    for arch in all_archs():
        for shape in SHAPES:
            path = os.path.join(ART, f"{arch}_{shape}_16x16.json")
            if not os.path.exists(path):
                emit(f"roofline,{arch},{shape},missing,,,,")
                continue
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") == "skipped":
                emit(f"roofline,{arch},{shape},skipped,,,,")
                continue
            if rec.get("status") != "ok":
                emit(f"roofline,{arch},{shape},failed,,,,")
                continue
            t = rec["roofline"]
            emit(f"roofline,{arch},{shape},ok,"
                 f"{t['compute_s']*1e3:.2f},{t['memory_s']*1e3:.2f},"
                 f"{t['collective_s']*1e3:.2f},{rec['dominant']}"
                 f",{rec.get('mfu_upper_bound', 0):.3f}")


if __name__ == "__main__":
    run(print)
