"""Tiled matmul kernel vs jnp.dot."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (256, 256, 256, 128, 128, 128),
    (256, 384, 512, 128, 256, 128),
    (128, 128, 128, 128, 128, 128),
    (512, 256, 256, 256, 128, 256),
])
def test_matmul_block_sweep(m, k, n, bm, bn, bk):
    a = jax.random.normal(KEY, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), jnp.float32)
    got = matmul(a, b, config={"block_m": bm, "block_n": bn, "block_k": bk},
                 interpret=True)
    np.testing.assert_allclose(got, matmul_ref(a, b), rtol=1e-4, atol=5e-4)


# dtype x odd/prime-shape coverage moved to the shared differential suite
# (tests/conftest.py KERNEL_CASES + test_kernels_differential.py)
