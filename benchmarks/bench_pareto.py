"""Pareto-front / policy sweep: does multi-objective tuning change answers?

For each benchmark workload the full space is swept once on the
deterministic device model (full metric vectors: time / modeled joules /
peak VMEM), then each policy (latency, energy, edp) picks its winner from
the same measurements — exactly what ``TunerSession`` does under a
policy.  Rows record per-policy winners, their real seconds and joules,
and the Pareto-front size (the number of genuinely distinct trade-offs
the space offers).

The CI gate asserts the subsystem is not decorative: **at least one
workload must flip winners between the latency and energy policies, with
the energy winner spending strictly fewer modeled joules**.  Pure
cost-model arithmetic — immune to runner noise.

Standalone (the CI bench-smoke invocation):

  PYTHONPATH=src:. python benchmarks/bench_pareto.py \
      --json BENCH_pareto.json [--smoke]

exits non-zero when the gate fails; ``run.py --only pareto`` emits the
same rows as a section.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

import numpy as np

from repro.core import CostModelObjective, Workload, build_space
from repro.core.objective import METRIC_ENERGY, METRIC_TIME
from repro.core.policy import get_policy, pareto_front, policy_scalar_cols
from repro.hw.profiles import get_profile

PROFILE = "tpu_v5e"
POLICIES = ("latency", "energy", "edp")

CASES = [("scan", "lf", 256, 4096), ("scan", "lf", 1024, 512),
         ("fft", "stockham", 256, 4096), ("tridiag", "wm", 256, 4096)]
SMOKE_CASES = [("scan", "lf", 1024, 512), ("fft", "stockham", 256, 4096)]


def run(emit, seed: int = 0, smoke: bool = False) -> List[str]:
    """Emit pareto rows; returns gate-failure strings (empty = pass)."""
    prof = get_profile(PROFILE)
    obj = CostModelObjective(prof)
    cases = SMOKE_CASES if smoke else CASES

    flips = 0
    for op, variant, n, batch in cases:
        wl = Workload(op=op, n=n, batch=batch, variant=variant)
        space = build_space(wl, prof)
        cands = space.enumerate_valid()
        cols = obj.batch_eval_metrics(space, cands, assume_valid=True)

        front = pareto_front(cols, cands, obj.metric_names())
        emit(f"pareto,{op},{variant},{n},front,size,{len(front)},"
             f"space={len(cands)}")

        winners = {}
        for name in POLICIES:
            scal = policy_scalar_cols(get_policy(name, prof), cols)
            i = int(np.argmin(scal))
            winners[name] = i
            emit(f"pareto,{op},{variant},{n},{name},time_us,"
                 f"{cols[METRIC_TIME][i] * 1e6:.3f},"
                 f"cfg={json.dumps(cands[i], sort_keys=True)}")
            emit(f"pareto,{op},{variant},{n},{name},energy_mj,"
                 f"{cols[METRIC_ENERGY][i] * 1e3:.4f},scalar={scal[i]:.6g}")

        i_lat, i_eng = winners["latency"], winners["energy"]
        flipped = cands[i_lat] != cands[i_eng] and \
            cols[METRIC_ENERGY][i_eng] < cols[METRIC_ENERGY][i_lat]
        flips += flipped
        saved = 1.0 - cols[METRIC_ENERGY][i_eng] / cols[METRIC_ENERGY][i_lat]
        emit(f"pareto,{op},{variant},{n},energy_vs_latency,winner_flips,"
             f"{int(flipped)},joules_saved={saved:.2%}")

    failures: List[str] = []
    if not flips:
        failures.append(
            "no workload flipped winners between the latency and energy "
            "policies with lower modeled joules — the policy layer is not "
            "changing any answer")
    emit(f"pareto,ALL,,,energy_vs_latency,flips,{flips},gate>=1")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Per-policy sweep winners + Pareto front benchmark")
    ap.add_argument("--json", default=None,
                    help="write the rows + gate verdict here "
                         "(e.g. BENCH_pareto.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced case matrix for CI")
    args = ap.parse_args(argv)

    rows: List[str] = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    failures = run(emit, seed=args.seed, smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "pareto", "seed": args.seed,
                       "smoke": bool(args.smoke), "profile": PROFILE,
                       "policies": list(POLICIES), "rows": rows,
                       "failures": failures},
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    for failure in failures:
        print(f"[bench-pareto] FAIL: {failure}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
