"""Tuned FFT entry points: in-VMEM Stockham + four-step large-N driver.

`fft(x)` — x complex (batch, n):
  * n <= max in-VMEM tile: single Stockham kernel launch, radix/rows from
    the TunerSession (paper §V-C small/medium sizes);
  * larger n: Bailey four-step decomposition N = n1*n2 — column FFTs,
    twiddle, row FFTs, transpose — i.e. the paper's §IV-C multi-kernel
    strategy with m kernels; the tile split n1 comes from the tuned
    `tile_n` (analytical rule: the largest resident tile minimizes m).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.space import Workload, fft_space, fit_block, large_fft_space
from repro.core.multikernel import max_resident_tile
from repro.kernels.fft.kernel import fft_pallas
from repro.kernels.fft.ref import fft_ref
from repro.tuning import default_session, on_cpu, tuned_kernel


def _normalize(cfg, wl, dims=None):
    """Raw Stockham knobs; rows are re-fitted per sub-launch (the four-step
    path runs the kernel at several different sub-batch sizes)."""
    return {"radix": cfg.get("radix", 2),
            "rows_per_program": cfg.get("rows_per_program", 4),
            "tile_n": cfg.get("tile_n", 2048)}


def _kernel_fft(x: jax.Array, radix: int, rows: int, inverse: bool,
                interpret: bool) -> jax.Array:
    batch, n = x.shape
    rows = fit_block(rows, batch)
    re, im = jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)
    yre, yim = fft_pallas(re, im, rows_per_program=rows, radix=radix,
                          inverse=inverse, interpret=interpret)
    return (yre + 1j * yim).astype(jnp.complex64)


@tuned_kernel("fft", space=fft_space, pallas=fft_pallas, reference=fft_ref,
              normalize=_normalize, variants=("stockham",))
def fft(x: jax.Array, config: Optional[dict] = None,
        interpret: Optional[bool] = None, inverse: bool = False) -> jax.Array:
    batch, n = x.shape
    interpret = on_cpu() if interpret is None else interpret
    session = default_session()
    wl_small = Workload(op="fft", n=n, batch=batch, variant="stockham")
    max_tile = max_resident_tile(wl_small)
    if n <= max_tile:
        cfg = session.resolve(wl_small, config=config)
        return _kernel_fft(x, cfg["radix"], cfg["rows_per_program"],
                           inverse, interpret)

    # ---- four-step multi-kernel path ----
    cfg = session.resolve(
        Workload(op="large_fft", n=n, batch=batch, variant="stockham"),
        config=config)
    n1 = fit_block(min(cfg["tile_n"], max_tile), n)
    n2 = n // n1
    sign = 1.0 if inverse else -1.0
    v = x.reshape(batch, n2, n1)
    # kernel 1: length-n2 FFTs down the columns (batch*n1 problems)
    vc = jnp.transpose(v, (0, 2, 1)).reshape(batch * n1, n2)
    if n2 <= max_tile:
        vc = _kernel_fft(vc, cfg["radix"], cfg["rows_per_program"],
                         inverse, interpret)
    else:  # recurse (m = 3 kernels, paper: N >= 2^19)
        vc = fft(vc, interpret=interpret, inverse=inverse)
    v = jnp.transpose(vc.reshape(batch, n1, n2), (0, 2, 1))
    # twiddle
    k2 = jnp.arange(n2).reshape(1, n2, 1)
    k1 = jnp.arange(n1).reshape(1, 1, n1)
    v = v * jnp.exp(sign * 2j * jnp.pi * (k1 * k2) / n).astype(jnp.complex64)
    # kernel 2: length-n1 FFTs along rows
    vr = v.reshape(batch * n2, n1)
    vr = _kernel_fft(vr, cfg["radix"], cfg["rows_per_program"],
                     inverse, interpret)
    v = vr.reshape(batch, n2, n1)
    # transpose for self-sorting output
    return jnp.transpose(v, (0, 2, 1)).reshape(batch, n)


# the four-step driver resolves op="large_fft" through the same session;
# register its space under that name too
tuned_kernel("large_fft", space=large_fft_space, pallas=fft_pallas,
             reference=fft_ref, normalize=_normalize,
             variants=("stockham",))(fft)


def ifft(x: jax.Array, config: Optional[dict] = None,
         interpret: Optional[bool] = None) -> jax.Array:
    return fft(x, config=config, interpret=interpret, inverse=True)
