"""Tuned SSD op: chunked state-space dual as a planned chain.

`ssd(x, a, b, c)` with shapes (B, L, H, P), (B, L, H), (B, L, S), (B, L, S).
The chunk length comes from the TunerSession (op="ssd" shares the scan
space; tile_n -> chunk). On CPU hosts the pure-jnp chunked formulation runs
(same math, XLA-fused); the Pallas path is exercised in interpret mode by
tests and compiled on real TPUs.

The op executes the intra → linrec → apply *chain* the planner lays out
(``plan_for_chain``): unfused (``fuse=0``), phase B runs on the shared
``driver.linrec_rows`` building block with the enclosing resolution
threaded into it — ``ssd(config=...)`` and ``overrides(ssd=...)`` reach
the embedded block's radix instead of silently re-resolving under
``config=None``; fused (``fuse=1``), phases B + C collapse into the
sequential ``ssd_state_apply_pallas`` launch whose VMEM carry holds the
inter-chunk state (no HBM roundtrip, and odd chunk counts need no
radix-space fallback). Every launch is recorded against the chain plan,
so ``capture_launches`` traces equal ``chain.launches``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.space import Workload, fit_block, scan_space
from repro.kernels.blocks import driver
from repro.kernels.blocks.plan import plan_for_chain
from repro.kernels.ssd.kernel import (ssd_apply_entry_pallas,
                                      ssd_intra_pallas,
                                      ssd_state_apply_pallas)
from repro.kernels.ssd.ref import ssd_chunked_ref
from repro.tuning import default_session, plan_execution, tuned_kernel


def _normalize(cfg, wl, dims=None):
    """Launch knobs: the chunk length (tuned tile_n fit to L), the radix
    the chain threads into the embedded phase-B scan, and the chain-fusion
    boundary."""
    return {"chunk": fit_block(cfg.get("tile_n", 128), wl.n),
            "radix": cfg.get("radix", 2),
            "fuse": cfg.get("fuse", 0)}


@tuned_kernel("ssd", space=scan_space, pallas=ssd_intra_pallas,
              reference=ssd_chunked_ref, normalize=_normalize,
              variants=("chunked",))
def ssd(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
        config: Optional[dict] = None, interpret: Optional[bool] = None,
        use_pallas: Optional[bool] = None) -> jax.Array:
    B, L, H, P = x.shape
    S = b.shape[-1]
    wl = Workload(op="ssd", n=L, batch=B * H, variant="chunked")
    cfg = default_session().resolve(wl, config=config)
    chunk = cfg["chunk"]
    radix = int(cfg.get("radix", 2))
    fuse = int(cfg.get("fuse", 0))
    use_pallas, interpret = plan_execution(use_pallas, interpret)
    if not use_pallas:
        return ssd_chunked_ref(x, a, b, c, chunk=chunk)

    # the chain plan (exact: the runtime state dims pin the embedded
    # phase-B launches) — what the conformance suite compares traces to
    chain = plan_for_chain(
        wl, {"tile_n": chunk, "radix": radix, "fuse": fuse}, dims=(S, P))

    # reshape to (BH, L, ...) rows; broadcast b/c over heads (n_groups=1)
    xbh = jnp.transpose(x, (0, 2, 1, 3)).reshape(B * H, L, P)
    abh = jnp.transpose(a, (0, 2, 1)).reshape(B * H, L)
    bbh = jnp.broadcast_to(b[:, None], (B, H, L, S)).reshape(B * H, L, S)
    cbh = jnp.broadcast_to(c[:, None], (B, H, L, S)).reshape(B * H, L, S)

    y_intra, a_chunk, state = driver.launch(
        ssd_intra_pallas, chain.launches[0], xbh, abh, bbh, cbh,
        chunk=chunk, interpret=interpret)
    nc = L // chunk
    if nc <= 1:
        # single chunk: the entry state is identically zero — the intra
        # kernel alone IS the answer (the plan's one-launch "fused" kind)
        return jnp.transpose(y_intra.reshape(B, H, L, P), (0, 2, 1, 3))

    if fuse:
        # phases B + C in one sequential launch: the (S, P) VMEM carry is
        # the inter-chunk recurrence state — chunk states never round-trip
        # through HBM between the recurrence and the apply
        y = driver.launch(ssd_state_apply_pallas, chain.launches[-1],
                          y_intra, abh, cbh, a_chunk, state, chunk=chunk,
                          interpret=interpret)
        return jnp.transpose(y.reshape(B, H, L, P), (0, 2, 1, 3))

    # phase B: inter-chunk linear recurrence (rows = BH*S*P, length nc) on
    # the shared carry-chain building block — the tuned scan kernel where
    # the (op="scan", variant="linrec") space has a valid config for nc,
    # the XLA reference otherwise (odd nc).  The enclosing resolution is
    # threaded in: the embedded block runs under the chain's radix, not a
    # fresh ``config=None`` resolution that overrides could never reach.
    a_rows = jnp.broadcast_to(a_chunk[:, None, None, :], (B * H, S, P, nc))
    s_rows = jnp.transpose(state, (0, 2, 3, 1))          # (BH, S, P, nc)
    h = driver.linrec_rows(a_rows.reshape(-1, nc), s_rows.reshape(-1, nc),
                           use_pallas=True, interpret=interpret,
                           config={"tile_n": nc, "radix": radix})
    h = h.reshape(B * H, S, P, nc)
    entry = jnp.concatenate(
        [jnp.zeros_like(h[..., :1]), h[..., :-1]], axis=-1)
    entry = jnp.transpose(entry, (0, 3, 1, 2))           # (BH, nc, S, P)

    y = driver.launch(ssd_apply_entry_pallas, chain.launches[-1],
                      y_intra, abh, cbh, entry, chunk=chunk,
                      interpret=interpret)
    return jnp.transpose(y.reshape(B, H, L, P), (0, 2, 1, 3))
