"""Oracle: standard softmax attention (causal / local-window / full)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (BH, Lq, D), k/v: (BH, Lk, D) -> (BH, Lq, D).

    When Lq < Lk the queries are assumed to be the *last* Lq positions
    (decode with a KV cache)."""
    BH, Lq, D = q.shape
    Lk = k.shape[1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(q.dtype)
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(Lq) + (Lk - Lq)
    kpos = jnp.arange(Lk)
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v)
