"""The repro.tuning public API: sessions, overrides, shims, DB schema."""
import json
import os
import threading

import pytest

from repro.core import TuningDB, Workload, build_space
from repro.tuning import (TunerSession, default_session, get_strategy,
                          overrides, registered_kernels, set_default_session,
                          strategies)
from repro.tuning.db import SCHEMA_VERSION


def _wl(n=256, batch=4096, op="scan", variant="ks"):
    return Workload(op=op, n=n, batch=batch, variant=variant)


# ---------------------------------------------------------------------------
# TunerSession core behaviours
# ---------------------------------------------------------------------------

def test_session_roundtrip_fresh_session_lookup(tmp_path):
    """tune -> persist -> a brand-new session sees the stored winner."""
    path = str(tmp_path / "db.json")
    s1 = TunerSession(db_path=path)
    wl = _wl()
    res = s1.tune(wl, method="random", max_evals=8)
    assert s1.lookup(wl) == res.best_config
    s2 = TunerSession(db_path=path)          # fresh session, same store
    assert s2.lookup(wl) == res.best_config
    assert s2.resolve_raw(wl) == res.best_config


def test_resolve_is_cached_and_normalized(tmp_path):
    s = TunerSession(db_path=str(tmp_path / "db.json"))
    wl = _wl()
    c1 = s.resolve(wl)
    c2 = s.resolve(wl)
    assert c1 == c2
    assert s.hits >= 1 and s.misses == 1
    # normalized launch kwargs: knobs divide the workload dims
    assert wl.batch % c1["rows_per_program"] == 0
    assert wl.n % c1["tile_n"] == 0
    # returned dicts are caller-owned copies — mutation cannot poison cache
    c1["tile_n"] = -1
    assert s.resolve(wl)["tile_n"] != -1


def test_analytical_suggestions_memoized(tmp_path, monkeypatch):
    s = TunerSession(db_path=str(tmp_path / "db.json"))
    calls = {"n": 0}
    real = s._analytical.suggest

    def counting(space):
        calls["n"] += 1
        return real(space)

    monkeypatch.setattr(s._analytical, "suggest", counting)
    wl = _wl()
    s.resolve(wl)
    s._resolved.clear()                        # drop resolve LRU only
    s.resolve(wl)
    s.suggest(wl)
    assert calls["n"] == 1                     # one model run per workload key


def test_tune_invalidates_resolve_cache(tmp_path):
    s = TunerSession(db_path=str(tmp_path / "db.json"))
    wl = _wl()
    cold = s.resolve(wl)
    res = s.tune(wl, method="random", max_evals=8)
    warm = s.resolve(wl)
    # post-tune resolution must reflect the DB entry, not the stale cache
    from repro.tuning import normalizer_for
    assert warm == normalizer_for(wl.op)(res.best_config, wl.canonical(), None)
    assert cold is not warm


def test_workload_canonicalization():
    import jax.numpy as jnp

    a = Workload(op="scan", n=256, batch=512, dtype="float32", variant="ks")
    b = Workload(op="scan", n=256, batch=512, dtype=jnp.float32, variant="ks")
    assert b.canonical().key == a.key


# ---------------------------------------------------------------------------
# overrides()
# ---------------------------------------------------------------------------

def test_overrides_nesting_and_restoration(tmp_path):
    s = TunerSession(db_path=str(tmp_path / "db.json"))
    wl = _wl()
    base = s.resolve(wl)
    with overrides(scan={"radix": 4}):
        outer = s.resolve(wl)
        assert outer["radix"] == 4
        with overrides(scan={"radix": 8, "unroll": 2}):
            inner = s.resolve(wl)
            assert inner["radix"] == 8 and inner["unroll"] == 2
        mid = s.resolve(wl)                  # inner frame popped
        assert mid["radix"] == 4 and mid["unroll"] == base["unroll"]
    assert s.resolve(wl) == base             # fully restored


def test_overrides_restore_on_exception(tmp_path):
    s = TunerSession(db_path=str(tmp_path / "db.json"))
    wl = _wl()
    base = s.resolve(wl)
    with pytest.raises(RuntimeError):
        with overrides(scan={"radix": 8}):
            raise RuntimeError("boom")
    assert s.resolve(wl) == base


def test_overrides_are_thread_local(tmp_path):
    s = TunerSession(db_path=str(tmp_path / "db.json"))
    wl = _wl()
    base = s.resolve(wl)
    seen = {}

    def worker():
        seen["other"] = s.resolve(wl)

    with overrides(scan={"radix": 8}):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen["other"] == base             # other thread unaffected


def test_overrides_reject_non_mapping():
    with pytest.raises(TypeError):
        with overrides(scan=4):
            pass


def test_overrides_nest_independently_across_threads(tmp_path):
    """Each thread owns its stack: nesting in a worker neither sees nor
    disturbs the main thread's frames, and vice versa."""
    s = TunerSession(db_path=str(tmp_path / "db.json"))
    wl = _wl()
    base = s.resolve(wl)
    results = {}
    barrier = threading.Barrier(2, timeout=10)

    def worker():
        with overrides(scan={"radix": 2}):
            with overrides(scan={"unroll": 4}):
                barrier.wait()               # main thread is inside radix=8
                results["worker_inner"] = s.resolve(wl)
            results["worker_outer"] = s.resolve(wl)
        results["worker_done"] = s.resolve(wl)

    t = threading.Thread(target=worker)
    with overrides(scan={"radix": 8}):
        t.start()
        barrier.wait()
        results["main_inner"] = s.resolve(wl)
        t.join()
        # worker's frames never leaked into this thread
        assert s.resolve(wl)["radix"] == 8
    assert results["main_inner"]["radix"] == 8
    assert results["main_inner"]["unroll"] == base["unroll"]
    assert results["worker_inner"]["radix"] == 2
    assert results["worker_inner"]["unroll"] == 4
    assert results["worker_outer"]["radix"] == 2
    assert results["worker_outer"]["unroll"] == base["unroll"]
    assert results["worker_done"] == base
    assert s.resolve(wl) == base


# ---------------------------------------------------------------------------
# retired facade: hard ImportError pointers
# ---------------------------------------------------------------------------

def test_legacy_tuner_facade_is_retired():
    """The deprecated repro.core.tuner facade is gone: importing it must
    fail loudly with a pointer at the replacement, and the old names must
    no longer leak from repro.core."""
    with pytest.raises(ImportError, match="repro.tuning"):
        import repro.core.tuner  # noqa: F401
    import repro.core as core
    for name in ("get_config", "tune_offline", "global_db"):
        assert not hasattr(core, name)
    # the TuningDB re-export survives the retirement
    from repro.core import TuningDB as ReExported
    from repro.tuning.db import TuningDB as Canonical
    assert ReExported is Canonical


# ---------------------------------------------------------------------------
# TuningDB: schema, paths, concurrency
# ---------------------------------------------------------------------------

def test_db_schema_versioned_envelope(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDB(path=path)
    db.store(_wl(), {"tile_n": 128}, 1e-4, "random", 3)
    with open(path) as f:
        raw = json.load(f)
    assert raw["schema"] == SCHEMA_VERSION
    assert len(raw["entries"]) == 1


def test_db_migrates_legacy_flat_file(tmp_path):
    path = str(tmp_path / "db.json")
    wl = _wl()
    legacy_key = f"tpu_v5e|{wl.key}"
    with open(path, "w") as f:
        json.dump({legacy_key: {"config": {"tile_n": 64}, "time_s": 1e-4,
                                "method": "bayesian", "evaluations": 5}}, f)
    db = TuningDB(path=path)
    assert db.lookup(wl) == {"tile_n": 64}
    # first store upgrades the file to the enveloped schema
    db.store(_wl(n=512), {"tile_n": 128}, 2e-4, "random", 1)
    with open(path) as f:
        raw = json.load(f)
    assert raw["schema"] == SCHEMA_VERSION
    assert legacy_key in raw["entries"]


def test_db_envelope_preserves_unknown_extra_keys(tmp_path):
    """Round-trip: unknown top-level keys in a schema-2 envelope survive
    load -> store -> reload instead of being dropped."""
    path = str(tmp_path / "db.json")
    wl = _wl()
    envelope = {
        "schema": SCHEMA_VERSION,
        "entries": {f"tpu_v5e|{wl.key}": {"config": {"tile_n": 64},
                                          "time_s": 1e-4, "method": "bayesian",
                                          "evaluations": 5}},
        "meta": {"written_by": "offline-sweeper", "host": "tpu-pod-7"},
        "x-annotations": ["keep", "me"],
    }
    with open(path, "w") as f:
        json.dump(envelope, f)
    db = TuningDB(path=path)
    assert db.lookup(wl) == {"tile_n": 64}
    db.store(_wl(n=512), {"tile_n": 128}, 2e-4, "random", 1)
    with open(path) as f:
        raw = json.load(f)
    assert raw["schema"] == SCHEMA_VERSION
    assert raw["meta"] == envelope["meta"]
    assert raw["x-annotations"] == ["keep", "me"]
    assert len(raw["entries"]) == 2
    # and a fresh handle keeps preserving them on its own writes
    db2 = TuningDB(path=path)
    db2.store(_wl(n=1024), {"tile_n": 256}, 3e-4, "random", 1)
    with open(path) as f:
        raw2 = json.load(f)
    assert raw2["meta"] == envelope["meta"]
    assert raw2["x-annotations"] == ["keep", "me"]


def test_db_store_with_bare_filename_path(tmp_path, monkeypatch):
    """A path with no directory component must not crash (os.makedirs(''))."""
    monkeypatch.chdir(tmp_path)
    db = TuningDB(path="bare_db.json")
    db.store(_wl(), {"tile_n": 128}, 1e-4, "random", 1)
    assert os.path.exists(tmp_path / "bare_db.json")
    assert TuningDB(path="bare_db.json").lookup(_wl()) == {"tile_n": 128}


def test_db_concurrent_store_from_threads(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDB(path=path)
    n_threads, per_thread = 8, 10
    errors = []

    def worker(tid):
        try:
            for i in range(per_thread):
                wl = _wl(n=128 * (1 + i % 4), batch=2 ** (8 + tid % 3))
                db.store(wl, {"tile_n": 128, "tid": tid}, 1e-4, "random", i)
        except Exception as e:              # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # file is valid, enveloped JSON with every distinct key present
    fresh = TuningDB(path=path)
    assert len(fresh.entries()) == len({
        f"tpu_v5e|{_wl(n=128 * (1 + i % 4), batch=2 ** (8 + t % 3)).key}"
        for t in range(n_threads) for i in range(per_thread)})


# ---------------------------------------------------------------------------
# registry + hot-path speedup
# ---------------------------------------------------------------------------

def test_all_seven_kernel_families_registered():
    # importing the ops modules registers the specs
    import repro.kernels.attention.ops    # noqa: F401
    import repro.kernels.fft.ops          # noqa: F401
    import repro.kernels.matmul.ops       # noqa: F401
    import repro.kernels.rglru.ops        # noqa: F401
    import repro.kernels.scan.ops         # noqa: F401
    import repro.kernels.ssd.ops          # noqa: F401
    import repro.kernels.tridiag.ops      # noqa: F401

    specs = registered_kernels()
    ops = {spec.op for spec in specs.values()}
    assert {"scan", "tridiag", "fft", "large_fft", "ssd", "rglru",
            "attention", "matmul"} <= ops
    for spec in specs.values():
        assert callable(spec.normalize)
        assert spec.reference is not None


def test_warm_resolve_much_faster_than_miss_path(tmp_path):
    """Acceptance: repeated resolve() >= 10x faster than the uncached miss
    path (analytical model + space enumeration per call)."""
    import time

    s = TunerSession(db_path=str(tmp_path / "db.json"))
    wl = _wl(n=512, batch=2 ** 15)
    s.resolve(wl)                            # prime

    t0 = time.perf_counter()
    for _ in range(50):
        s.resolve(wl)
    warm = (time.perf_counter() - t0) / 50

    from repro.core.analytical import AnalyticalTuner
    t0 = time.perf_counter()
    for _ in range(3):
        AnalyticalTuner().suggest(build_space(wl))   # the old miss path
    miss = (time.perf_counter() - t0) / 3

    assert miss / max(warm, 1e-9) >= 10, (warm, miss)


def test_strategy_registry_fallback_order_ml_analytical_default(tmp_path,
                                                                monkeypatch):
    """strategy='ml' resolves through the ladder: learned model when an
    artifact exists -> analytical when it doesn't -> the generic guideline
    default, never an error."""
    from repro.core import CachedObjective, TPUCostModelObjective
    from repro.core.analytical import AnalyticalTuner
    from repro.tuning.ml import build_dataset, train_bundle
    from repro.tuning.ml.dataset import POOLED_OPS

    assert "ml" in strategies()
    wl = _wl().canonical()
    space = build_space(wl)

    # rung 2/3: no artifact on disk -> analytical answers (which itself is
    # the guideline's space-wide default ranking, so a config always comes
    # back); the strategy records why
    monkeypatch.setenv("REPRO_ML_MODEL", str(tmp_path / "missing.npz"))
    res = get_strategy("ml")(space, CachedObjective(TPUCostModelObjective()))
    assert res.stopped_by == "ml-fallback:no-model"
    assert res.best_config == AnalyticalTuner().suggest(space)

    # rung 1: train + publish an artifact -> the learned model answers with
    # zero objective evaluations, via the same registry entry
    ds = build_dataset([_wl(n=128, batch=2048), _wl(n=256, batch=2048)])
    bundle = train_bundle(ds.by_op(), n_trees=8, max_depth=8, seed=0,
                          meta={"aliases": POOLED_OPS})
    path = str(tmp_path / "model.npz")
    bundle.save(path)
    monkeypatch.setenv("REPRO_ML_MODEL", path)
    cached = CachedObjective(TPUCostModelObjective())
    res = get_strategy("ml")(space, cached)
    assert res.stopped_by in ("ml", "ml-defer-analytical")
    # zero search evaluations; the one objective call measures the winner
    assert res.evaluations == 0 and cached.evaluations == 1
    assert space.is_valid(res.best_config)

    # rung 2 again, per-op: an op the bundle has no forest for falls back
    mm = Workload(op="matmul", n=512, batch=512).canonical()
    res = get_strategy("ml")(build_space(mm),
                             CachedObjective(TPUCostModelObjective()))
    assert res.stopped_by == "ml-fallback:no-forest:matmul"

    # and the session API reaches the same ladder end-to-end
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    tuned = session.tune(wl, method="ml")
    assert tuned.stopped_by in ("ml", "ml-defer-analytical")
    assert session.lookup(wl) == tuned.best_config


def test_set_default_session_swaps(tmp_path):
    s = TunerSession(db_path=str(tmp_path / "db.json"))
    prev = set_default_session(s)
    try:
        assert default_session() is s
    finally:
        set_default_session(prev)
