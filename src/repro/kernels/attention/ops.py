"""Tuned attention entry point with GQA + decode handling."""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.space import Workload, attention_space, fit_block
from repro.kernels.attention.kernel import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref
from repro.tuning import default_session, plan_execution, tuned_kernel


def _normalize(cfg, wl, dims=None):
    """Fit flash block sizes to the actual (Lq, Lk); wl.n only carries Lk,
    so the entry point passes both lengths through ``dims``."""
    dims = dims or {}
    lq = int(dims.get("lq", wl.n))
    lk = int(dims.get("lk", wl.n))
    return {"block_q": fit_block(cfg.get("block_q", 256), lq),
            "block_k": fit_block(cfg.get("block_k", 256), lk)}


@tuned_kernel("attention", space=attention_space,
              pallas=flash_attention_pallas, reference=attention_ref,
              normalize=_normalize, variants=("flash",))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              config: Optional[dict] = None,
              interpret: Optional[bool] = None,
              use_pallas: Optional[bool] = None) -> jax.Array:
    """Multi-head attention core on flattened (B*H, L, D) tensors.

    GQA callers repeat KV heads before the call. Decode (Lq == 1) always
    takes the XLA path — it is a GEMV-shaped, memory-bound op where flash
    tiling has nothing to add.
    """
    BH, lq, d = q.shape
    lk = k.shape[1]
    use_pallas, interpret = plan_execution(use_pallas, interpret, gate=lq > 1)
    if not use_pallas or lq == 1:
        return attention_ref(q, k, v, causal=causal, window=window)
    cfg = default_session().resolve(
        Workload(op="attention", n=lk, batch=BH, variant="flash"),
        config=config, dims={"lq": lq, "lk": lk})
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=interpret, **cfg)
