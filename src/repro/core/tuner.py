"""Deprecated tuner facade — use :mod:`repro.tuning` instead.

Historical entry points (``get_config``, ``tune_offline``, ``global_db``)
now delegate to a :class:`repro.tuning.TunerSession` and emit
``DeprecationWarning``. They return the same configs as before: the shims
resolve *raw* (pre-normalization) configs, exactly like the old code, so
legacy callers that validate against the search space keep working.

``TuningDB`` lives in :mod:`repro.tuning.db`; the re-export here keeps
``from repro.core import TuningDB`` imports alive.
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.core.bayesian import TuneResult
from repro.core.objective import Objective
from repro.core.space import Config, Workload
from repro.tuning.db import DEFAULT_DB_PATH, TuningDB

__all__ = ["DEFAULT_DB_PATH", "TuningDB", "get_config", "global_db",
           "tune_offline"]


def _warn(old: str, new: str) -> None:
    warnings.warn(f"repro.core.tuner.{old} is deprecated; use {new}",
                  DeprecationWarning, stacklevel=3)


def _session(db: Optional[TuningDB]):
    from repro.tuning.session import TunerSession, default_session

    if db is None:
        return default_session()
    # cache the session on the db itself (same lifetime, no global registry)
    # so analytical memoization and the resolve cache still apply per DB
    session = getattr(db, "_legacy_session", None)
    if session is None:
        session = db._legacy_session = TunerSession(db=db)
    return session


def global_db() -> TuningDB:
    """Deprecated: the default session's DB."""
    _warn("global_db()", "repro.tuning.default_session().db")
    return _session(None).db


def get_config(wl: Workload, db: Optional[TuningDB] = None) -> Config:
    """Deprecated online entry point: DB hit, else analytical suggestion."""
    _warn("get_config()", "repro.tuning.TunerSession.resolve")
    return _session(db).resolve_raw(wl)


def tune_offline(wl: Workload, method: str = "bayesian",
                 objective: Optional[Objective] = None,
                 db: Optional[TuningDB] = None, seed: int = 0,
                 max_evals: int = 64) -> TuneResult:
    """Deprecated offline tuning pass; persists the winner into the DB."""
    _warn("tune_offline()", "repro.tuning.TunerSession.tune")
    return _session(db).tune(wl, method=method, objective=objective,
                             seed=seed, max_evals=max_evals)
