"""Transfer tuning: cross-size amortization + cross-device journal seeding."""
import numpy as np

from repro.core import (BayesianTuner, CachedObjective, CostModelObjective,
                        ExhaustiveSearch, TPUCostModelObjective, Workload,
                        build_space)
from repro.core.transfer import (TaskHistory, TransferBayesianTuner,
                                 device_histories, journal_history, op_family,
                                 transfer_seed, transfer_strategy, tune_family)
from repro.hw.profiles import GPU_SM, TPU_V5E


def _obj():
    return CachedObjective(TPUCostModelObjective(noise=0.02))


def test_transfer_reduces_evaluations_at_equal_quality():
    sizes = [128, 256, 512, 1024]
    fam = tune_family("scan", "lf", sizes, lambda n: 2**26 // n, _obj,
                      seed=0)
    effs_t, tot_t = [], 0
    effs_p, tot_p = [], 0
    for n in sizes:
        sp = build_space(Workload(op="scan", n=n, batch=2**26 // n,
                                  variant="lf"))
        best = ExhaustiveSearch().tune(sp, _obj()).best_time
        tot_t += fam[n].evaluations
        effs_t.append(min(best / fam[n].best_time, 1.0))
        bo = BayesianTuner(seed=0).tune(sp, _obj())
        tot_p += bo.evaluations
        effs_p.append(min(best / bo.best_time, 1.0))
    assert tot_t < tot_p                       # fewer evaluations...
    assert np.mean(effs_t) > np.mean(effs_p) - 0.02   # ...no quality loss


def test_transfer_without_history_still_works():
    wl = Workload(op="fft", n=512, batch=2**17, variant="stockham")
    sp = build_space(wl)
    res = TransferBayesianTuner(seed=1).tune(sp, _obj(), histories=())
    assert sp.is_valid(res.best_config)


# ---------------------------------------------------------------------------
# Family guard (regression: cross-family history pollution)
# ---------------------------------------------------------------------------

def test_op_family_pools_scan_variants():
    assert op_family("ssd") == "scan"
    assert op_family("rglru") == "scan"
    assert op_family("fft") == "fft"


def test_foreign_family_history_is_ignored():
    """An FFT history at the same N must not steer a scan search: the task
    kernel only sees log2(N), so without the guard the foreign
    observations enter the prior at full weight (the regression)."""
    n = 512
    scan_wl = Workload(op="scan", n=n, batch=2**17, variant="lf")
    fft_wl = Workload(op="fft", n=n, batch=2**17, variant="stockham")
    fft_sp = build_space(fft_wl)
    fft_res = ExhaustiveSearch().tune(fft_sp, _obj())
    foreign = TaskHistory(fft_wl, [c for c, _ in fft_res.history],
                          [t for _, t in fft_res.history])

    sp = build_space(scan_wl)
    clean = TransferBayesianTuner(seed=3).tune(sp, _obj(), histories=())
    polluted = TransferBayesianTuner(seed=3).tune(sp, _obj(), (foreign,))
    # with the guard the foreign history is filtered out entirely, so the
    # search is trajectory-identical to the history-free run
    assert polluted.best_config == clean.best_config
    assert [c for c, _ in polluted.history] == [c for c, _ in clean.history]


def test_same_family_history_does_transfer():
    """Control for the guard test: a scan history DOES change the search
    bootstrap (otherwise the guard could pass by ignoring everything)."""
    wl = Workload(op="scan", n=512, batch=2**17, variant="lf")
    src_wl = Workload(op="scan", n=256, batch=2**18, variant="lf")
    src_sp = build_space(src_wl)
    src = ExhaustiveSearch().tune(src_sp, _obj())
    hist = TaskHistory(src_wl, [c for c, _ in src.history],
                       [t for _, t in src.history])
    sp = build_space(wl)
    cold = TransferBayesianTuner(seed=3).tune(sp, _obj(), histories=())
    warm = TransferBayesianTuner(seed=3).tune(sp, _obj(), (hist,))
    assert [c for c, _ in warm.history] != [c for c, _ in cold.history]


# ---------------------------------------------------------------------------
# Cross-device seeding (journals from device A warm-start device B)
# ---------------------------------------------------------------------------

def _journal_tpu_sweep(journal_dir, wl):
    ExhaustiveSearch(journal_dir=str(journal_dir)).tune(
        build_space(wl, TPU_V5E), CostModelObjective(TPU_V5E))


def test_journal_history_reweights_by_profile_distance(tmp_path):
    import os

    wl = Workload(op="scan", n=256, batch=2**18, variant="lf")
    _journal_tpu_sweep(tmp_path, wl)
    path = os.path.join(str(tmp_path), os.listdir(str(tmp_path))[0])

    got = journal_history(path, GPU_SM)
    assert got is not None
    hist, w = got
    assert hist.workload.key == wl.key
    assert 0.0 < w < 1.0
    # times are flattened slowdowns: best == 1.0, spread shrunk by w
    assert min(hist.times) == 1.0
    assert all(t >= 1.0 for t in hist.times)

    # a journal measured on the target itself has nothing to transfer
    assert journal_history(path, TPU_V5E) is None


def test_device_histories_scopes_to_workload(tmp_path):
    wl = Workload(op="scan", n=256, batch=2**18, variant="lf")
    other = Workload(op="scan", n=512, batch=2**17, variant="lf")
    _journal_tpu_sweep(tmp_path, wl)
    _journal_tpu_sweep(tmp_path, other)

    hists = device_histories(str(tmp_path), wl, GPU_SM)
    assert len(hists) == 1 and hists[0].workload.key == wl.key
    assert device_histories(str(tmp_path), wl, TPU_V5E) == []


def test_transfer_strategy_warm_start_finds_optimum_faster(tmp_path):
    wl = Workload(op="scan", n=256, batch=2**18, variant="lf")
    _journal_tpu_sweep(tmp_path, wl)

    sp = build_space(wl, GPU_SM)
    best = ExhaustiveSearch().tune(sp, CostModelObjective(GPU_SM)).best_time

    warm = transfer_strategy(sp, CachedObjective(CostModelObjective(GPU_SM)),
                             seed=0, journal_dir=str(tmp_path))
    assert sp.is_valid(warm.best_config)
    # the cross-device ranking transfers: the very first warm evaluations
    # land on (near-)optimal configs
    first = [t for _, t in warm.history[:2]]
    assert min(first) <= best * 1.05


def test_transfer_seed_populates_session_db(tmp_path):
    from repro.tuning.session import TunerSession

    wl = Workload(op="scan", n=256, batch=2**18, variant="lf")
    _journal_tpu_sweep(tmp_path / "journals", wl)

    session = TunerSession(db_path=str(tmp_path / "db.json"),
                           platform="gpu_sm")
    out = transfer_seed(session, [str(tmp_path / "journals")])
    assert wl.key in out
    stored = session.db.lookup(wl)
    assert stored == dict(out[wl.key].best_config)
    entry = session.db.entries()[f"gpu_sm|{wl.key}"]
    assert entry["method"] == "transfer" and entry["profile"] == "gpu_sm"

    # a tpu session sees nothing: the journals ARE tpu_v5e measurements
    tpu = TunerSession(db_path=str(tmp_path / "db2.json"),
                       platform="tpu_v5e")
    assert transfer_seed(tpu, [str(tmp_path / "journals")]) == {}
