"""gemma-2b: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000, GeGLU,
head_dim=256 [arXiv:2403.08295]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, activation="geglu", tie_embeddings=True,
))
