"""``strategy="ml"`` — learned config prediction as a first-class strategy.

Ranks every valid candidate with the trained forest and returns the argmin
in **zero objective evaluations** — the ML twin of the analytical
methodology's zero-evaluation online answer, but learned from offline
measurements instead of derived from architectural rules.

Fallback ladder (the registry's resolution order):

  1. **ml** — a model artifact exists, has a forest for this op, and the
     per-tree disagreement at the winning candidate is below the
     confidence gate;
  2. **analytical** — no artifact / no forest for the op / low confidence:
     defer to the expert model (one objective evaluation, same contract as
     the registered ``analytical`` strategy);
  3. **default** — the analytical path itself degrades to the generic
     space-wide argmax of the guideline score, which always produces a
     valid config.

``TuneResult.stopped_by`` records which rung answered ("ml",
"ml-defer-analytical", "ml-fallback:no-model",
"ml-fallback:no-forest:<op>", "ml-fallback:low-confidence"), so callers
and tests can assert the ladder.

The *choice* is always evaluation-free (``choose`` never touches an
objective).  ``tune`` then measures the single chosen config so that
``TuneResult.best_time`` — and anything persisted to the TuningDB by
``TunerSession.tune`` — is a real time in seconds, never a unitless
predicted score.  ``evaluations`` stays 0, matching the ``analytical``
strategy's convention: it counts *search* evaluations, and the ranking
consumed none.
"""
from __future__ import annotations

import os
import threading
from typing import Optional, Tuple

import numpy as np

from repro.core.analytical import AnalyticalTuner
from repro.core.bayesian import TuneResult
from repro.core.objective import Objective
from repro.core.space import SearchSpace
from repro.tuning.ml.features import FEATURE_NAMES, featurize_batch
from repro.tuning.ml.forest import ModelArtifactError, ModelBundle

ANA_RANK_COL = FEATURE_NAMES.index("ana_rank_pct")

# repo-relative artifact location used when $REPRO_ML_MODEL is unset
DEFAULT_MODEL_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                  "..", "artifacts", "ml_model.npz")


def default_model_path() -> str:
    """Artifact path honoring $REPRO_ML_MODEL *at call time* (a process
    that retargets the env var after import gets the new artifact from
    every entry point, not just ``default_strategy``)."""
    return os.path.abspath(os.environ.get("REPRO_ML_MODEL",
                                          DEFAULT_MODEL_PATH))

# per-tree std (log-slowdown units) above which the forest's answer is
# considered a guess; exp(0.4) ~ 1.5x disagreement between trees
DEFAULT_MAX_STD = 0.4

# If the analytical suggestion is predicted within this log-slowdown of the
# learned optimum (~2%), defer to it: near the top the forest's residual
# error exceeds the true config-to-config gaps, and the expert ordering is
# the more reliable discriminator in that band (and the more explainable
# choice). Outside the band, the learned ranking overrides the expert.
DEFAULT_DEFER_EPS = 0.02


class MLStrategy:
    """Learned candidate ranking with graceful analytical fallback."""

    name = "ml"

    def __init__(self, model: Optional[ModelBundle] = None, *,
                 model_path: Optional[str] = None,
                 max_std: float = DEFAULT_MAX_STD,
                 defer_eps: float = DEFAULT_DEFER_EPS):
        self._model = model
        self._model_path = os.path.abspath(model_path) if model_path else None
        self.max_std = max_std
        self.defer_eps = defer_eps
        self._load_attempted = model is not None
        self._analytical = AnalyticalTuner()

    @property
    def model_path(self) -> str:
        return self._model_path or default_model_path()

    # -- model loading -------------------------------------------------------

    @property
    def model(self) -> Optional[ModelBundle]:
        if not self._load_attempted:
            self._load_attempted = True
            try:
                self._model = ModelBundle.load(self.model_path)
            except ModelArtifactError:
                self._model = None
        return self._model

    # -- prediction ----------------------------------------------------------

    def predict(self, space: SearchSpace, cfgs,
                X: Optional[np.ndarray] = None
                ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """(mean, per-tree std) of log-slowdown; None when un-modeled.

        Pass ``X`` (rows from :func:`featurize_batch` over the same
        ``cfgs``) to reuse an already-computed feature matrix.
        """
        bundle = self.model
        if bundle is None:
            return None
        forest = bundle.forest_for(space.workload.op)
        if forest is None:
            return None
        if X is None:
            X = featurize_batch(space, cfgs)
        return forest.predict(X)

    def _analytical_index(self, space: SearchSpace, cfgs,
                          X: Optional[np.ndarray]) -> int:
        """Index of the analytical suggestion among ``cfgs``.

        With a feature matrix in hand the answer is free: the candidate
        whose ``ana_rank_pct`` is 1.0 is exactly the guideline's argmax.
        """
        if X is not None and len(X):
            return int(np.argmax(X[:, ANA_RANK_COL]))
        return cfgs.index(self._analytical.suggest(space))

    def choose(self, space: SearchSpace, cfgs,
               X: Optional[np.ndarray] = None,
               pred: Optional[Tuple[np.ndarray, np.ndarray]] = None
               ) -> Tuple[int, str]:
        """(index of the chosen candidate, which rung chose it).

        The deployed decision rule — evaluation-free, fallbacks included —
        shared with ``evaluate_model`` so the reported accuracy is the
        accuracy of what actually ships: predicted-argmin, except the
        analytical suggestion wins when its prediction sits within
        ``defer_eps`` of the learned optimum, and the analytical choice
        answers outright when no model/forest exists or the per-tree
        disagreement exceeds ``max_std``.
        """
        if not cfgs:
            raise ValueError(f"empty search space for {space.workload.key}")
        if self.model is None:
            return self._analytical_index(space, cfgs, X), \
                "ml-fallback:no-model"
        if self.model.forest_for(space.workload.op) is None:
            return self._analytical_index(space, cfgs, X), \
                f"ml-fallback:no-forest:{space.workload.op}"
        if X is None:
            X = featurize_batch(space, cfgs)
        mean, std = pred if pred is not None else self.predict(space, cfgs, X)
        best = int(np.argmin(mean))
        ana = self._analytical_index(space, cfgs, X)
        if float(std[best]) > self.max_std:
            return ana, "ml-fallback:low-confidence"
        if float(mean[ana]) <= float(mean[best]) + self.defer_eps:
            return ana, "ml-defer-analytical"
        return best, "ml"

    # -- strategy entry point (registry signature) ---------------------------

    def tune(self, space: SearchSpace, objective: Objective, *,
             seed: int = 0, max_evals: int = 0) -> TuneResult:
        cfgs = space.enumerate_valid()
        chosen, rung = self.choose(space, cfgs)
        # one real measurement of the winner so best_time (and whatever the
        # session persists) is seconds, not a relative predicted score;
        # evaluations stays 0 — the search consumed none (same convention
        # as the analytical strategy)
        m = objective(space, cfgs[chosen])
        cfg = dict(cfgs[chosen])
        return TuneResult(cfg, m.time_s, 0, [(cfg, m.time_s)], rung)

    __call__ = tune


# ---------------------------------------------------------------------------
# Default (process-wide) strategy — what strategy="ml" resolves to
# ---------------------------------------------------------------------------
# Cached per (path, mtime, size) so a retrained artifact is picked up
# without restarting, while steady-state calls skip the disk entirely.

_DEFAULT_LOCK = threading.Lock()
_DEFAULT: Tuple[Optional[Tuple], Optional[MLStrategy]] = (None, None)


def _artifact_token(path: str) -> Optional[Tuple]:
    try:
        st = os.stat(path)
        return (path, st.st_mtime_ns, st.st_size)
    except OSError:
        return (path,)


def default_strategy() -> MLStrategy:
    global _DEFAULT
    path = default_model_path()
    token = _artifact_token(path)
    with _DEFAULT_LOCK:
        cached_token, cached = _DEFAULT
        if cached is not None and cached_token == token:
            return cached
        strategy = MLStrategy(model_path=path)
        _DEFAULT = (token, strategy)
        return strategy
