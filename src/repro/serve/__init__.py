"""repro.serve — throughput-first continuous-batching serving.

:class:`ServeEngine` is the production path: single-dispatch batched
prefill, a donated on-device decode loop, and budgeted deque admission
(docs/serving.md).  :class:`ReferenceEngine` preserves the per-token
replay baseline the engine is differentially tested and benchmarked
against; :mod:`repro.serve.trace` generates the seeded multi-tenant
request streams the serving benchmark gates on.
"""
from repro.serve.engine import (FINISH_LENGTH, FINISH_STOP, Request,
                                ServeEngine, StepRecord)
from repro.serve.reference import ReferenceEngine
from repro.serve.trace import (TenantSpec, TraceRequest, default_tenants,
                               synthetic_trace, trace_summary)

__all__ = [
    "FINISH_LENGTH", "FINISH_STOP", "Request", "ServeEngine", "StepRecord",
    "ReferenceEngine", "TenantSpec", "TraceRequest", "default_tenants",
    "synthetic_trace", "trace_summary",
]
