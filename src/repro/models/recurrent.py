"""RecurrentGemma recurrent block: conv1d + RG-LRU (tuned linrec scan)."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.scan.ops import linear_recurrence
from repro.models.layers import causal_conv1d, dense, init_dense

_C = 8.0  # RG-LRU decay sharpness (Griffin)


def init_recurrent_block(key, cfg: ModelConfig, dtype) -> Dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "wx": init_dense(ks[0], d, w, dtype),
        "wy": init_dense(ks[1], d, w, dtype),          # gate branch
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
                   * (1.0 / math.sqrt(cfg.conv_width))).astype(dtype),
        "wa": init_dense(ks[3], w, w, dtype),          # recurrence gate
        "wi": init_dense(ks[4], w, w, dtype),          # input gate
        "lambda": (jnp.log(jnp.expm1(
            jnp.linspace(0.9, 0.999, w) ** (-1.0 / _C) - 1.0))
        ).astype(jnp.float32),                         # softplus^-1 param
        "wo": init_dense(ks[5], w, d, dtype),
    }


def recurrent_block(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                    cache: Optional[Dict] = None,
                    compute_dtype=jnp.bfloat16
                    ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, L, D). cache: {"conv": (B,K-1,W), "state": (B,W)}."""
    bsz, L, _ = x.shape
    u = dense(p["wx"], x, compute_dtype)
    gate = jax.nn.gelu(dense(p["wy"], x, compute_dtype), approximate=True)
    u, conv_cache = causal_conv1d(
        u, p["conv_w"].astype(compute_dtype),
        cache=None if cache is None else cache["conv"])

    r = jax.nn.sigmoid(dense(p["wa"], u, jnp.float32))
    i = jax.nn.sigmoid(dense(p["wi"], u, jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"])[None, None, :] * r
    a = jnp.exp(log_a)                                          # (B, L, W)
    gated = i * u.astype(jnp.float32)
    # 1 - a^2 = -expm1(2 log_a): exact and grad-stable as a -> 1
    b = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * gated

    if cache is None or L > 1:
        W = a.shape[-1]
        a_rows = jnp.transpose(a, (0, 2, 1)).reshape(bsz * W, L)
        b_rows = jnp.transpose(b, (0, 2, 1)).reshape(bsz * W, L)
        h = linear_recurrence(a_rows, b_rows,
                              use_pallas=cfg.use_pallas or None)
        h = jnp.transpose(h.reshape(bsz, W, L), (0, 2, 1))
        new_state = h[:, -1]
    else:
        h = a[:, 0] * cache["state"] + b[:, 0]                  # (B, W)
        new_state = h
        h = h[:, None]

    y = dense(p["wo"], h.astype(compute_dtype) * gate, compute_dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": conv_cache.astype(cache["conv"].dtype),
                     "state": new_state}
    return y, new_cache


def init_recurrent_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
            "state": jnp.zeros((batch, w), jnp.float32)}
