"""Objective functions mapping (workload, config) -> execution time (seconds).

Mirrors the paper's measurement protocol:
  - repeated executions, median taken (paper: 100 runs to damp run-to-run
    variability; we default lower for CPU-host practicality, configurable);
  - invalid configurations or configurations exceeding a timeout are clamped
    to a large penalty value (paper §IV-B);
  - the objective is a black box to the ML-based search.

Two families:
  * WallClockObjective  — genuinely times a compiled callable on this host.
  * TPUCostModelObjective — a v5e timing model (DESIGN.md §2) used as the
    offline-tuning "device". It intentionally models more mechanisms (DMA
    ramp, issue pipelines, pass overheads, mixed-radix penalties) than the
    analytical guideline consumes, so analytical-vs-BO comparisons on it are
    meaningful.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import time
from typing import Callable, Dict, Optional

from repro.core.space import Config, SearchSpace, Workload
from repro.hw.tpu import (
    V5E,
    TpuSpec,
    dma_efficiency,
    effective_element_bytes,
    ilp_factor,
    lane_utilization,
    sublane_utilization,
)

PENALTY_TIME = 60.0  # seconds — the paper's 1-minute clamp


@dataclasses.dataclass
class Measurement:
    time_s: float
    valid: bool
    meta: Dict[str, float] = dataclasses.field(default_factory=dict)


class Objective:
    """Black-box objective: lower is better."""

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        raise NotImplementedError


class WallClockObjective(Objective):
    """Times `runner(workload, config) -> callable()` on the host.

    runner builds (and jits) the kernel for the config; the returned thunk is
    executed `reps` times and the median is reported. Exceptions or invalid
    configs yield the penalty clamp.
    """

    def __init__(self, runner: Callable[[Workload, Config], Callable[[], None]],
                 reps: int = 5, warmup: int = 1, timeout_s: float = PENALTY_TIME):
        self.runner = runner
        self.reps = reps
        self.warmup = warmup
        self.timeout_s = timeout_s

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        if not space.is_valid(cfg):
            return Measurement(PENALTY_TIME, False)
        try:
            thunk = self.runner(space.workload, cfg)
            for _ in range(self.warmup):
                thunk()
            times = []
            for _ in range(self.reps):
                t0 = time.perf_counter()
                thunk()
                dt = time.perf_counter() - t0
                times.append(dt)
                if dt > self.timeout_s:
                    return Measurement(PENALTY_TIME, False)
            times.sort()
            return Measurement(times[len(times) // 2], True)
        except Exception:
            return Measurement(PENALTY_TIME, False)


def _flops_and_passes(wl: Workload, cfg: Config) -> Dict[str, float]:
    """Operation-specific work model for the cost objective."""
    n = wl.n
    tile_n = cfg.get("tile_n", n)
    r = cfg.get("radix", 2)
    out: Dict[str, float] = {}
    def mixed(tile: int, radix: int) -> float:
        # ragged final circuit level when radix^k != tile: extra low-radix
        # step + sync (paper's WM jagged-performance observation)
        k = round(math.log(max(tile, 2), radix)) if radix > 1 else 1
        return 0.0 if radix**k == tile else 1.0

    if wl.op in ("scan", "ssd", "rglru"):
        steps = math.ceil(math.log(max(tile_n, 2), r))
        # Kogge-Stone does N work per step; Ladner-Fischer ~2N total but more
        # steps of structure; model KS-like: n ops/step, radix-r node = r-1 adds
        out["flops"] = steps * n * (r - 1) / max(r / 2, 1)
        out["passes"] = math.ceil(math.log(max(n, 2), r) / math.log(max(tile_n, 2), r)) if tile_n < n else 1
        out["steps"] = steps
        out["mixed_radix"] = mixed(tile_n, r)
    elif wl.op == "tridiag":
        steps = math.ceil(math.log2(max(n, 2))) if wl.variant in ("cr", "pcr") else math.ceil(math.log(max(n, 2), r))
        per_step = 14 if wl.variant == "pcr" else 9  # PCR full-width; CR halves
        work_n = n if wl.variant == "pcr" else 2 * n
        out["flops"] = steps * work_n * per_step / max(math.log2(r), 1)
        out["passes"] = 1
        out["steps"] = steps
        out["mixed_radix"] = mixed(tile_n, r) if wl.variant == "wm" else 0.0
    elif wl.op in ("fft", "large_fft"):
        # radix-r Stockham: log_r(N) stages, each stage ~5N flops equivalent
        stages_total = math.log(max(n, 2), r)
        out["flops"] = 5.0 * n * math.log2(max(n, 2))  # canonical 5NlogN
        s = math.log(max(tile_n, 2), r)
        out["passes"] = max(1, math.ceil(stages_total / max(s, 1)))
        out["steps"] = math.ceil(stages_total)
        # mixed-radix penalty (paper Fig 5 jagged line): if r^k != tile_n an
        # extra lower-radix step is required
        k = round(math.log(tile_n, r))
        out["mixed_radix"] = 0.0 if r ** k == tile_n else 1.0
    elif wl.op == "attention":
        head_dim = 128
        out["flops"] = 4.0 * n * head_dim  # per q-row, per kv token: 2 matmuls
        out["passes"] = 1
        out["steps"] = max(n // cfg.get("block_k", 128), 1)
    elif wl.op == "matmul":
        out["flops"] = 2.0 * n * n  # per row of M
        out["passes"] = 1
        out["steps"] = max(n // cfg.get("block_k", 128), 1)
    else:
        out["flops"] = float(n)
        out["passes"] = 1
        out["steps"] = 1
    out.setdefault("mixed_radix", 0.0)
    return out


class TPUCostModelObjective(Objective):
    """Deterministic v5e timing model (+ optional hash-seeded jitter).

    t = passes * [ launch + max(t_compute, t_memory)/overlap + steps*sync ]

    with: t_memory from bytes moved through the DMA ramp; t_compute from VPU
    issue with lane/sublane utilization and ILP factors; overlap in (0.5,1]
    grows with grid depth (needs >=2 programs in flight to double-buffer).
    """

    def __init__(self, spec: TpuSpec = V5E, noise: float = 0.0):
        self.spec = spec
        self.noise = noise

    def _jitter(self, wl: Workload, cfg: Config) -> float:
        if not self.noise:
            return 1.0
        key = f"{wl.key}|{sorted(cfg.items())}".encode()
        h = int.from_bytes(hashlib.sha256(key).digest()[:8], "big")
        u = (h / 2**64) * 2.0 - 1.0  # [-1, 1)
        return 1.0 + self.noise * u

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        if not space.is_valid(cfg):
            return Measurement(PENALTY_TIME, False)
        wl, spec = space.workload, self.spec
        # tridiag: 4 coefficients per equation; fft: interleaved complex
        eb = effective_element_bytes(wl.op, wl.dtype)

        work = _flops_and_passes(wl, cfg)
        batch = max(wl.batch, 1)
        rows = cfg.get("rows_per_program", 1)
        tile_n = cfg.get("tile_n", wl.n)

        if wl.op == "attention":
            block_q, block_k = cfg["block_q"], cfg["block_k"]
            grid = max(batch, 1) * max(wl.n // block_q, 1)
            block_bytes = (block_q + 2 * block_k) * 128 * eb
            total_bytes = batch * wl.n * 128 * eb * 3
            total_flops = batch * wl.n * work["flops"]
            trailing = block_k
        elif wl.op == "matmul":
            bm, bn, bk = cfg["block_m"], cfg["block_n"], cfg["block_k"]
            grid = max(batch // bm, 1) * max(wl.n // bn, 1)
            block_bytes = (bm * bk + bk * bn) * eb
            total_bytes = (batch * wl.n + wl.n * wl.n) * eb
            total_flops = batch * work["flops"]
            trailing = bn
        else:
            grid = max(batch // rows, 1) * max(wl.n // tile_n, 1)
            block_bytes = rows * tile_n * eb
            total_bytes = 2.0 * batch * wl.n * eb * work["passes"]
            total_flops = batch * work["flops"]
            trailing = min(tile_n, spec.lane_count * 8) if not cfg.get("in_register") else tile_n

        # --- memory term ---
        t_mem = total_bytes / (spec.hbm_bandwidth * dma_efficiency(int(block_bytes), spec))
        # --- compute term (VPU for prefix ops; MXU for matmul/attention) ---
        if wl.op in ("matmul", "attention"):
            peak = spec.peak_bf16_flops if wl.dtype == "bfloat16" else spec.peak_f32_flops
            mxu_util = min(trailing / spec.mxu_dim, 1.0)
            t_comp = total_flops / (peak * max(mxu_util, 1e-3))
        else:
            util = lane_utilization(trailing, spec)
            sub = sublane_utilization(rows * max(tile_n // spec.lane_count, 1), spec)
            eff = max(util * max(sub, 0.25) * ilp_factor(cfg.get("unroll", 1)), 1e-3)
            t_comp = total_flops / (spec.peak_vpu_flops * eff)
            if cfg.get("in_register"):
                t_comp *= 0.8   # no scratch roundtrip between steps
            else:
                t_comp *= 1.0 + 0.05 * work["steps"]  # scratch traffic per step

        # --- overlap: need >=2 programs in flight (occupancy premise) ---
        overlap = 1.0 if grid >= 4 else (0.85 if grid >= 2 else 0.55)
        t_body = max(t_comp, t_mem) / overlap + (1.0 - overlap) * min(t_comp, t_mem) * 0.1
        passes = work["passes"]
        t = passes * (spec.kernel_launch_s + t_body / passes + work["steps"] / passes * spec.pass_sync_s)
        t *= 1.0 + 0.25 * work.get("mixed_radix", 0.0)
        t *= self._jitter(wl, cfg)
        return Measurement(
            t, True,
            meta={"t_comp": t_comp, "t_mem": t_mem, "grid": grid,
                  "passes": passes, "flops": total_flops, "bytes": total_bytes},
        )


class CachedObjective(Objective):
    """Memoizes measurements — searches may revisit configs."""

    def __init__(self, inner: Objective):
        self.inner = inner
        self.cache: Dict[str, Measurement] = {}
        self.evaluations = 0   # counts *unique* real evaluations (paper Fig 4)

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        key = f"{space.workload.key}|{tuple(sorted(cfg.items()))}"
        if key not in self.cache:
            self.cache[key] = self.inner(space, cfg)
            self.evaluations += 1
        return self.cache[key]
