"""Vectorized, resumable exhaustive sweeps (the Phi-denominator engine).

Exhaustive search is the load-bearing wall of the paper's evaluation: it
supplies the optimum every other methodology is scored against, and the
dense (config, time) pairs the ML predictor trains on.  This module
replaces the seed's serial per-config Python loop with:

  * **batched evaluation** — the whole candidate set goes through
    ``Objective.batch_eval`` (a handful of numpy array ops on the cost
    model) instead of thousands of Python calls;
  * **a resumable journal** — one JSONL file per (workload, objective)
    with atomic line appends, so a long wall-clock sweep survives
    interruption and a re-run only evaluates what is missing;
  * **metric-vector journaling + Pareto fronts** — entries record the full
    metric vector (time/energy/peak-VMEM), the sweep maintains the
    non-dominated set per (workload, objective), and a :class:`Policy`
    picks the winner from the front — one sweep serves every policy;
  * **analytical-dominance pruning** — ``prune="analytical"`` keeps the
    top-k candidates ranked by the zero-evaluation expert model (the
    model-steered pruning lever of Schoonhoven et al.), recording how many
    candidates were dropped.  Pruning is latency-ranked, so combining it
    with a non-latency policy raises rather than silently searching the
    wrong subset.

``run_sweep`` is what ``ExhaustiveSearch.tune`` (and therefore
``strategy="exhaustive"``) executes; ``repro.tuning.ml.dataset`` consumes
the same journals directly as training rows.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.bayesian import TuneResult
from repro.core.objective import METRIC_TIME, Objective
from repro.core.policy import (Policy, get_policy, pareto_front,
                               policy_scalar_cols)
from repro.core.space import Config, SearchSpace, Workload

# v3 adds the per-entry metric vector ("m": {metric: value}); v2 added the
# hardware-profile name to the header. Older journals stay readable — their
# entries load as time_s-only vectors, and the objective-signature check
# already rejects cross-profile resumption, since the profile name is
# embedded in every cost-model signature.
JOURNAL_VERSION = 3

# default kept-set size for prune="analytical"; expensive objectives can
# pass an explicit top_k
DEFAULT_TOP_K = 64

# the journal header contract, flattened (nested workload fields appear as
# "workload.<field>").  ``repro.analysis`` fingerprints this list against
# JOURNAL_VERSION: changing the header layout without bumping the version
# silently orphans every journal on disk, so the lint gate catches it.
HEADER_FIELDS = ("kind", "version", "workload.key", "workload.op",
                 "workload.n", "workload.batch", "workload.dtype",
                 "workload.variant", "objective", "profile", "space_size",
                 "pruned")


def make_header(wl: Workload, objective: Objective, space_size: int,
                pruned: int = 0) -> Dict:
    """The version-stamped journal header record (one per journal file).

    The single construction site for the ``HEADER_FIELDS`` contract;
    ``space_size`` is the FULL valid-space size — a pruned sweep records
    how much it dropped so journal consumers (dataset export) can tell
    "complete enumeration" from "model-steered subset".
    """
    return {"kind": "header", "version": JOURNAL_VERSION,
            "workload": {"key": wl.key, "op": wl.op, "n": wl.n,
                         "batch": wl.batch, "dtype": wl.dtype,
                         "variant": wl.variant},
            "objective": objective.signature(),
            # device the times were measured on (None for objectives
            # that carry no hardware model, e.g. wallclock runners)
            "profile": getattr(getattr(objective, "spec", None),
                               "name", None),
            "space_size": space_size,
            "pruned": int(pruned)}


def append_journal_lines(path: str, lines) -> None:
    """Crash-tolerant JSONL append: the one sanctioned way to extend a
    journal or trace file.

    The whole payload goes through a single ``os.write`` on an
    ``O_APPEND`` descriptor, so concurrent writers never interleave
    mid-line and a killed writer leaves at most one torn trailing line —
    which every loader skips.  If a previous writer died mid-line, the
    torn tail is terminated first so none of this payload's records are
    glued onto it.
    """
    payload = "".join(line + "\n" for line in lines).encode()
    if not payload:
        return
    if _tail_torn(path):
        # appending directly would glue our first record onto the torn
        # bytes and lose BOTH lines to the json parse
        payload = b"\n" + payload
    fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
    try:
        os.write(fd, payload)
    finally:
        os.close(fd)


def _tail_torn(path: str) -> bool:
    """True when the file ends mid-line (a writer was killed inside its
    os.write) — the next append must not extend that line."""
    try:
        with open(path, "rb") as f:
            f.seek(-1, os.SEEK_END)
            return f.read(1) != b"\n"
    except (OSError, ValueError):   # absent or empty file
        return False


def config_key(cfg: Config) -> str:
    """Canonical, order-independent identity of a config inside one space."""
    return ",".join(f"{k}={cfg[k]}" for k in sorted(cfg))


def _safe(token: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.=-]+", "_", token)


def journal_path(journal_dir: str, wl: Workload, objective: Objective) -> str:
    """Per-(workload, objective) journal file inside ``journal_dir``."""
    return os.path.join(journal_dir,
                        f"{_safe(wl.key)}__{_safe(objective.signature())}.jsonl")


class SweepJournal:
    """Append-only JSONL checkpoint for one (workload, objective) sweep.

    Line 1 is a header carrying the workload fields and the objective
    signature; every subsequent line is one completed evaluation.  Appends
    go through a single ``os.write`` on an ``O_APPEND`` descriptor per
    chunk, so a killed sweep leaves at most one torn trailing line — which
    ``load`` skips — and concurrent writers never interleave mid-line.
    """

    def __init__(self, path: str):
        self.path = path

    @classmethod
    def for_workload(cls, journal_dir: str, wl: Workload,
                     objective: Objective) -> "SweepJournal":
        os.makedirs(journal_dir, exist_ok=True)
        return cls(journal_path(journal_dir, wl, objective))

    # -- reading ------------------------------------------------------------

    def load(self, wl: Optional[Workload] = None,
             objective: Optional[Objective] = None) -> Dict[str, float]:
        """Completed {config_key: time_s}; {} when the journal is absent.

        When ``wl``/``objective`` are given, a header that does not match
        raises — silently resuming someone else's numbers would corrupt
        the optimum.
        """
        return {k: vec[METRIC_TIME]
                for k, vec in self.load_metrics(wl, objective).items()}

    def load_metrics(self, wl: Optional[Workload] = None,
                     objective: Optional[Objective] = None
                     ) -> Dict[str, Dict[str, float]]:
        """Completed {config_key: metric vector}; {} when absent.

        Version-3 entries carry their vector in ``"m"``; older entries
        (and v3 entries from time-only objectives) load as
        ``{"time_s": t}`` — the documented migration for pre-vector
        journals.  Header validation matches ``load``.
        """
        if not os.path.exists(self.path):
            return {}
        done: Dict[str, Dict[str, float]] = {}
        header_ok = False
        with open(self.path, "r") as f:
            for i, line in enumerate(f):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue     # torn trailing line from a killed run
                if not isinstance(rec, dict):
                    continue     # parseable but not a record (e.g. "123")
                if i == 0 and rec.get("kind") == "header":
                    self._check_header(rec, wl, objective)
                    header_ok = True
                    continue
                if "k" in rec and "t" in rec:
                    vec = {n: float(v) for n, v in rec["m"].items()} \
                        if isinstance(rec.get("m"), dict) else {}
                    vec[METRIC_TIME] = float(rec["t"])
                    done[rec["k"]] = vec
        if not header_ok and (wl is not None or objective is not None):
            # a torn/missing header means the entries cannot be validated
            # against this (workload, objective) — never resume them.
            # Quarantine the bytes and let the sweep start a fresh journal.
            self._quarantine()
            return {}
        return done

    def read_header(self) -> Optional[Dict]:
        if not os.path.exists(self.path):
            return None
        with open(self.path, "r") as f:
            first = f.readline().strip()
        if not first:
            return None
        try:
            rec = json.loads(first)
        except json.JSONDecodeError:
            return None
        return rec if isinstance(rec, dict) and rec.get("kind") == "header" \
            else None

    def entries(self) -> List[Tuple[Config, float]]:
        """Completed (config, time) pairs, first-completion order.

        Deduplicated by config (last line wins, matching ``load``):
        concurrent writers that both loaded before either appended can
        legally write the same config twice.
        """
        return [(cfg, vec[METRIC_TIME]) for cfg, vec in self.metric_entries()]

    def metric_entries(self) -> List[Tuple[Config, Dict[str, float]]]:
        """Completed (config, metric-vector) pairs, first-completion order.

        Same dedup semantics as ``entries``; pre-v3 entries come back as
        ``time_s``-only vectors.
        """
        if not os.path.exists(self.path):
            return []
        seen: Dict[str, int] = {}
        out: List[Tuple[Config, Dict[str, float]]] = []
        with open(self.path, "r") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if not isinstance(rec, dict) or rec.get("kind") == "header" \
                        or "cfg" not in rec:
                    continue
                cfg = {k: int(v) for k, v in rec["cfg"].items()}
                key = config_key(cfg)
                vec = {n: float(v) for n, v in rec["m"].items()} \
                    if isinstance(rec.get("m"), dict) else {}
                vec[METRIC_TIME] = float(rec["t"])
                pair = (cfg, vec)
                if key in seen:
                    out[seen[key]] = pair
                else:
                    seen[key] = len(out)
                    out.append(pair)
        return out

    @staticmethod
    def _check_header(rec: Dict, wl: Optional[Workload],
                      objective: Optional[Objective]) -> None:
        if wl is not None and rec.get("workload", {}).get("key") != wl.key:
            raise ValueError(
                f"sweep journal is for workload "
                f"{rec.get('workload', {}).get('key')!r}, not {wl.key!r}")
        if objective is not None and rec.get("objective") != objective.signature():
            raise ValueError(
                f"sweep journal was measured with objective "
                f"{rec.get('objective')!r}, not {objective.signature()!r}")
        if objective is not None and rec.get("profile") is not None:
            want = getattr(getattr(objective, "spec", None), "name", None)
            if want is not None and rec["profile"] != want:
                raise ValueError(
                    f"sweep journal was measured on profile "
                    f"{rec.get('profile')!r}, not {want!r}")

    # -- writing ------------------------------------------------------------

    def _quarantine(self) -> None:
        """Set a corrupt journal aside (bytes preserved for post-mortem)."""
        target = self.path + ".corrupt"
        try:
            os.replace(self.path, target)
        except OSError:
            os.unlink(self.path)

    def _ensure_header(self, wl: Workload, objective: Objective,
                       space_size: int, pruned: int = 0) -> None:
        if os.path.exists(self.path) and os.path.getsize(self.path):
            if self.read_header() is not None:
                return
            # non-empty but headerless (e.g. the very first os.write was
            # torn): unusable — quarantine and re-journal from scratch
            self._quarantine()
        header = make_header(wl, objective, space_size, pruned)
        self._append_lines([json.dumps(header, sort_keys=True)])

    def append(self, wl: Workload, objective: Objective, space_size: int,
               entries: Sequence[Tuple],
               pruned: int = 0) -> None:
        """Append completed evaluations: ``(config, time)`` pairs, or
        ``(config, time, metric_vector)`` triples (the vector is written as
        ``"m"`` minus the redundant ``time_s`` mirror)."""
        self._ensure_header(wl, objective, space_size, pruned)
        self._append_lines(self._entry_line(*entry) for entry in entries)

    @staticmethod
    def _entry_line(cfg: Config, t: float, metrics=None) -> str:
        rec = {"k": config_key(cfg), "cfg": cfg, "t": float(t)}
        vec = {n: float(v) for n, v in (metrics or {}).items()
               if n != METRIC_TIME}
        if vec:
            rec["m"] = vec
        return json.dumps(rec, sort_keys=True)

    def _append_lines(self, lines) -> None:
        append_journal_lines(self.path, lines)


# ---------------------------------------------------------------------------
# Pruning
# ---------------------------------------------------------------------------

def prune_candidates(space: SearchSpace, cands: List[Config],
                     top_k: int) -> Tuple[List[Config], int]:
    """Keep the ``top_k`` analytically-ranked candidates, enumeration order.

    The expert model ranks for free (no objective evaluations); measuring
    only its favourites is the Prajapati-style "rank before you measure"
    lever for objectives where every evaluation is minutes of wall clock.
    """
    if top_k >= len(cands):
        return cands, 0
    from repro.core.analytical import score
    order = sorted(range(len(cands)),
                   key=lambda i: score(space, cands[i]).key(), reverse=True)
    kept_idx = sorted(order[:top_k])          # preserve enumeration order
    return [cands[i] for i in kept_idx], len(cands) - top_k


# ---------------------------------------------------------------------------
# The sweep
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    best_config: Config
    best_time: float                     # winner's measured seconds
    evaluations: int                     # fresh objective evaluations
    resumed: int                         # configs answered by the journal
    pruned: int                          # candidates dropped before measuring
    total: int                           # candidates actually swept
    history: List[Tuple[Config, float]]  # enumeration order, penalty-clamped
    stopped_by: str                      # "exhausted" | "pruned"
    journal: Optional[str] = None        # journal path, when journaled
    metrics: Optional[Dict[str, np.ndarray]] = None  # columns over history
    pareto: Tuple = ()                   # non-dominated (config, vector)s
    policy: Optional[str] = None         # policy key the winner was picked by
    best_scalar: Optional[float] = None  # winner's policy scalar

    def as_tune_result(self) -> TuneResult:
        # under a policy, the quantity the search minimized (and therefore
        # reports as best/history values) is the policy scalar
        if self.policy is not None and self.metrics is not None:
            pol = get_policy(self.policy)
            scal = policy_scalar_cols(pol, self.metrics)
            history = list(zip((c for c, _ in self.history), scal.tolist()))
            return TuneResult(self.best_config, float(self.best_scalar),
                              self.evaluations + self.resumed, history,
                              self.stopped_by)
        return TuneResult(self.best_config, self.best_time,
                          self.evaluations + self.resumed, self.history,
                          self.stopped_by)


def run_sweep(space: SearchSpace, objective: Objective, *,
              journal: Optional[SweepJournal] = None,
              prune: Optional[str] = None, top_k: Optional[int] = None,
              chunk: int = 1024,
              policy: Union[str, Policy, None] = None) -> SweepResult:
    """Evaluate the (optionally pruned) valid space; resume from ``journal``.

    Evaluation happens in ``chunk``-sized batches through
    ``objective.batch_eval_metrics``; each completed chunk is journaled
    (full metric vectors) before the next starts, so an interrupted sweep
    re-run skips everything already measured and still returns the
    identical winner.  The result carries the Pareto front over the
    objective's metric axes; ``policy`` picks the winner from it (default
    ``latency`` — identical behavior and numbers as the scalar-era sweep).

    Pruning is ranked by the latency-shaped analytical model, so it
    composes only with policies declared ``prune_safe`` — any other
    combination raises instead of optimizing the wrong subset.
    """
    wl = space.workload
    pol = None
    if policy is not None:
        pol = get_policy(policy, getattr(objective, "spec", None))
        if pol.name == "latency":
            pol = None
    if prune is not None and pol is not None and not pol.prune_safe:
        raise ValueError(
            f"prune={prune!r} ranks candidates by latency and cannot vouch "
            f"for policy {pol.key!r}; sweep unpruned and pick from the "
            f"Pareto front instead")
    cands = space.enumerate_valid()
    if not cands:
        raise ValueError(f"empty search space for {wl.key}")
    full_size = len(cands)

    pruned = 0
    if prune is not None:
        if prune != "analytical":
            raise ValueError(f"unknown prune mode {prune!r}; "
                             f"supported: 'analytical'")
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        cands, pruned = prune_candidates(
            space, cands, top_k if top_k is not None else DEFAULT_TOP_K)

    names = objective.metric_names()
    cols = {n: np.full(len(cands), np.nan) for n in names}
    times = cols[METRIC_TIME]
    resumed = 0
    if journal is not None:
        done = journal.load_metrics(wl, objective)
        pending: List[int] = []
        for i, cand in enumerate(cands):
            vec = done.get(config_key(cand)) if done else None
            if vec is None:
                pending.append(i)
            else:
                # axes a pre-vector journal did not record stay NaN; the
                # policy scalarization falls back to time for those rows
                for n in names:
                    if n in vec:
                        cols[n][i] = vec[n]
                resumed += 1
    else:
        pending = list(range(len(cands)))

    chunk = max(int(chunk), 1)
    for lo in range(0, len(pending), chunk):
        idx = pending[lo: lo + chunk]
        mcols = objective.batch_eval_metrics(space, [cands[i] for i in idx],
                                             assume_valid=True)
        for n in names:
            cols[n][idx] = mcols[n]
        if journal is not None:
            journal.append(
                wl, objective, full_size,
                [(cands[i], float(mcols[METRIC_TIME][j]),
                  {n: float(mcols[n][j]) for n in names})
                 for j, i in enumerate(idx)],
                pruned=pruned)

    if pol is not None:
        scal = policy_scalar_cols(pol, cols)
        best_i = int(np.argmin(scal))
        best_scalar = float(scal[best_i])
    else:
        best_i = int(np.argmin(times))
        best_scalar = None
    return SweepResult(
        best_config=cands[best_i],
        best_time=float(times[best_i]),
        evaluations=len(pending),
        resumed=resumed,
        pruned=pruned,
        total=len(cands),
        history=list(zip(cands, times.tolist())),
        stopped_by="pruned" if pruned else "exhausted",
        journal=journal.path if journal is not None else None,
        metrics=cols,
        pareto=_sweep_front(cols, cands, names),
        policy=pol.key if pol is not None else None,
        best_scalar=best_scalar,
    )


def _sweep_front(cols: Dict[str, np.ndarray], cands: List[Config],
                 names: Sequence[str]) -> Tuple:
    """Pareto front over the swept columns; rows with unrecorded axes
    (pre-vector journal resumes) count as worst-possible on those axes."""
    filled = {n: np.nan_to_num(cols[n], nan=np.inf) for n in names}
    filled[METRIC_TIME] = cols[METRIC_TIME]
    return pareto_front(filled, cands, names)
