"""repro — TPU-native auto-tuning framework (Dieguez & Amor 2023 reproduction).

Subpackages: core (tuning methodologies), hw (TPU machine model), kernels
(Pallas TPU kernels), models (architecture zoo), configs, data, optim,
distributed, train, serve, launch.
"""
__version__ = "1.0.0"
