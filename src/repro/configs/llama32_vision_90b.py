"""llama-3.2-vision-90b: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attn image layers every 5th; vision frontend STUB
[hf:meta-llama/Llama-3.2-90B-Vision]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256, activation="swiglu",
    activation_strategy="sp",
    cross_attn_every=5, vision_len=1601, rope_theta=500000.0,
))
