"""qwen3-moe-30b-a3b: 48L d_model=2048 32H (GQA kv=4) d_ff=768(expert)
vocab=151936, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=0, d_ff_expert=768, vocab=151936, activation="swiglu",
    n_experts=128, n_shared_experts=0, moe_top_k=8,
))
