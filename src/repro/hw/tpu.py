"""Deprecated compatibility shim over :mod:`repro.hw.profiles`.

The machine model became data in the hardware-profile subsystem:
``TpuSpec`` is an alias of :class:`repro.hw.profiles.HardwareProfile`
(a strict superset of the old field set, same v5e defaults), and the
model functions live in ``repro.hw.profiles`` with the numpy/math
imports hoisted to module level.  ``V5E`` still resolves — with a
``DeprecationWarning`` — to the registered ``tpu_v5e`` profile, so old
imports keep working while call sites migrate.
"""
from __future__ import annotations

import warnings

from repro.hw.profiles import (  # noqa: F401  (re-exports)
    TPU_V5E,
    HardwareProfile as TpuSpec,
    dma_efficiency,
    dma_efficiency_arr,
    dtype_bytes,
    effective_element_bytes,
    ilp_factor,
    ilp_factor_arr,
    lane_utilization,
    lane_utilization_arr,
    sublane_utilization,
    sublane_utilization_arr,
)


def __getattr__(name: str):
    if name == "V5E":
        warnings.warn(
            "repro.hw.tpu.V5E is deprecated; use the 'tpu_v5e' profile from "
            "repro.hw.profiles (TPU_V5E / get_profile('tpu_v5e'))",
            DeprecationWarning, stacklevel=2)
        return TPU_V5E
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
