"""Pallas TPU kernel: batched radix-r prefix scan (add + linear-recurrence).

Layout: problems are rows of a (batch, n) array. The grid is
(batch/rows_per_program, n/tile_n); the column dimension is sequential on a
TPU core, so a VMEM scratch carries the running prefix across column tiles
(the multi-pass path of paper §IV-C; a single column tile is the in-VMEM
fast path, and with `in_register` the block is small enough to stay
VREG-resident between circuit levels).

The in-block circuit is a radix-r Kogge-Stone tree: at level s (stride r^s)
each element folds in r-1 shifted neighbours, so K = ceil(log_r tile_n)
levels replace log2 levels — the paper's rule-4 radix lever. Shifts are
zero/identity-padded `concatenate`s, which Mosaic lowers to lane shifts.

Tunable parameters consumed from the TuningDB config:
  tile_n, rows_per_program, radix, unroll (trace-time loop grouping hint;
  Pallas fully unrolls static Python loops, so this knob only reorders the
  fold tree), in_register (skip the cross-tile carry machinery).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _shift_right(x: jax.Array, off: int, fill: float) -> jax.Array:
    """Shift columns right by `off`, filling with the monoid identity."""
    if off <= 0:
        return x
    pad = jnp.full(x.shape[:-1] + (off,), fill, dtype=x.dtype)
    return jnp.concatenate([pad, x[..., :-off]], axis=-1)


def _ks_levels(tile_n: int, radix: int):
    """Strides for each Kogge-Stone level."""
    strides = []
    s = 1
    while s < tile_n:
        strides.append(s)
        s *= radix
    return strides


def _scan_add_kernel(x_ref, o_ref, carry_ref, *, radix: int, unroll: int,
                     multi_tile: bool):
    if multi_tile:
        @pl.when(pl.program_id(1) == 0)
        def _init():
            carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...].astype(jnp.float32)
    tile_n = x.shape[-1]
    for stride in _ks_levels(tile_n, radix):
        acc = x
        # fold r-1 shifted copies; `unroll` groups the fold pairwise
        # (associativity lets us build a balanced tree for ILP)
        shifted = [_shift_right(x, k * stride, 0.0) for k in range(1, radix)
                   if k * stride < tile_n]
        if unroll > 1:
            while len(shifted) > 1:
                nxt = []
                for i in range(0, len(shifted) - 1, 2):
                    nxt.append(shifted[i] + shifted[i + 1])
                if len(shifted) % 2:
                    nxt.append(shifted[-1])
                shifted = nxt
            acc = acc + shifted[0] if shifted else acc
        else:
            for sh in shifted:
                acc = acc + sh
        x = acc
    if multi_tile:
        x = x + carry_ref[...]
        carry_ref[...] = x[:, -1:]
    o_ref[...] = x.astype(o_ref.dtype)


def _scan_linrec_kernel(a_ref, b_ref, h_ref, carry_ref, *, radix: int,
                        unroll: int, multi_tile: bool):
    del unroll  # fold order fixed by composition order for linrec
    if multi_tile:
        @pl.when(pl.program_id(1) == 0)
        def _init():
            carry_ref[...] = jnp.zeros_like(carry_ref)

    aa = a_ref[...].astype(jnp.float32)
    bb = b_ref[...].astype(jnp.float32)
    tile_n = aa.shape[-1]
    for stride in _ks_levels(tile_n, radix):
        acc_a, acc_b = aa, bb
        for k in range(1, radix):
            off = k * stride
            if off >= tile_n:
                break
            sa = _shift_right(aa, off, 1.0)   # identity transform a=1
            sb = _shift_right(bb, off, 0.0)   # identity transform b=0
            # compose: acc (newer) after shifted (older):
            # (a, b) = (a_old * a_new, a_new * b_old + b_new)
            acc_b = acc_a * sb + acc_b
            acc_a = acc_a * sa
        aa, bb = acc_a, acc_b
    # aa now holds prefix products of a; bb the zero-state response
    if multi_tile:
        h = bb + aa * carry_ref[...]
        carry_ref[...] = h[:, -1:]
    else:
        h = bb
    h_ref[...] = h.astype(h_ref.dtype)


def _grid_and_specs(batch: int, n: int, rows: int, tile_n: int, n_in: int):
    grid = (batch // rows, n // tile_n)
    in_spec = pl.BlockSpec((rows, tile_n), lambda i, j: (i, j))
    out_spec = pl.BlockSpec((rows, tile_n), lambda i, j: (i, j))
    scratch = [pltpu.VMEM((rows, 1), jnp.float32)]
    return grid, [in_spec] * n_in, out_spec, scratch


@functools.partial(jax.jit, static_argnames=("rows_per_program", "tile_n",
                                             "radix", "unroll", "interpret"))
def scan_add_pallas(x: jax.Array, *, rows_per_program: int = 8,
                    tile_n: int = 0, radix: int = 2, unroll: int = 1,
                    interpret: bool = False) -> jax.Array:
    """Inclusive prefix sum over the last axis of (batch, n)."""
    batch, n = x.shape
    tile_n = tile_n or n
    grid, in_specs, out_spec, scratch = _grid_and_specs(
        batch, n, rows_per_program, tile_n, 1)
    kernel = functools.partial(_scan_add_kernel, radix=radix, unroll=unroll,
                               multi_tile=True)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("rows_per_program", "tile_n",
                                             "radix", "unroll", "interpret"))
def scan_linrec_pallas(a: jax.Array, b: jax.Array, *, rows_per_program: int = 8,
                       tile_n: int = 0, radix: int = 2, unroll: int = 1,
                       interpret: bool = False) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along the last axis of (batch, n) pairs."""
    batch, n = a.shape
    tile_n = tile_n or n
    grid, in_specs, out_spec, scratch = _grid_and_specs(
        batch, n, rows_per_program, tile_n, 2)
    kernel = functools.partial(_scan_linrec_kernel, radix=radix, unroll=unroll,
                               multi_tile=True)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
