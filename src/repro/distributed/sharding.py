"""Sharding-rules engine: parameter paths -> PartitionSpecs.

2D "FSDP x TP" layout over ("data", "model"):
  * the tensor-parallel dimension of each weight shards on "model"
    (Megatron column/row split; experts shard on "model" = EP);
  * the complementary dimension shards on "data" (ZeRO-3-style), so
    optimizer state for the 34B/90B archs fits per-device HBM;
  * the "pod" axis is pure DP: parameters are replicated across pods and
    gradients all-reduce over DCI (optionally compressed, optim/compression).

Rules check divisibility against the actual mesh; a non-divisible dim falls
back to unsharded, and the decision log records every fallback (e.g.
gemma-2b's 8-head QKV on a 16-way model axis shards the fused head*dim
feature dimension instead — see DESIGN.md §5/§6).

Leaves under "blocks"/"enc_blocks" carry a leading lax.scan group dimension;
their specs get a leading None prepended automatically.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any

# rule table: (path regex, spec template applied to the LAST len(template)
# dims of the leaf). "fsdp" -> "data", "tp" -> "model", None -> replicated.
_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    (r"embed/table$",               ("tp", "fsdp")),
    (r"attn/w[qkv]/w$",             ("fsdp", "tp")),
    (r"xattn/w[qkv]/w$",            ("fsdp", "tp")),
    (r"attn/wo/w$",                 ("tp", "fsdp")),
    (r"xattn/wo/w$",                ("tp", "fsdp")),
    (r"w[qkv]/b$",                  ("tp",)),
    (r"mlp/w[iu]/w$",               ("fsdp", "tp")),
    (r"mlp/wo/w$",                  ("tp", "fsdp")),
    (r"shared/w[iu]/w$",            ("fsdp", "tp")),
    (r"shared/wo/w$",               ("tp", "fsdp")),
    (r"moe/router/w$",              ("fsdp", None)),
    (r"moe/w[iu]$",                 ("tp", "fsdp", None)),   # (E, D, F): EP
    (r"moe/wo$",                    ("tp", None, "fsdp")),   # (E, F, D)
    (r"ssd/in_proj/w$",             ("fsdp", "tp")),
    (r"ssd/out_proj/w$",            ("tp", "fsdp")),
    (r"ssd/conv_w$",                (None, "tp")),
    (r"rec/w[xy]/w$",               ("fsdp", "tp")),
    (r"rec/w[ai]/w$",               (None, "tp")),
    (r"rec/conv_w$",                (None, "tp")),
    (r"rec/wo/w$",                  ("tp", "fsdp")),
]

_AXIS_MAP = {"fsdp": "data", "tp": "model"}


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


class ShardingDecisions:
    """Collects rule hits/fallbacks for DESIGN/EXPERIMENTS reporting."""

    def __init__(self):
        self.fallbacks: List[str] = []
        self.hits: Dict[str, str] = {}

    def record(self, path: str, spec, note: str = ""):
        self.hits[path] = f"{spec}{(' # ' + note) if note else ''}"

    def record_fallback(self, path: str, dim: int, axis: str, size: int,
                        dim_size: int):
        self.fallbacks.append(
            f"{path}: dim{dim} ({dim_size}) not divisible by {axis}"
            f" ({size}) -> replicated on that axis")


def spec_for_leaf(path: str, shape: Tuple[int, ...], mesh,
                  scanned: bool, decisions: Optional[ShardingDecisions] = None
                  ) -> P:
    for pattern, template in _RULES:
        if re.search(pattern, path):
            ndim = len(shape)
            offset = ndim - len(template)
            axes: List[Optional[str]] = [None] * ndim
            for i, logical in enumerate(template):
                if logical is None:
                    continue
                axis = _AXIS_MAP[logical]
                if axis not in mesh.axis_names:
                    continue
                size = mesh.shape[axis]
                dim = offset + i
                if shape[dim] % size == 0 and shape[dim] >= size:
                    axes[dim] = axis
                elif decisions is not None:
                    decisions.record_fallback(path, dim, axis, size, shape[dim])
            spec = P(*axes)
            if decisions is not None:
                decisions.record(path, spec)
            return spec
    # default: replicated (norm scales, small vectors, scalars)
    return P()


def param_specs(params: PyTree, mesh,
                decisions: Optional[ShardingDecisions] = None,
                pure_dp: bool = False) -> PyTree:
    """PartitionSpec pytree matching `params` (leading scan dims handled).
    pure_dp: replicate everything (small models where TP costs more in
    residual all-reduces than it saves in memory)."""
    if pure_dp:
        return jax.tree.map(lambda l: P(*([None] * getattr(l, "ndim", 0))),
                            params)

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        scanned = ps.startswith(("blocks", "enc_blocks"))
        shape = np.shape(leaf) if not hasattr(leaf, "shape") else leaf.shape
        if scanned and len(shape) >= 1:
            inner = spec_for_leaf(ps, tuple(shape[1:]), mesh, True, decisions)
            return P(*((None,) + tuple(inner)))
        return spec_for_leaf(ps, tuple(shape), mesh, False, decisions)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def shardings_from_specs(specs: PyTree, mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh, extra_dims: int = 1) -> P:
    """Input batch sharding: (B, ...) with B over ("pod","data")."""
    from repro.launch.mesh import batch_axes

    axes = batch_axes(mesh)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None),
             *([None] * extra_dims))


def train_state_specs(state: PyTree, mesh,
                      decisions: Optional[ShardingDecisions] = None,
                      pure_dp: bool = False) -> PyTree:
    """Specs for the full train state: optimizer moments inherit parameter
    specs (AdamW) or sliced specs (Adafactor's factored accumulators)."""
    from repro.optim.adamw import AdamWState
    from repro.optim.adafactor import AdafactorState

    pspecs = param_specs(state["params"], mesh, decisions,
                         pure_dp=pure_dp)
    out: Dict[str, Any] = {"params": pspecs, "step": P()}
    opt = state["opt"]
    if isinstance(opt, AdamWState):
        out["opt"] = AdamWState(mu=pspecs, nu=pspecs, count=P())
    elif isinstance(opt, AdafactorState):
        def vr_spec(spec, p):
            return P(*tuple(spec)[:-1]) if p.ndim >= 2 else spec

        def vc_spec(spec, p):
            t = tuple(spec)
            return P(*(t[:-2] + t[-1:])) if p.ndim >= 2 else P()

        out["opt"] = AdafactorState(
            vr=jax.tree.map(vr_spec, pspecs, state["params"],
                            is_leaf=lambda x: isinstance(x, P)),
            vc=jax.tree.map(vc_spec, pspecs, state["params"],
                            is_leaf=lambda x: isinstance(x, P)),
            count=P())
    else:
        raise TypeError(f"unknown optimizer state {type(opt)}")
    if "ef_err" in state:
        out["ef_err"] = pspecs
    return out


def batch_specs(batch: PyTree, mesh, axes: Optional[Tuple[str, ...]] = None
                ) -> PyTree:
    """Input batches shard on the batch dim only; a batch smaller than the
    batch-axis product (long_500k: global_batch=1) stays replicated.
    `axes` overrides the batch axes (pure_dp: the whole mesh)."""
    from repro.launch.mesh import batch_axes

    baxes = axes if axes is not None else batch_axes(mesh)
    total = 1
    for a in baxes:
        total *= mesh.shape[a]
    b_axis = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def one(leaf):
        if leaf.shape and leaf.shape[0] % total == 0:
            return P(b_axis, *([None] * max(leaf.ndim - 1, 0)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(one, batch)


def cache_specs(cache: PyTree, mesh) -> PyTree:
    """KV/state caches shard on batch; KV heads/features on model where
    divisible (decode_32k: 128-batch x 32k cache dominates memory)."""
    from repro.launch.mesh import batch_axes

    baxes = batch_axes(mesh)
    b_axis = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    model = "model" if "model" in mesh.axis_names else None
    msize = mesh.shape[model] if model else 1

    total = 1
    for a in baxes:
        total *= mesh.shape[a]

    def one(path, leaf):
        shape = leaf.shape
        # leading dim = scan groups, second = batch
        axes: List[Optional[str]] = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % total == 0:
            axes[1] = b_axis
        # shard the widest trailing dim on model if divisible
        if model and len(shape) >= 3:
            best, best_dim = 0, -1
            for d in range(2, len(shape)):
                if shape[d] % msize == 0 and shape[d] > best:
                    best, best_dim = shape[d], d
            if best_dim >= 0 and best >= msize:
                axes[best_dim] = model
        return P(*axes)

    return jax.tree_util.tree_map_with_path(one, cache)
