"""Serving engine: batched prefill + continuous-batching decode.

A slot-based scheduler: the engine owns `max_batch` slots, each slot a
request's KV/state cache lane. New requests prefill into a free slot (the
prefill forward recomputes the prompt; for cache-full archs the prompt K/V
are inserted by replaying tokens through decode for simplicity at host
scale — production TPU path would bulk-write prefill K/V); decode steps run
all active slots in lockstep (one jitted decode_step per token).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

PyTree = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params: PyTree, max_batch: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        self.cache = model.init_cache(max_batch, max_len, dtype=jnp.float32)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(model.decode_step)
        self.queue: List[Request] = []
        self.completed: List[Request] = []

    # -- public API --
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            # an empty prompt has no last token to decode from: _admit would
            # set slot_pos = -1 and _decode_step would IndexError on
            # prompt[-1]; reject at the door instead of crashing the batch
            raise ValueError("empty prompt: need at least one token")
        rid = len(self.queue) + len(self.completed) + sum(
            r is not None for r in self.slot_req)
        self.queue.append(Request(rid, prompt, max_new_tokens))
        return rid

    def run(self, max_steps: int = 1000) -> List[Request]:
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self._admit()
            self._decode_step()
            steps += 1
        return self.completed

    # -- internals --
    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None:
                continue
            while self.queue:
                req = self.queue.pop(0)
                if np.asarray(req.prompt).size == 0:
                    # hand-built Request bypassing submit(): complete it
                    # empty rather than poisoning the whole batch with
                    # slot_pos = -1 and an IndexError on prompt[-1]
                    req.done = True
                    self.completed.append(req)
                    continue
                self.slot_req[slot] = req
                # replay prompt through decode to build this slot's cache
                for t, tok in enumerate(req.prompt[:-1]):
                    self._step_slot(slot, int(tok), t)
                self.slot_pos[slot] = len(req.prompt) - 1
                break

    def _step_slot(self, slot: int, token: int, pos: int) -> int:
        """Single-slot step executed via the batched decode fn (other slots
        run their current token as padding work — lockstep batching)."""
        tokens = np.zeros((self.max_batch, 1), np.int32)
        poss = np.maximum(self.slot_pos[:, None], 0).astype(np.int32)
        tokens[slot, 0] = token
        poss[slot, 0] = pos
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.asarray(poss))
        return int(np.argmax(np.asarray(logits)[slot]))

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits_row))
        z = logits_row / self.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self.rng.choice(len(p), p=p))

    def _decode_step(self) -> None:
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tokens = np.zeros((self.max_batch, 1), np.int32)
        poss = np.maximum(self.slot_pos[:, None], 0).astype(np.int32)
        for s in active:
            req = self.slot_req[s]
            last = (req.output[-1] if req.output
                    else int(req.prompt[-1]))
            tokens[s, 0] = last
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.asarray(poss))
        logits = np.asarray(logits)
        for s in active:
            req = self.slot_req[s]
            nxt = self._sample(logits[s])
            req.output.append(nxt)
            self.slot_pos[s] += 1
            if (len(req.output) >= req.max_new_tokens
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                self.completed.append(req)
                self.slot_req[s] = None
