"""Pure-numpy random-forest regressor + versioned ``.npz`` model bundle.

Why a forest and not the GP already in ``core/bayesian.py``: the predictor
must answer *online* (rank hundreds of candidates in well under a
millisecond, zero objective evaluations) and must expose a cheap
uncertainty signal for the fallback gate. Bagged CART trees give both —
prediction is a handful of vectorized array traversals, and the spread of
the per-tree predictions is the disagreement estimate used to decide when
to fall back to the analytical model.

No sklearn: the container policy is numpy-only, and the trees here are
small enough (thousands of rows, ~24 features) that exact greedy splits
via prefix sums are fast.

Serialization: one ``.npz`` holds every per-op forest flattened to arrays
plus a JSON ``__meta__`` blob carrying the schema + feature versions.
Loading a bundle whose versions mismatch raises ``ModelArtifactError`` so
callers fall back instead of silently mis-predicting.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.tuning.ml.features import FEATURE_NAMES, FEATURE_VERSION

MODEL_SCHEMA = 1


class ModelArtifactError(RuntimeError):
    """Missing / corrupt / version-mismatched model artifact."""


# ---------------------------------------------------------------------------
# CART regression tree (arrays-of-nodes layout, exact greedy splits)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Tree:
    """Flat node arrays; feature == -1 marks a leaf."""

    feature: np.ndarray      # int32 (n_nodes,)
    threshold: np.ndarray    # float64 (n_nodes,)
    left: np.ndarray         # int32 (n_nodes,)
    right: np.ndarray        # int32 (n_nodes,)
    value: np.ndarray        # float64 (n_nodes,)

    def predict(self, X: np.ndarray) -> np.ndarray:
        idx = np.zeros(len(X), dtype=np.int32)
        while True:
            feat = self.feature[idx]
            active = feat >= 0
            if not active.any():
                return self.value[idx]
            rows = np.nonzero(active)[0]
            f, node = feat[rows], idx[rows]
            go_left = X[rows, f] <= self.threshold[node]
            idx[rows] = np.where(go_left, self.left[node], self.right[node])


def _best_split(X: np.ndarray, y: np.ndarray, feat_ids: np.ndarray,
                min_leaf: int) -> Optional[Tuple[int, float, float]]:
    """(feature, threshold, gain) of the best SSE-reducing split, or None."""
    n = len(y)
    parent_sse = float(np.sum(y * y) - np.sum(y) ** 2 / n)
    best: Optional[Tuple[int, float, float]] = None
    for f in feat_ids:
        order = np.argsort(X[:, f], kind="stable")
        xs, ys = X[order, f], y[order]
        # candidate boundaries: between distinct consecutive x values
        cum_y = np.cumsum(ys)
        cum_y2 = np.cumsum(ys * ys)
        k = np.arange(1, n)                       # left-side sizes
        valid = (xs[1:] != xs[:-1]) & (k >= min_leaf) & (n - k >= min_leaf)
        if not valid.any():
            continue
        ly, ly2 = cum_y[:-1], cum_y2[:-1]
        ry, ry2 = cum_y[-1] - ly, cum_y2[-1] - ly2
        sse = (ly2 - ly * ly / k) + (ry2 - ry * ry / (n - k))
        sse = np.where(valid, sse, np.inf)
        i = int(np.argmin(sse))
        gain = parent_sse - float(sse[i])
        if gain > 1e-12 and (best is None or gain > best[2]):
            thr = 0.5 * (xs[i] + xs[i + 1])
            best = (int(f), float(thr), gain)
    return best


def _grow_tree(X: np.ndarray, y: np.ndarray, rng: np.random.Generator, *,
               max_depth: int, min_leaf: int, feature_frac: float) -> Tree:
    feature: List[int] = []
    threshold: List[float] = []
    left: List[int] = []
    right: List[int] = []
    value: List[float] = []
    n_feat = X.shape[1]
    n_sub = max(1, int(round(feature_frac * n_feat)))

    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(-1)
        right.append(-1)
        value.append(0.0)
        return len(feature) - 1

    root = new_node()
    stack: List[Tuple[int, np.ndarray, int]] = [(root, np.arange(len(y)), 0)]
    while stack:
        node, idx, depth = stack.pop()
        ys = y[idx]
        value[node] = float(ys.mean())
        if depth >= max_depth or len(idx) < 2 * min_leaf \
                or float(ys.max() - ys.min()) < 1e-12:
            continue
        feat_ids = rng.permutation(n_feat)[:n_sub]
        split = _best_split(X[idx], ys, feat_ids, min_leaf)
        if split is None:
            continue
        f, thr, _ = split
        mask = X[idx, f] <= thr
        li, ri = idx[mask], idx[~mask]
        if not len(li) or not len(ri):
            continue
        feature[node], threshold[node] = f, thr
        left[node], right[node] = new_node(), new_node()
        stack.append((left[node], li, depth + 1))
        stack.append((right[node], ri, depth + 1))
    return Tree(np.asarray(feature, np.int32), np.asarray(threshold),
                np.asarray(left, np.int32), np.asarray(right, np.int32),
                np.asarray(value))


# ---------------------------------------------------------------------------
# Forest
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Forest:
    """Bagged regression trees; predicts (mean, per-tree std)."""

    trees: List[Tree] = dataclasses.field(default_factory=list)

    @classmethod
    def fit(cls, X: np.ndarray, y: np.ndarray, *, n_trees: int = 48,
            max_depth: int = 12, min_leaf: int = 2, feature_frac: float = 0.8,
            bootstrap: bool = True, seed: int = 0) -> "Forest":
        if len(X) == 0:
            raise ValueError("cannot fit a forest on an empty dataset")
        rng = np.random.default_rng(seed)
        trees = []
        for _ in range(n_trees):
            if bootstrap:
                idx = rng.integers(0, len(X), size=len(X))
                Xi, yi = X[idx], y[idx]
            else:
                Xi, yi = X, y     # diversity from feature subsampling only
            trees.append(_grow_tree(Xi, yi, rng, max_depth=max_depth,
                                    min_leaf=min_leaf,
                                    feature_frac=feature_frac))
        return cls(trees)

    def predict_all(self, X: np.ndarray) -> np.ndarray:
        """Per-tree predictions, shape (n_trees, n_rows)."""
        return np.stack([t.predict(X) for t in self.trees])

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        per_tree = self.predict_all(X)
        return per_tree.mean(axis=0), per_tree.std(axis=0)


# ---------------------------------------------------------------------------
# Bundle: one forest per kernel op, one artifact on disk
# ---------------------------------------------------------------------------

_TREE_FIELDS = ("feature", "threshold", "left", "right", "value")


class ModelBundle:
    """{op -> Forest} plus metadata; saved/loaded as a versioned ``.npz``."""

    def __init__(self, forests: Optional[Dict[str, Forest]] = None,
                 meta: Optional[Dict] = None):
        self.forests: Dict[str, Forest] = dict(forests or {})
        self.meta: Dict = {
            "schema": MODEL_SCHEMA,
            "feature_version": FEATURE_VERSION,
            "feature_names": list(FEATURE_NAMES),
            "label": "log_slowdown_vs_group_best",
        }
        self.meta.update(meta or {})

    def ops(self) -> Tuple[str, ...]:
        aliased = tuple(self.meta.get("aliases", {}))
        return tuple(sorted(set(self.forests) | set(aliased)))

    def forest_for(self, op: str) -> Optional[Forest]:
        """Forest for ``op``, following ``meta["aliases"]`` one hop.

        Ops sharing a search space and cost structure (scan / ssd / rglru)
        train one pooled forest; the alias map routes them to it.
        """
        forest = self.forests.get(op)
        if forest is not None:
            return forest
        alias = self.meta.get("aliases", {}).get(op)
        return self.forests.get(alias) if alias else None

    # -- persistence ---------------------------------------------------------

    def save(self, path: str) -> str:
        arrays: Dict[str, np.ndarray] = {
            "__meta__": np.frombuffer(
                json.dumps(self.meta, sort_keys=True).encode(), dtype=np.uint8),
        }
        for op, forest in self.forests.items():
            arrays[f"{op}::n_trees"] = np.array([len(forest.trees)])
            for i, tree in enumerate(forest.trees):
                for field in _TREE_FIELDS:
                    arrays[f"{op}::{i}::{field}"] = getattr(tree, field)
        directory = os.path.dirname(os.path.abspath(path))
        if directory:
            os.makedirs(directory, exist_ok=True)
        # atomic publish: CI's bench job may read while train-model rewrites
        buf = io.BytesIO()
        np.savez_compressed(buf, **arrays)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "wb") as f:
            f.write(buf.getvalue())
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "ModelBundle":
        if not os.path.exists(path):
            raise ModelArtifactError(f"no model artifact at {path!r}")
        try:
            with np.load(path) as data:
                meta = json.loads(bytes(data["__meta__"]).decode())
                if meta.get("schema") != MODEL_SCHEMA:
                    raise ModelArtifactError(
                        f"model schema {meta.get('schema')} != {MODEL_SCHEMA}")
                if meta.get("feature_version") != FEATURE_VERSION:
                    raise ModelArtifactError(
                        f"feature version {meta.get('feature_version')} != "
                        f"{FEATURE_VERSION}; retrain the model")
                forests: Dict[str, Forest] = {}
                for key in data.files:
                    if not key.endswith("::n_trees"):
                        continue
                    op = key[: -len("::n_trees")]
                    trees = [
                        Tree(*(data[f"{op}::{i}::{field}"]
                               for field in _TREE_FIELDS))
                        for i in range(int(data[key][0]))
                    ]
                    forests[op] = Forest(trees)
        except ModelArtifactError:
            raise
        except Exception as e:                    # corrupt zip/json/arrays
            raise ModelArtifactError(f"unreadable model artifact {path!r}: {e}")
        return cls(forests, meta)


def train_bundle(datasets: Dict[str, Tuple[np.ndarray, np.ndarray]], *,
                 n_trees: int = 48, max_depth: int = 12, min_leaf: int = 2,
                 feature_frac: float = 0.8, bootstrap: bool = True,
                 seed: int = 0, meta: Optional[Dict] = None) -> ModelBundle:
    """Fit one forest per op from ``{op: (X, y)}`` training splits."""
    forests = {}
    for op, (X, y) in sorted(datasets.items()):
        forests[str(op)] = Forest.fit(
            np.asarray(X, np.float64), np.asarray(y, np.float64),
            n_trees=n_trees, max_depth=max_depth, min_leaf=min_leaf,
            feature_frac=feature_frac, bootstrap=bootstrap, seed=seed)
    info = {"n_trees": n_trees, "max_depth": max_depth, "seed": seed,
            "train_rows": {str(op): int(len(X))
                           for op, (X, _) in datasets.items()}}
    info.update(meta or {})
    return ModelBundle(forests, info)
