"""Oracle for the RG-LRU (Real-Gated Linear Recurrent Unit) core.

Given per-position per-channel decay a (0,1) and gated input u:
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * u_t
(De et al., RecurrentGemma / Griffin). Shapes: (B, L, D).
"""
import jax
import jax.numpy as jnp


def rglru_ref(a: jax.Array, u: jax.Array) -> jax.Array:
    def step(h, au):
        a_t, u_t = au
        h = a_t * h + jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 0.0)) * u_t
        return h, h

    aT = jnp.moveaxis(a, 1, 0)
    uT = jnp.moveaxis(u, 1, 0)
    _, hT = jax.lax.scan(step, jnp.zeros_like(aT[0]), (aT, uT))
    return jnp.moveaxis(hT, 0, 1)
