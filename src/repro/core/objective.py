"""Objective functions mapping (workload, config) -> a metric vector.

Mirrors the paper's measurement protocol:
  - repeated executions, median taken (paper: 100 runs to damp run-to-run
    variability; we default lower for CPU-host practicality, configurable);
  - invalid configurations or configurations exceeding a timeout are clamped
    to a large penalty value (paper §IV-B);
  - the objective is a black box to the ML-based search.

A :class:`Measurement` carries a **metric vector** (``time_s`` always;
model-backed objectives add ``energy_j`` and ``peak_vmem_bytes``), with
``time_s`` kept as the scalar-compatible primary field — every pre-vector
consumer keeps working unchanged.  Which metric (or combination) a search
actually minimizes is a *policy* decision (``repro.core.policy``), not an
objective property.

The objective family is profile-generalized: every architectural constant
comes from a :class:`~repro.hw.profiles.HardwareProfile`, so the same
model retargets across devices by swapping the profile.

  * ``WallClockObjective`` — genuinely times a compiled callable on this
    host; emits ``time_s`` only.
  * ``CostModelObjective(profile)`` — a deterministic timing + energy
    model for one hardware profile, used as the offline-tuning "device".
    It intentionally models more mechanisms (DMA ramp, issue pipelines,
    pass overheads, mixed-radix penalties) than the analytical guideline
    consumes, so analytical-vs-BO comparisons on it are meaningful.  Under
    ``tpu_v5e`` its latency arithmetic is bit-identical to the historical
    ``TPUCostModelObjective`` (pinned by fixture test); the energy model
    (``idle_w``/``peak_compute_w``/``hbm_pj_per_byte`` profile fields) is
    additional output, never an input to the latency path.
  * ``PolicyObjective`` (``repro.core.policy``) — adapts any vector
    objective to the scalar lower-is-better protocol under a policy.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import time
import warnings
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.space import Config, SearchSpace, Workload
from repro.hw.profiles import (
    HardwareProfile,
    active_profile,
    dma_efficiency,
    dma_efficiency_arr,
    effective_element_bytes,
    ilp_factor,
    ilp_factor_arr,
    lane_utilization,
    lane_utilization_arr,
    sublane_utilization,
    sublane_utilization_arr,
)

PENALTY_TIME = 60.0  # seconds — the paper's 1-minute clamp

# canonical metric names (the vector axes every layer agrees on)
METRIC_TIME = "time_s"
METRIC_ENERGY = "energy_j"
METRIC_PEAK_VMEM = "peak_vmem_bytes"

# per-metric penalty clamps for invalid/failed measurements: each value is
# far beyond anything a real config can produce, so an invalid config loses
# on EVERY metric (and therefore under every policy and on the Pareto front)
METRIC_PENALTIES: Dict[str, float] = {
    METRIC_TIME: PENALTY_TIME,
    METRIC_ENERGY: 1e6,          # joules; worst real config is ~1e4
    METRIC_PEAK_VMEM: float(2**40),
}

# bump when the serialized Measurement layout changes
MEASUREMENT_VERSION = 1

# the serialized layout ``Measurement.to_dict`` emits, fingerprinted by
# ``repro.analysis`` against MEASUREMENT_VERSION: journals, DB entries and
# traces all persist this dict, so reshaping it without a version bump
# silently corrupts every consumer's migration path
MEASUREMENT_FIELDS = ("version", "time_s", "valid", "metrics", "meta")


def metric_penalty(name: str) -> float:
    """The penalty clamp for one metric (PENALTY_TIME for unknown names)."""
    return METRIC_PENALTIES.get(name, PENALTY_TIME)


@dataclasses.dataclass
class Measurement:
    """One evaluation: a metric vector with ``time_s`` as the primary axis.

    ``time_s`` stays a plain field for scalar compatibility — everything
    that predates vector objectives keeps reading it.  ``metrics`` is the
    canonical vector; ``__post_init__`` guarantees it always contains
    ``time_s`` (mirrored from the field), so ``Measurement(t, True)`` and
    fully vector-valued constructions behave identically downstream.
    """

    time_s: float
    valid: bool
    meta: Dict[str, float] = dataclasses.field(default_factory=dict)
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        # time_s is authoritative: the metrics vector always mirrors it
        self.metrics = dict(self.metrics)
        self.metrics[METRIC_TIME] = self.time_s

    def metric(self, name: str, default: Optional[float] = None) -> Optional[float]:
        return self.metrics.get(name, default)

    @property
    def energy_j(self) -> Optional[float]:
        """Modeled/measured joules; None for time-only objectives."""
        return self.metrics.get(METRIC_ENERGY)

    @property
    def peak_vmem_bytes(self) -> Optional[float]:
        """Peak on-chip working set; None for time-only objectives."""
        return self.metrics.get(METRIC_PEAK_VMEM)

    # -- versioned serialization (journals, DB entries, traces) -------------

    def to_dict(self) -> Dict:
        return {"version": MEASUREMENT_VERSION, "time_s": self.time_s,
                "valid": self.valid, "metrics": dict(self.metrics),
                "meta": dict(self.meta)}

    @classmethod
    def from_dict(cls, d: Mapping) -> "Measurement":
        """Inverse of ``to_dict``; version-0 dicts (no ``metrics``) load as
        time-only vectors."""
        metrics = dict(d.get("metrics") or {})
        time_s = float(d.get("time_s", metrics.get(METRIC_TIME, PENALTY_TIME)))
        return cls(time_s, bool(d.get("valid", True)),
                   meta=dict(d.get("meta") or {}), metrics=metrics)


class Objective:
    """Black-box objective: lower is better (on every metric)."""

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        raise NotImplementedError

    def metric_names(self) -> Tuple[str, ...]:
        """The metric axes this objective emits; ``time_s`` always first."""
        return (METRIC_TIME,)

    def batch_eval(self, space: SearchSpace, cfgs: Sequence[Config], *,
                   assume_valid: bool = False) -> np.ndarray:
        """Evaluate a whole candidate set; returns penalty-clamped times (s).

        The default walks ``__call__`` config by config; objectives with a
        closed-form model override this with a vectorized fast path (the
        sweep engine feeds it thousands of candidates at once).
        ``assume_valid`` lets callers that enumerated the space skip the
        per-config validity re-check.
        """
        out = np.empty(len(cfgs), dtype=np.float64)
        for i, cfg in enumerate(cfgs):
            m = self(space, cfg)
            out[i] = m.time_s if m.valid else PENALTY_TIME
        return out

    def batch_eval_metrics(self, space: SearchSpace, cfgs: Sequence[Config],
                           *, assume_valid: bool = False
                           ) -> Dict[str, np.ndarray]:
        """Vector form of ``batch_eval``: one array per metric name.

        Invalid/failed configs are clamped to each metric's penalty value
        (``metric_penalty``), so they lose under every policy.  Time-only
        objectives delegate to ``batch_eval`` — subclasses that override
        only the scalar fast path keep it for free.
        """
        names = self.metric_names()
        if names == (METRIC_TIME,):
            return {METRIC_TIME: self.batch_eval(space, cfgs,
                                                 assume_valid=assume_valid)}
        cols = {n: np.empty(len(cfgs), dtype=np.float64) for n in names}
        for i, cfg in enumerate(cfgs):
            m = self(space, cfg)
            for n in names:
                cols[n][i] = (m.metric(n, metric_penalty(n)) if m.valid
                              else metric_penalty(n))
        return cols

    def signature(self) -> str:
        """Stable identity used to key sweep journals (see tuning/sweep.py).

        Two objectives with the same signature must assign the same metric
        vector to the same (workload, config); override when parameters
        change that.
        """
        return type(self).__name__


class WallClockObjective(Objective):
    """Times `runner(workload, config) -> callable()` on the host.

    runner builds (and jits) the kernel for the config; the returned thunk is
    executed `reps` times and the median is reported. Exceptions or invalid
    configs yield the penalty clamp.
    """

    def __init__(self, runner: Callable[[Workload, Config], Callable[[], None]],
                 reps: int = 5, warmup: int = 1, timeout_s: float = PENALTY_TIME):
        self.runner = runner
        self.reps = reps
        self.warmup = warmup
        self.timeout_s = timeout_s

    def signature(self) -> str:
        # the runner decides what is measured: journals keyed by a bare
        # class name would happily resume another kernel's times
        runner_id = f"{getattr(self.runner, '__module__', '?')}." \
                    f"{getattr(self.runner, '__qualname__', repr(self.runner))}"
        return (f"wallclock:{runner_id}:reps={self.reps}"
                f":warmup={self.warmup}:timeout={self.timeout_s}")

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        if not space.is_valid(cfg):
            return Measurement(PENALTY_TIME, False)
        try:
            thunk = self.runner(space.workload, cfg)
            for _ in range(self.warmup):
                thunk()
            times = []
            for _ in range(self.reps):
                t0 = time.perf_counter()
                thunk()
                dt = time.perf_counter() - t0
                times.append(dt)
                if dt > self.timeout_s:
                    return Measurement(PENALTY_TIME, False)
            times.sort()
            return Measurement(times[len(times) // 2], True)
        except Exception:
            return Measurement(PENALTY_TIME, False)


def _flops_and_passes(wl: Workload, cfg: Config) -> Dict[str, float]:
    """Operation-specific work model for the cost objective."""
    n = wl.n
    tile_n = cfg.get("tile_n", n)
    r = cfg.get("radix", 2)
    out: Dict[str, float] = {}
    def mixed(tile: int, radix: int) -> float:
        # ragged final circuit level when radix^k != tile: extra low-radix
        # step + sync (paper's WM jagged-performance observation)
        k = round(math.log(max(tile, 2), radix)) if radix > 1 else 1
        return 0.0 if radix**k == tile else 1.0

    if wl.op in ("scan", "ssd", "rglru"):
        steps = math.ceil(math.log(max(tile_n, 2), r))
        # Kogge-Stone does N work per step; Ladner-Fischer ~2N total but more
        # steps of structure; model KS-like: n ops/step, radix-r node = r-1 adds
        out["flops"] = steps * n * (r - 1) / max(r / 2, 1)
        base_passes = math.ceil(math.log(max(n, 2), r) / math.log(max(tile_n, 2), r)) if tile_n < n else 1
        fuse = cfg.get("fuse", 0)
        if wl.op == "ssd":
            # chain passes: intra + (linrec + apply, or the fused
            # state-apply launch) — fuse=1 saves one HBM pass
            out["passes"] = (3.0 - fuse) if tile_n < n else 1.0
        elif wl.op == "rglru":
            # gate link: a separate XLA pass unless folded into the scan
            # kernel's first stage (fuse=1)
            out["passes"] = base_passes + (1.0 - fuse)
        else:
            out["passes"] = base_passes
        out["steps"] = steps
        out["mixed_radix"] = mixed(tile_n, r)
    elif wl.op == "tridiag":
        steps = math.ceil(math.log2(max(n, 2))) if wl.variant in ("cr", "pcr") else math.ceil(math.log(max(n, 2), r))
        per_step = 14 if wl.variant == "pcr" else 9  # PCR full-width; CR halves
        work_n = n if wl.variant == "pcr" else 2 * n
        out["flops"] = steps * work_n * per_step / max(math.log2(r), 1)
        out["passes"] = 1
        out["steps"] = steps
        out["mixed_radix"] = mixed(tile_n, r) if wl.variant == "wm" else 0.0
    elif wl.op in ("fft", "large_fft"):
        # radix-r Stockham: log_r(N) stages, each stage ~5N flops equivalent
        stages_total = math.log(max(n, 2), r)
        out["flops"] = 5.0 * n * math.log2(max(n, 2))  # canonical 5NlogN
        s = math.log(max(tile_n, 2), r)
        out["passes"] = max(1, math.ceil(stages_total / max(s, 1)))
        out["steps"] = math.ceil(stages_total)
        # mixed-radix penalty (paper Fig 5 jagged line): if r^k != tile_n an
        # extra lower-radix step is required
        k = round(math.log(tile_n, r))
        out["mixed_radix"] = 0.0 if r ** k == tile_n else 1.0
    elif wl.op == "attention":
        head_dim = 128
        out["flops"] = 4.0 * n * head_dim  # per q-row, per kv token: 2 matmuls
        out["passes"] = 1
        out["steps"] = max(n // cfg.get("block_k", 128), 1)
    elif wl.op == "matmul":
        out["flops"] = 2.0 * n * n  # per row of M
        out["passes"] = 1
        out["steps"] = max(n // cfg.get("block_k", 128), 1)
    else:
        out["flops"] = float(n)
        out["passes"] = 1
        out["steps"] = 1
    out.setdefault("mixed_radix", 0.0)
    return out


def _knob(cfgs: Sequence[Config], name: str, default) -> np.ndarray:
    return np.array([c.get(name, default) for c in cfgs], dtype=np.float64)


class _KnobCols:
    """One-pass knob extraction for a homogeneous candidate set.

    Configs coming out of ``enumerate_valid`` (and journal replays of them)
    all share one key order, so the whole knob table is a single
    ``np.array`` of ``c.values()`` — the per-knob ``dict.get`` loops were
    75% of the batched evaluation cost. Heterogeneous sets fall back to the
    per-knob path transparently.
    """

    def __init__(self, cfgs: Sequence[Config]):
        import itertools
        import operator

        self.cfgs = cfgs
        self.cols: Dict[str, np.ndarray] = {}
        if not cfgs:
            return
        names = tuple(cfgs[0].keys())
        k = len(names)
        if k < 2:
            return
        # itemgetter extracts BY NAME, so differing key orders cannot be
        # mis-columned; a config missing a knob raises KeyError (fall back
        # to per-knob gets), and the length sum rules out extra knobs that
        # the table would otherwise silently answer with defaults
        if sum(map(len, cfgs)) != len(cfgs) * k:
            return
        getter = operator.itemgetter(*names)
        try:
            mat = np.fromiter(
                itertools.chain.from_iterable(map(getter, cfgs)),
                dtype=np.float64, count=len(cfgs) * k).reshape(len(cfgs), k)
        except KeyError:
            return
        self.cols = {nm: mat[:, j] for j, nm in enumerate(names)}

    def get(self, name: str, default) -> np.ndarray:
        col = self.cols.get(name)
        if col is not None:
            return col
        if self.cols:   # homogeneous set without this knob: broadcast default
            return np.full(len(self.cfgs), float(default))
        return _knob(self.cfgs, name, default)


def _mixed_radix_arr(tile: np.ndarray, r: np.ndarray) -> np.ndarray:
    """Vectorized mixed() from _flops_and_passes: 1.0 when radix^k != tile."""
    k = np.where(r > 1,
                 np.rint(np.log(np.maximum(tile, 2)) / np.log(np.maximum(r, 2))),
                 1.0)
    return np.where(np.power(r, k) == tile, 0.0, 1.0)


def _batch_work(wl: Workload, cfgs: Sequence[Config],
                cols: Optional[_KnobCols] = None) -> Dict[str, np.ndarray]:
    """Vectorized `_flops_and_passes`: arrays over the candidate axis.

    Element-for-element identical to the scalar model (same formulas, same
    double-precision ops), so batched sweeps and per-config evaluation
    produce the same times.
    """
    cols = cols or _KnobCols(cfgs)
    n = wl.n
    tile_n = cols.get("tile_n", n)
    r = cols.get("radix", 2)
    out: Dict[str, np.ndarray] = {}
    ones = np.ones(len(cfgs), dtype=np.float64)

    if wl.op in ("scan", "ssd", "rglru"):
        log_r = np.log(np.maximum(r, 2))
        log_tile = np.log(np.maximum(tile_n, 2))
        steps = np.ceil(log_tile / log_r)
        out["flops"] = steps * n * (r - 1) / np.maximum(r / 2, 1)
        base_passes = np.where(
            tile_n < n,
            np.ceil(np.log(max(n, 2)) / log_r / (log_tile / log_r)), 1.0)
        fuse = cols.get("fuse", 0)
        if wl.op == "ssd":
            out["passes"] = np.where(tile_n < n, 3.0 - fuse, 1.0)
        elif wl.op == "rglru":
            out["passes"] = base_passes + (1.0 - fuse)
        else:
            out["passes"] = base_passes
        out["steps"] = steps
        out["mixed_radix"] = _mixed_radix_arr(tile_n, r)
    elif wl.op == "tridiag":
        if wl.variant in ("cr", "pcr"):
            steps = float(math.ceil(math.log2(max(n, 2)))) * ones
        else:
            steps = np.ceil(np.log(max(n, 2)) / np.log(np.maximum(r, 2)))
        per_step = 14 if wl.variant == "pcr" else 9
        work_n = n if wl.variant == "pcr" else 2 * n
        out["flops"] = steps * work_n * per_step / np.maximum(np.log2(r), 1)
        out["passes"] = ones.copy()
        out["steps"] = steps
        out["mixed_radix"] = (_mixed_radix_arr(tile_n, r)
                              if wl.variant == "wm" else 0.0 * ones)
    elif wl.op in ("fft", "large_fft"):
        log_r = np.log(np.maximum(r, 2))
        stages_total = np.log(max(n, 2)) / log_r
        out["flops"] = 5.0 * n * math.log2(max(n, 2)) * ones
        s = np.log(np.maximum(tile_n, 2)) / log_r
        out["passes"] = np.maximum(1, np.ceil(stages_total / np.maximum(s, 1)))
        out["steps"] = np.ceil(stages_total)
        k = np.rint(np.log(tile_n) / np.log(r))
        out["mixed_radix"] = np.where(np.power(r, k) == tile_n, 0.0, 1.0)
    elif wl.op == "attention":
        head_dim = 128
        out["flops"] = 4.0 * n * head_dim * ones
        out["passes"] = ones.copy()
        out["steps"] = np.maximum(np.floor(n / cols.get("block_k", 128)), 1)
    elif wl.op == "matmul":
        out["flops"] = 2.0 * n * n * ones
        out["passes"] = ones.copy()
        out["steps"] = np.maximum(np.floor(n / cols.get("block_k", 128)), 1)
    else:
        out["flops"] = float(n) * ones
        out["passes"] = ones.copy()
        out["steps"] = ones.copy()
    out.setdefault("mixed_radix", 0.0 * ones)
    return out


class CostModelObjective(Objective):
    """Deterministic timing model for a hardware profile (+ optional jitter).

    t = passes * [ launch + max(t_compute, t_memory)/overlap + steps*sync ]

    with: t_memory from bytes moved through the DMA ramp; t_compute from
    vector-unit issue with lane/sublane utilization and ILP factors (matrix
    unit for matmul/attention); overlap in (0.5,1] grows with grid depth
    (needs >=2 programs in flight to double-buffer). Every architectural
    constant comes from the :class:`~repro.hw.profiles.HardwareProfile`, so
    the same model retargets by swapping the profile — the paper's
    portability mechanism. Under ``tpu_v5e`` the latency arithmetic is
    bit-identical to the historical ``TPUCostModelObjective`` (pinned by
    fixture test).

    Beyond ``time_s`` the model emits two more metric axes from the same
    intermediates:

    * ``energy_j``  — ``idle_w * t + peak_compute_w * t_comp
      + hbm_pj_per_byte * 1e-12 * bytes`` (static draw for the kernel's
      duration, dynamic draw while compute units are busy, per-byte memory
      access energy).  Energy is derived *from* the latency terms, never
      fed back into them.
    * ``peak_vmem_bytes`` — the double-buffered block working set.
    """

    def __init__(self, profile: Optional[HardwareProfile] = None,
                 noise: float = 0.0, *,
                 spec: Optional[HardwareProfile] = None):
        if spec is not None:
            warnings.warn("CostModelObjective(spec=...) is deprecated; "
                          "pass profile=...", DeprecationWarning, stacklevel=2)
            if profile is None:
                profile = spec
        self.spec = profile if profile is not None else active_profile()
        self.noise = noise

    @property
    def profile(self) -> HardwareProfile:
        """Canonical name for the hardware profile (``spec`` predates it)."""
        return self.spec

    def metric_names(self) -> Tuple[str, ...]:
        return (METRIC_TIME, METRIC_ENERGY, METRIC_PEAK_VMEM)

    def _jitter(self, wl: Workload, cfg: Config) -> float:
        if not self.noise:
            return 1.0
        key = f"{wl.key}|{sorted(cfg.items())}".encode()
        h = int.from_bytes(hashlib.sha256(key).digest()[:8], "big")
        u = (h / 2**64) * 2.0 - 1.0  # [-1, 1)
        return 1.0 + self.noise * u

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        if not space.is_valid(cfg):
            return Measurement(PENALTY_TIME, False)
        wl, spec = space.workload, self.spec
        # tridiag: 4 coefficients per equation; fft: interleaved complex
        eb = effective_element_bytes(wl.op, wl.dtype)

        work = _flops_and_passes(wl, cfg)
        batch = max(wl.batch, 1)
        rows = cfg.get("rows_per_program", 1)
        tile_n = cfg.get("tile_n", wl.n)

        if wl.op == "attention":
            block_q, block_k = cfg["block_q"], cfg["block_k"]
            grid = max(batch, 1) * max(wl.n // block_q, 1)
            block_bytes = (block_q + 2 * block_k) * 128 * eb
            total_bytes = batch * wl.n * 128 * eb * 3
            total_flops = batch * wl.n * work["flops"]
            trailing = block_k
        elif wl.op == "matmul":
            bm, bn, bk = cfg["block_m"], cfg["block_n"], cfg["block_k"]
            grid = max(batch // bm, 1) * max(wl.n // bn, 1)
            block_bytes = (bm * bk + bk * bn) * eb
            total_bytes = (batch * wl.n + wl.n * wl.n) * eb
            total_flops = batch * work["flops"]
            trailing = bn
        else:
            grid = max(batch // rows, 1) * max(wl.n // tile_n, 1)
            block_bytes = rows * tile_n * eb
            total_bytes = 2.0 * batch * wl.n * eb * work["passes"]
            total_flops = batch * work["flops"]
            trailing = min(tile_n, spec.lane_count * 8) if not cfg.get("in_register") else tile_n

        # --- memory term ---
        t_mem = total_bytes / (spec.hbm_bandwidth * dma_efficiency(int(block_bytes), spec))
        # --- compute term (VPU for prefix ops; MXU for matmul/attention) ---
        if wl.op in ("matmul", "attention"):
            peak = spec.peak_bf16_flops if wl.dtype == "bfloat16" else spec.peak_f32_flops
            mxu_util = min(trailing / spec.mxu_dim, 1.0)
            t_comp = total_flops / (peak * max(mxu_util, 1e-3))
        else:
            util = lane_utilization(trailing, spec)
            sub = sublane_utilization(rows * max(tile_n // spec.lane_count, 1), spec)
            eff = max(util * max(sub, 0.25)
                      * ilp_factor(cfg.get("unroll", 1), spec), 1e-3)
            t_comp = total_flops / (spec.peak_vpu_flops * eff)
            if cfg.get("in_register"):
                t_comp *= 0.8   # no scratch roundtrip between steps
            else:
                t_comp *= 1.0 + 0.05 * work["steps"]  # scratch traffic per step

        # --- overlap: need >=2 programs in flight (occupancy premise) ---
        overlap = 1.0 if grid >= 4 else (0.85 if grid >= 2 else 0.55)
        t_body = max(t_comp, t_mem) / overlap + (1.0 - overlap) * min(t_comp, t_mem) * 0.1
        passes = work["passes"]
        t = passes * (spec.kernel_launch_s + t_body / passes + work["steps"] / passes * spec.pass_sync_s)
        t *= 1.0 + 0.25 * work.get("mixed_radix", 0.0)
        t *= self._jitter(wl, cfg)
        # energy/memory axes, derived from the latency intermediates (the
        # latency value above is already final — nothing below feeds back)
        energy = (spec.idle_w * t + spec.peak_compute_w * t_comp
                  + spec.hbm_pj_per_byte * 1e-12 * total_bytes)
        peak_vmem = 2.0 * block_bytes   # double-buffered working set
        return Measurement(
            t, True,
            meta={"t_comp": t_comp, "t_mem": t_mem, "grid": grid,
                  "passes": passes, "flops": total_flops, "bytes": total_bytes},
            metrics={METRIC_ENERGY: energy, METRIC_PEAK_VMEM: peak_vmem},
        )

    def signature(self) -> str:
        # the historical "tpu_cost:tpu_v5e:..." form is kept for tpu_v5e so
        # pre-profile sweep journals stay resumable; other profiles get
        # their own namespace — a journal measured on one profile can never
        # satisfy the signature check under another
        prefix = "tpu_cost" if self.spec.name == "tpu_v5e" else "cost"
        return f"{prefix}:{self.spec.name}:noise={self.noise}"

    def batch_eval(self, space: SearchSpace, cfgs: Sequence[Config], *,
                   assume_valid: bool = False) -> np.ndarray:
        """Vectorized fast path: the time column of ``batch_eval_metrics``."""
        return self.batch_eval_metrics(space, cfgs,
                                       assume_valid=assume_valid)[METRIC_TIME]

    def batch_eval_metrics(self, space: SearchSpace, cfgs: Sequence[Config],
                           *, assume_valid: bool = False
                           ) -> Dict[str, np.ndarray]:
        """Vectorized fast path: the whole candidate set in array ops.

        Mirrors ``__call__`` branch for branch; the only per-config Python
        left is knob extraction (and the sha256 jitter when noise is on).
        The time column is computed first and independently — the energy
        and memory columns are derived afterwards, so the latency numbers
        are bit-identical to the pre-vector implementation.
        """
        if not len(cfgs):
            return {n: np.empty(0, dtype=np.float64)
                    for n in self.metric_names()}
        wl, spec = space.workload, self.spec
        eb = effective_element_bytes(wl.op, wl.dtype)
        cols = _KnobCols(cfgs)
        work = _batch_work(wl, cfgs, cols)
        batch = max(wl.batch, 1)
        rows = cols.get("rows_per_program", 1)
        tile_n = cols.get("tile_n", wl.n)
        in_reg = cols.get("in_register", 0)

        if wl.op == "attention":
            block_q = cols.get("block_q", 128)
            block_k = cols.get("block_k", 128)
            grid = max(batch, 1) * np.maximum(np.floor(wl.n / block_q), 1)
            block_bytes = (block_q + 2 * block_k) * 128 * eb
            total_bytes = batch * wl.n * 128 * eb * 3.0 + 0.0 * grid
            total_flops = batch * wl.n * work["flops"]
            trailing = block_k
        elif wl.op == "matmul":
            bm = cols.get("block_m", 128)
            bn = cols.get("block_n", 128)
            bk = cols.get("block_k", 128)
            grid = np.maximum(np.floor(batch / bm), 1) \
                * np.maximum(np.floor(wl.n / bn), 1)
            block_bytes = (bm * bk + bk * bn) * eb
            total_bytes = (batch * wl.n + wl.n * wl.n) * eb + 0.0 * grid
            total_flops = batch * work["flops"]
            trailing = bn
        else:
            grid = np.maximum(np.floor(batch / rows), 1) \
                * np.maximum(np.floor(wl.n / tile_n), 1)
            block_bytes = rows * tile_n * eb
            total_bytes = 2.0 * batch * wl.n * eb * work["passes"]
            total_flops = batch * work["flops"]
            trailing = np.where(in_reg, tile_n,
                                np.minimum(tile_n, spec.lane_count * 8))

        with np.errstate(all="ignore"):
            t_mem = total_bytes / (spec.hbm_bandwidth
                                   * dma_efficiency_arr(block_bytes, spec))
            if wl.op in ("matmul", "attention"):
                peak = spec.peak_bf16_flops if wl.dtype == "bfloat16" \
                    else spec.peak_f32_flops
                mxu_util = np.minimum(trailing / spec.mxu_dim, 1.0)
                t_comp = total_flops / (peak * np.maximum(mxu_util, 1e-3))
            else:
                util = lane_utilization_arr(trailing, spec)
                sub = sublane_utilization_arr(
                    rows * np.maximum(np.floor(tile_n / spec.lane_count), 1),
                    spec)
                eff = np.maximum(util * np.maximum(sub, 0.25)
                                 * ilp_factor_arr(cols.get("unroll", 1), spec),
                                 1e-3)
                t_comp = total_flops / (spec.peak_vpu_flops * eff)
                t_comp = np.where(in_reg, t_comp * 0.8,
                                  t_comp * (1.0 + 0.05 * work["steps"]))

            overlap = np.where(grid >= 4, 1.0, np.where(grid >= 2, 0.85, 0.55))
            t_body = np.maximum(t_comp, t_mem) / overlap \
                + (1.0 - overlap) * np.minimum(t_comp, t_mem) * 0.1
            passes = work["passes"]
            t = passes * (spec.kernel_launch_s + t_body / passes
                          + work["steps"] / passes * spec.pass_sync_s)
            t = t * (1.0 + 0.25 * work["mixed_radix"])
            if self.noise:
                t = t * np.array([self._jitter(wl, c) for c in cfgs])
            # derived metric columns — same expressions as the scalar path,
            # computed after (and never feeding into) the time column
            energy = (spec.idle_w * t + spec.peak_compute_w * t_comp
                      + spec.hbm_pj_per_byte * 1e-12 * total_bytes)
            peak_vmem = 2.0 * block_bytes * np.ones_like(t)

        t = np.nan_to_num(t, nan=PENALTY_TIME, posinf=PENALTY_TIME,
                          neginf=PENALTY_TIME)
        if not assume_valid:
            valid = np.fromiter((space.is_valid(c) for c in cfgs),
                                dtype=bool, count=len(cfgs))
            t = np.where(valid, t, PENALTY_TIME)
        # the exact penalty clamp marks a failed/invalid row (the batched
        # protocol's convention); such rows lose on every metric axis
        pen_e, pen_v = metric_penalty(METRIC_ENERGY), metric_penalty(METRIC_PEAK_VMEM)
        bad = t == PENALTY_TIME
        energy = np.nan_to_num(energy, nan=pen_e, posinf=pen_e, neginf=pen_e)
        return {METRIC_TIME: t,
                METRIC_ENERGY: np.where(bad, pen_e, energy),
                METRIC_PEAK_VMEM: np.where(bad, pen_v, peak_vmem)}


# Backwards-compatible name: the objective predates the profile layer and
# much of the stack (and its journals' signatures) grew up calling it this.
TPUCostModelObjective = CostModelObjective


class CachedObjective(Objective):
    """Memoizes measurements — searches may revisit configs."""

    def __init__(self, inner: Objective):
        self.inner = inner
        self.cache: Dict[str, Measurement] = {}
        self.evaluations = 0   # counts *unique* real evaluations (paper Fig 4)

    @property
    def spec(self) -> Optional[HardwareProfile]:
        """The inner objective's hardware profile, when it models one
        (journal headers record it; wallclock objectives have none)."""
        return getattr(self.inner, "spec", None)

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        key = f"{space.workload.key}|{tuple(sorted(cfg.items()))}"
        if key not in self.cache:
            self.cache[key] = self.inner(space, cfg)
            self.evaluations += 1
        return self.cache[key]

    def signature(self) -> str:
        return self.inner.signature()

    def metric_names(self) -> Tuple[str, ...]:
        return self.inner.metric_names()

    def seed(self, space: SearchSpace, history: Sequence[tuple],
             metrics: Optional[Sequence[Mapping[str, float]]] = None) -> None:
        """Pre-load (config, time) pairs as cached measurements.

        Used by consumers that obtained times outside this cache — e.g. a
        journal-resumed sweep — and need later scalar calls to answer from
        those exact numbers instead of re-measuring (`evaluations` is not
        incremented; nothing fresh was run).  ``metrics``, when given, is a
        parallel sequence of metric vectors (journal version 3 records
        them); without it the seeded entries are time-only vectors.
        """
        wl_key = space.workload.key
        for i, (cfg, t) in enumerate(history):
            key = f"{wl_key}|{tuple(sorted(cfg.items()))}"
            if key not in self.cache:
                t = float(t)
                vec = dict(metrics[i]) if metrics is not None else {}
                self.cache[key] = Measurement(t, t != PENALTY_TIME,
                                              metrics=vec)

    def batch_eval(self, space: SearchSpace, cfgs: Sequence[Config], *,
                   assume_valid: bool = False) -> np.ndarray:
        wl_key = space.workload.key
        keys = [f"{wl_key}|{tuple(sorted(c.items()))}" for c in cfgs]
        fresh = [i for i, k in enumerate(keys) if k not in self.cache]
        if fresh:
            times = self.inner.batch_eval(
                space, [cfgs[i] for i in fresh], assume_valid=assume_valid)
            for i, t in zip(fresh, times):
                t = float(t)
                # in the times-array protocol the exact penalty clamp marks
                # a failed/invalid measurement (batch_eval never clamps a
                # valid config — a genuinely valid one may model slower than
                # 60 s and must stay valid). assume_valid skips the SPACE
                # validity re-check only; it cannot vouch for measurement
                # validity (wallclock timeouts, OOM penalties).
                self.cache[keys[i]] = Measurement(t, t != PENALTY_TIME)
            self.evaluations += len(fresh)
        out = np.empty(len(cfgs), dtype=np.float64)
        for i, k in enumerate(keys):
            m = self.cache[k]
            out[i] = m.time_s if m.valid else PENALTY_TIME
        return out

    def batch_eval_metrics(self, space: SearchSpace, cfgs: Sequence[Config],
                           *, assume_valid: bool = False
                           ) -> Dict[str, np.ndarray]:
        names = self.metric_names()
        wl_key = space.workload.key
        keys = [f"{wl_key}|{tuple(sorted(c.items()))}" for c in cfgs]
        # a cached VALID entry missing a requested metric (seeded from a
        # pre-vector journal, or cached through the times-only protocol)
        # is re-run to fill the vector — but its cached time stays
        # authoritative, so seeded sweep times are never re-measured away
        missing = []
        for i, k in enumerate(keys):
            m = self.cache.get(k)
            if m is None or (m.valid
                             and any(n not in m.metrics for n in names)):
                missing.append(i)
        if missing:
            cols = self.inner.batch_eval_metrics(
                space, [cfgs[i] for i in missing], assume_valid=assume_valid)
            for j, i in enumerate(missing):
                t = float(cols[METRIC_TIME][j])
                vec = {n: float(cols[n][j]) for n in names}
                prev = self.cache.get(keys[i])
                if prev is None:
                    self.cache[keys[i]] = Measurement(t, t != PENALTY_TIME,
                                                      metrics=vec)
                    self.evaluations += 1
                else:   # upgrade: keep the seeded time, adopt fresh metrics
                    vec.update(prev.metrics)
                    self.cache[keys[i]] = Measurement(prev.time_s, prev.valid,
                                                      meta=prev.meta,
                                                      metrics=vec)
        out = {n: np.empty(len(cfgs), dtype=np.float64) for n in names}
        for i, k in enumerate(keys):
            m = self.cache[k]
            for n in names:
                out[n][i] = (m.metric(n, metric_penalty(n)) if m.valid
                             else metric_penalty(n))
        return out
