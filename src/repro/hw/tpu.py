"""TPU v5e machine model.

These constants drive (a) the analytical model-driven tuner's validity and
occupancy reasoning (core/analytical.py), (b) the TPU cost-model objective
(core/objective.py), and (c) the roofline accounting (launch/roofline.py).

The paper targets a Jetson TX1 (GM20B Maxwell); this module is the TPU v5e
replacement for its table of architectural limits (warps/SM, smem/block, ...).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    name: str = "tpu_v5e"
    # --- per-chip peak rates (assignment-specified constants) ---
    peak_bf16_flops: float = 197e12       # FLOP/s per chip, bf16 MXU
    peak_f32_flops: float = 98.5e12       # MXU f32 ~ half of bf16
    peak_vpu_flops: float = 3.2e12        # vector unit, elementwise f32
    hbm_bandwidth: float = 819e9          # B/s per chip
    ici_link_bandwidth: float = 50e9      # B/s per ICI link (assignment value)
    # --- memory hierarchy ---
    hbm_bytes: int = 16 * 2**30           # 16 GiB HBM per chip
    vmem_bytes: int = 128 * 2**20         # VMEM per core (v5e: 128 MiB shared
    #                                       scratch pool; we budget conservatively)
    vmem_budget: int = 64 * 2**20         # usable budget for kernel working sets
    # --- tiling geometry ---
    lane_count: int = 128                 # trailing VREG dim
    sublane_count: int = 8                # second-to-last VREG dim (f32)
    mxu_dim: int = 128                    # systolic array edge
    # --- pipeline model ---
    dma_latency_s: float = 2e-6           # per-block DMA issue latency
    kernel_launch_s: float = 5e-6         # fixed pallas_call overhead
    pass_sync_s: float = 1.5e-6           # per-pass barrier/scratch-flush cost
    # --- mesh geometry ---
    chips_per_pod: int = 256


V5E = TpuSpec()


def dtype_bytes(dtype) -> int:
    import numpy as np

    return np.dtype(dtype).itemsize


def effective_element_bytes(op: str, dtype) -> int:
    """Bytes one logical element of ``op`` moves through memory.

    Per-family multipliers over the raw dtype width: a tridiagonal element
    is an equation of 4 coefficients, an FFT element is an interleaved
    complex pair. The single source of truth for the analytical model, the
    cost objective, and the ML featurizer — which must agree, since the
    learned labels come from the cost model.
    """
    eb = dtype_bytes(dtype)
    if op == "tridiag":
        return 4 * eb
    if op in ("fft", "large_fft"):
        return 2 * eb
    return eb


def lane_utilization(trailing_dim: int, spec: TpuSpec = V5E) -> float:
    """Fraction of the 128-wide lane dim that does useful work.

    The analogue of warp occupancy in the paper's guideline: a trailing dim of
    96 wastes 25% of every VPU issue; a trailing dim of 384 is three full
    tiles -> 1.0.
    """
    lanes = spec.lane_count
    if trailing_dim <= 0:
        return 0.0
    if trailing_dim >= lanes:
        full, rem = divmod(trailing_dim, lanes)
        used = full * lanes + rem
        tiles = full + (1 if rem else 0)
        return used / (tiles * lanes)
    return trailing_dim / lanes


def sublane_utilization(second_dim: int, spec: TpuSpec = V5E) -> float:
    sub = spec.sublane_count
    if second_dim <= 0:
        return 0.0
    if second_dim >= sub:
        full, rem = divmod(second_dim, sub)
        tiles = full + (1 if rem else 0)
        return second_dim / (tiles * sub)
    return second_dim / sub


def dma_efficiency(block_bytes: int, spec: TpuSpec = V5E) -> float:
    """HBM bandwidth ramp: small DMAs underutilize the memory system.

    Saturates around 512 KiB transfers; modeled as b/(b+b_half) with
    b_half = 64 KiB (fit shape typical of TPU DMA engines).
    """
    b_half = 64 * 2**10
    return block_bytes / (block_bytes + b_half)


def ilp_factor(unroll: int) -> float:
    """Issue-pipeline utilization vs in-kernel ILP (the paper's premise iii).

    One node-op per step leaves VPU issue bubbles; saturates by ~8-way.
    """
    import math

    return min(1.0, 0.55 + 0.15 * math.log2(max(unroll, 1)))


# ---------------------------------------------------------------------------
# Vectorized counterparts (numpy arrays in, arrays out)
# ---------------------------------------------------------------------------
# The sweep engine evaluates whole candidate sets in a handful of array ops;
# these mirror the scalar functions above element-for-element so batched and
# per-config evaluation agree to floating-point identity.

def lane_utilization_arr(trailing_dim, spec: TpuSpec = V5E):
    import numpy as np

    t = np.asarray(trailing_dim, dtype=np.float64)
    lanes = float(spec.lane_count)
    full = np.floor(t / lanes)
    rem = t - full * lanes
    tiles = full + (rem > 0)
    multi = t / np.maximum(tiles * lanes, 1.0)
    out = np.where(t >= lanes, multi, t / lanes)
    return np.where(t <= 0, 0.0, out)


def sublane_utilization_arr(second_dim, spec: TpuSpec = V5E):
    import numpy as np

    s = np.asarray(second_dim, dtype=np.float64)
    sub = float(spec.sublane_count)
    full = np.floor(s / sub)
    rem = s - full * sub
    tiles = full + (rem > 0)
    multi = s / np.maximum(tiles * sub, 1.0)
    out = np.where(s >= sub, multi, s / sub)
    return np.where(s <= 0, 0.0, out)


def dma_efficiency_arr(block_bytes, spec: TpuSpec = V5E):
    import numpy as np

    b = np.trunc(np.asarray(block_bytes, dtype=np.float64))
    b_half = 64 * 2**10
    return b / (b + b_half)


def ilp_factor_arr(unroll):
    import numpy as np

    u = np.maximum(np.asarray(unroll, dtype=np.float64), 1.0)
    return np.minimum(1.0, 0.55 + 0.15 * np.log2(u))
