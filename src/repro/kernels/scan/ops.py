"""Tuned scan entry points (prefix sum + linear recurrence).

Every call resolves its configuration through the TuningDB (offline-tuned)
or the analytical model (online, zero evaluations) — the paper's deployment
flow. Shapes are normalized to (batch, n) rows; callers with higher-rank
arrays flatten leading dims.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import Workload, get_config
from repro.kernels.scan.kernel import scan_add_pallas, scan_linrec_pallas
from repro.kernels.scan.ref import scan_add_ref, scan_linrec_assoc_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _norm_cfg(cfg: dict, batch: int, n: int) -> dict:
    rows = max(min(cfg.get("rows_per_program", 8), batch), 1)
    while batch % rows:
        rows //= 2
    tile = max(min(cfg.get("tile_n", n), n), 1)
    while n % tile:
        tile //= 2
    return {"rows_per_program": max(rows, 1), "tile_n": max(tile, 1),
            "radix": cfg.get("radix", 2), "unroll": cfg.get("unroll", 1)}


def prefix_sum(x: jax.Array, variant: str = "ks",
               config: Optional[dict] = None,
               interpret: Optional[bool] = None,
               use_pallas: Optional[bool] = None) -> jax.Array:
    """Inclusive row-wise prefix sum with tuned blocking."""
    batch, n = x.shape
    if use_pallas is None:
        # default: Pallas on TPU, or when the caller explicitly asks for
        # interpret-mode validation; XLA reference path otherwise (CPU hosts
        # should not pay the interpret-mode python loop in production paths)
        use_pallas = (not _on_cpu()) or bool(interpret)
    if not use_pallas:
        return scan_add_ref(x)
    interpret = _on_cpu() if interpret is None else interpret
    cfg = _norm_cfg(config or get_config(
        Workload(op="scan", n=n, batch=batch, variant=variant)), batch, n)
    return scan_add_pallas(x, interpret=interpret, **cfg)


def linear_recurrence(a: jax.Array, b: jax.Array, variant: str = "ks",
                      config: Optional[dict] = None,
                      interpret: Optional[bool] = None,
                      use_pallas: Optional[bool] = None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t row-wise with tuned blocking.

    The workhorse behind RG-LRU layers and SSD inter-chunk state scans.
    """
    batch, n = a.shape
    if use_pallas is None:
        use_pallas = (not _on_cpu()) or bool(interpret)
    if not use_pallas:
        return scan_linrec_assoc_ref(a, b)
    interpret = _on_cpu() if interpret is None else interpret
    cfg = _norm_cfg(config or get_config(
        Workload(op="scan", n=n, batch=batch, variant=variant)), batch, n)
    return scan_linrec_pallas(a, b, interpret=interpret, **cfg)
