"""Removed. ``repro.core.tuner`` was a deprecated facade; it is gone.

Migration (see docs/tuning.md, "Migrating from the legacy facade"):

* ``get_config(wl)``      -> ``repro.tuning.TunerSession.resolve(wl)``
  (or ``resolve_raw`` for the pre-normalization config)
* ``tune_offline(wl,...)``-> ``repro.tuning.TunerSession.tune(wl, ...)``
* ``global_db()``         -> ``repro.tuning.default_session().db``
* ``TuningDB``            -> ``repro.tuning.db.TuningDB``
  (still re-exported as ``repro.core.TuningDB``)
"""
raise ImportError(
    "repro.core.tuner was removed: use repro.tuning "
    "(TunerSession.resolve / TunerSession.tune / default_session().db; "
    "TuningDB lives in repro.tuning.db) — see docs/tuning.md")
