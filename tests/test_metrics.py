"""Phi performance-portability metric properties (paper §VI)."""
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.metrics import efficiency, phi, phi_from_times


def test_perfect_match_is_one():
    assert phi([1.0, 1.0, 1.0]) == pytest.approx(1.0)


def test_known_value():
    # harmonic mean of (1, 0.5) = 2/(1+2) = 0.666...
    assert phi([1.0, 0.5]) == pytest.approx(2.0 / 3.0)


@given(st.lists(st.floats(min_value=0.05, max_value=1.0), min_size=1,
                max_size=16))
@settings(max_examples=50, deadline=None)
def test_phi_bounded_by_min_and_max(effs):
    v = phi(effs)
    assert min(effs) - 1e-9 <= v <= max(effs) + 1e-9


@given(st.lists(st.floats(min_value=0.05, max_value=1.0), min_size=2,
                max_size=8),
       st.integers(min_value=0, max_value=7))
@settings(max_examples=50, deadline=None)
def test_phi_monotone_in_each_coordinate(effs, idx):
    idx = idx % len(effs)
    lower = list(effs)
    lower[idx] = max(lower[idx] * 0.5, 0.01)
    assert phi(lower) <= phi(effs) + 1e-12


def test_phi_from_times():
    best = {128: 1.0, 256: 2.0}
    mine = {128: 1.0, 256: 4.0}      # eff = 1.0, 0.5
    assert phi_from_times(mine, best) == pytest.approx(2.0 / 3.0)
    with pytest.raises(ValueError):
        phi_from_times({128: 1.0}, best)


def test_efficiency_clamped():
    assert efficiency(2.0, 1.0) == pytest.approx(0.5)
    assert efficiency(0.5, 1.0) == 1.0      # can't beat the observed best
    with pytest.raises(ValueError):
        efficiency(-1.0, 1.0)
