"""Offline tuning CLI — populates the TuningDB (paper's offline flow).

  PYTHONPATH=src python -m repro.launch.tune --op scan --variant lf \
      --sizes 128,256,512 --method bayesian
  PYTHONPATH=src python -m repro.launch.tune --paper-suite   # all paper ops

Runs through a :class:`repro.tuning.TunerSession`; ``--db`` selects a
non-default store.
"""
from __future__ import annotations

import argparse
from typing import Optional

from repro.configs.paper_ops import PREFIX_OPS, TOTAL_ELEMS
from repro.core import TPUCostModelObjective, Workload
from repro.tuning import TunerSession, default_session, strategies


def tune_suite(method: str, noise: float = 0.02, verbose: bool = True,
               session: Optional[TunerSession] = None) -> None:
    session = session or default_session()
    for op, spec in PREFIX_OPS.items():
        for variant in spec["variants"]:
            for n in spec["sizes"]:
                wl = Workload(op=op, n=n, batch=max(TOTAL_ELEMS // n, 1),
                              variant=variant)
                res = session.tune(wl, method=method,
                                   objective=TPUCostModelObjective(noise=noise))
                if verbose:
                    print(f"[tune] {wl.key}: {res.best_config} "
                          f"t={res.best_time*1e6:.1f}us "
                          f"evals={res.evaluations}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--op", default=None)
    ap.add_argument("--variant", default="")
    ap.add_argument("--sizes", default="")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--method", default="bayesian", choices=list(strategies()))
    ap.add_argument("--db", default=None,
                    help="path to the tuning DB (default: the session DB)")
    ap.add_argument("--paper-suite", action="store_true")
    args = ap.parse_args()

    session = TunerSession(db_path=args.db) if args.db else default_session()
    if args.paper_suite:
        tune_suite(args.method, session=session)
        return
    assert args.op and args.sizes
    for n in [int(s) for s in args.sizes.split(",")]:
        wl = Workload(op=args.op, n=n,
                      batch=args.batch or max(TOTAL_ELEMS // n, 1),
                      variant=args.variant)
        res = session.tune(wl, method=args.method)
        print(f"[tune] {wl.key}: {res.best_config} "
              f"t={res.best_time*1e6:.1f}us evals={res.evaluations}")


if __name__ == "__main__":
    main()
