"""The three tuning methodologies + TuningDB (paper core behaviours)."""
import pytest

from repro.core import (AnalyticalTuner, BayesianTuner, CachedObjective,
                        ExhaustiveSearch, RandomSearch, TPUCostModelObjective,
                        TuningDB, Workload, build_space)
from repro.core.objective import PENALTY_TIME


def _space(n=512, batch=2**17, op="scan", variant="lf"):
    return build_space(Workload(op=op, n=n, batch=batch, variant=variant))


def test_analytical_returns_valid_config():
    for op, variant in [("scan", "lf"), ("tridiag", "wm"),
                        ("fft", "stockham"), ("attention", "flash")]:
        space = _space(op=op, variant=variant)
        cfg = AnalyticalTuner().suggest(space)
        assert space.is_valid(cfg)


def test_analytical_zero_evaluations():
    space = _space()
    obj = CachedObjective(TPUCostModelObjective())
    AnalyticalTuner().suggest(space)
    assert obj.evaluations == 0    # online methodology: no measurements


def test_exhaustive_finds_global_optimum():
    space = _space(n=256, batch=2**18)
    obj = CachedObjective(TPUCostModelObjective())
    res = ExhaustiveSearch().tune(space, obj)
    times = [obj(space, c).time_s for c in space.enumerate_valid()]
    assert res.best_time == pytest.approx(min(times))


def test_bayesian_beats_random_at_equal_budget():
    """Aggregate over several sizes/seeds: BO efficiency >= random's."""
    wins, total = 0, 0
    for n in [256, 512, 1024]:
        space = _space(n=n)
        ExhaustiveSearch().tune(
            space, CachedObjective(TPUCostModelObjective(noise=0.02)))
        for seed in range(3):
            bo = BayesianTuner(seed=seed, max_evals=20).tune(
                space, CachedObjective(TPUCostModelObjective(noise=0.02)))
            rnd = RandomSearch(max_evals=bo.evaluations, seed=seed).tune(
                space, CachedObjective(TPUCostModelObjective(noise=0.02)))
            wins += bo.best_time <= rnd.best_time + 1e-12
            total += 1
    assert wins >= total * 0.6


def test_bayesian_sliding_window_stop():
    space = _space(n=256)
    bo = BayesianTuner(seed=0, max_evals=1000, patience=5).tune(
        space, CachedObjective(TPUCostModelObjective()))
    assert bo.evaluations < space.size()
    assert bo.stopped_by in ("sliding_window", "exhausted")


def test_invalid_configs_get_penalty():
    space = _space(n=256)
    obj = TPUCostModelObjective()
    bad = {"tile_n": 999, "rows_per_program": 1, "radix": 2, "unroll": 1,
           "in_register": 0}
    m = obj(space, bad)
    assert not m.valid and m.time_s == PENALTY_TIME


def test_tuning_db_roundtrip(tmp_path):
    db = TuningDB(path=str(tmp_path / "db.json"))
    wl = Workload(op="scan", n=512, batch=1024, variant="lf")
    assert db.lookup(wl) is None
    db.store(wl, {"tile_n": 512}, 1e-4, "bayesian", 12)
    assert db.lookup(wl) == {"tile_n": 512}
    db2 = TuningDB(path=str(tmp_path / "db.json"))
    assert db2.lookup(wl) == {"tile_n": 512}   # persisted


def test_resolve_online_fallback(tmp_path):
    from repro.tuning import TunerSession
    db = TuningDB(path=str(tmp_path / "db.json"))
    wl = Workload(op="scan", n=256, batch=4096, variant="ks")
    cfg = TunerSession(db=db).resolve_raw(wl)  # miss -> analytical, instant
    assert build_space(wl).is_valid(cfg)


def test_session_tune_populates_db(tmp_path):
    from repro.tuning import TunerSession
    db = TuningDB(path=str(tmp_path / "db.json"))
    wl = Workload(op="fft", n=256, batch=2**18, variant="stockham")
    res = TunerSession(db=db).tune(wl, method="bayesian")
    assert db.lookup(wl) == res.best_config
    assert res.evaluations > 0
