"""StagePlan — the staged-execution planner shared by kernels, the
analytical model, and ML featurization.

BPLG's central idea is that FFT, scan and tridiagonal solvers are all
compositions of the *same* tuned CTA-level building blocks (radix-r
staging, layout shuffles, carry chaining).  The repo analogue: given
``(Workload, Config)`` this module produces the exact staged execution —
the per-stage radix sequence (with the mixed-radix ragged final stage),
the launch grid / block shapes / scratch, the per-stage VMEM bytes, and
the HBM pass count (== number of kernel launches the driver performs).

It is the single source of truth: the kernel drivers execute
``plan.launches`` verbatim, ``core.analytical.resources`` reads its
fields instead of re-deriving pass counts from knobs, and
``tuning.ml.features`` featurizes the same fields — so model and kernel
cannot silently disagree (tests/test_blocks_plan.py pins the agreement).

Composite ops (rglru's gate→linrec, SSD's intra→linrec→apply) are
*chains* of links: the ``fuse`` knob decides whether neighbouring links
share a launch (gate folded into the scan kernel's first stage, SSD
phase B + apply collapsed into one sequential-grid launch) or break at
the historical boundaries, each break costing a full HBM roundtrip.
``plan_for_chain`` exposes the per-link view (and, given the runtime
state dims a ``Workload`` cannot carry, the *exact* embedded launches);
``plan_for`` already folds the chain's pass accounting into the regular
``StagePlan``, so the analytical model and the featurizer price fusion
with no extra plumbing.

Deliberately pure Python (no jax import): the analytical tuner and the
numpy-only ML stack consume plans without pulling in the kernel runtime.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Dict, List, Mapping, Optional, Tuple

from repro.core.space import Workload, fit_block
from repro.hw.profiles import (HardwareProfile, active_profile, dtype_bytes,
                               effective_element_bytes, lane_utilization,
                               sublane_utilization)

# Column tiles a fused carry chain tolerates before the multi-pass driver
# (three launches, parallel across chunks) wins over serializing the grid's
# sequential dimension — the paper's §IV-C small/large-N boundary.
DEFAULT_SEQ_LIMIT = 64

# Variants whose in-kernel state is an (a, b) pair: three resident planes
# (two inputs + output) instead of two.
_LINREC_VARIANTS = ("linrec",)


# ---------------------------------------------------------------------------
# Mixed-radix stage decomposition
# ---------------------------------------------------------------------------

def _smallest_prime_factor(n: int) -> int:
    if n % 2 == 0:
        return 2
    f = 3
    while f * f <= n:
        if n % f == 0:
            return f
        f += 2
    return n


def stage_radices(n: int, radix: int) -> Tuple[int, ...]:
    """Per-stage fan-in sequence for an n-point staged circuit.

    Generalizes the FFT kernel's ``rr = min(radix, n_cur)`` and the scan
    kernel's ``_ks_levels``: each stage takes the preferred fan-in when it
    divides what is left, else the largest divisor <= radix (the ragged
    mixed-radix final stage), else the smallest prime factor.  Invariant
    (pinned by tests): ``prod(stage_radices(n, r)) == n`` for every n >= 1,
    so a stage loop driven by this sequence can never mis-reshape — unlike
    the historical per-kernel loops, which crashed whenever an intermediate
    ``n_cur`` stopped dividing by the radix (e.g. radix 8 at n = 96).
    """
    n = int(n)
    radix = max(int(radix), 2)
    out = []
    n_cur = n
    while n_cur > 1:
        rr = min(radix, n_cur)
        if n_cur % rr:
            divisors = [d for d in range(rr, 1, -1) if n_cur % d == 0]
            rr = divisors[0] if divisors else _smallest_prime_factor(n_cur)
        out.append(rr)
        n_cur //= rr
    return tuple(out)


def stage_strides(stages: Tuple[int, ...]) -> Tuple[int, ...]:
    """Input stride of each stage: cumulative product of earlier fan-ins."""
    strides = []
    s = 1
    for r in stages:
        strides.append(s)
        s *= r
    return tuple(strides)


def is_ragged(stages: Tuple[int, ...], nominal: int, span: int) -> bool:
    """Mixed-radix tail check shared by every plan builder.

    ``stage_radices`` only ever reduces the fan-in toward the tail, so a
    sequence is ragged exactly when its last stage falls short of the
    nominal fan-in (clamped by the circuit span for tiny tiles).  The
    analytical radix_rank and the ML ``ragged_tail`` feature both train
    on this flag — keep the definition in one place.
    """
    return bool(stages) and stages[-1] != min(nominal, span)


def resident_tile_cap(wl: Workload,
                      spec: Optional[HardwareProfile] = None) -> int:
    """Largest power-of-two tile whose double-buffered footprint fits VMEM
    with at least one problem row per program (paper §IV-C boundary)."""
    spec = spec if spec is not None else active_profile()
    eb = dtype_bytes(wl.dtype) * (2 if wl.op in ("fft", "large_fft") else 1)
    tile = 256
    while tile * 2 * eb * 2 <= spec.vmem_budget and tile * 2 <= wl.n:
        tile *= 2
    return tile


def wm_chunk(radix: int, n: int) -> int:
    """The Wang&Mou chunk implied by the tuned radix (paper: the fan-in).

    Lives here — not at the dispatch site — so the tridiag normalizer can
    put the derived chunk INTO the resolved config: what the TuningDB
    records then uniquely determines the executed kernel.
    """
    return fit_block(min(max(radix * 16, 8), max(n // 2, 1)), n)


# ---------------------------------------------------------------------------
# Plan dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Launch:
    """One kernel launch the driver will perform."""

    name: str                       # kernel family tag (display/debug)
    grid: Tuple[int, ...]           # pallas grid
    block_shape: Tuple[int, int]    # main operand block (rows, cols)
    stages: Tuple[int, ...]         # in-kernel stage radices
    vmem_bytes: int                 # resident io + scratch per program

    @property
    def programs(self) -> int:
        out = 1
        for g in self.grid:
            out *= g
        return out


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """The exact staged execution of one (workload, config) pair."""

    op: str
    variant: str
    n: int
    batch: int
    dtype: str
    kind: str                       # "fused" | "multipass" | "three-phase"
    #                                 (ssd unfused) | "two-phase" (ssd
    #                                 fused) | "xla"; dispatchers branch
    #                                 on == "multipass" only
    tile_n: int                     # elements resident per program
    rows: int                       # problem rows per program
    radix: int                      # nominal (tuned) fan-in
    stages: Tuple[int, ...]         # per-stage radices of the resident tile
    seq_tiles: int                  # sequential carry tiles per program
    grid: Tuple[int, ...]           # main-launch grid
    launches: Tuple[Launch, ...]    # every kernel launch, driver order
    passes: int                     # HBM roundtrips == len(launches) +
    #                                 xla_passes when pallas-backed; 1 for
    #                                 fused XLA variants
    vmem_bytes: int                 # peak resident io+scratch per program
    stage_vmem_bytes: Tuple[int, ...]   # transient footprint per stage
    block_bytes: int                # DMA block (analytical rank input)
    element_bytes: int              # effective bytes per logical element
    trailing: int                   # trailing-dim extent a VPU issue sees
    lane_eff: float                 # trailing-lane efficiency
    sublane_eff: float
    occupancy: float
    ilp: float
    ragged: bool                    # mixed-radix tail (last stage < radix)
    steps_per_pass: float
    # HBM passes performed by XLA-level chain links that are not pallas
    # launches (e.g. rglru's unfused elementwise gate): they cost a full
    # read+write roundtrip but never appear in ``launches``
    xla_passes: int = 0
    children: Tuple["StagePlan", ...] = ()

    @property
    def stage_count(self) -> int:
        return len(self.stages)

    @property
    def grid_size(self) -> int:
        out = 1
        for g in self.grid:
            out *= g
        return out

    def check(self, spec: HardwareProfile) -> List[str]:
        """Structural invariant violations of this plan ([] when sound).

        The zero-execution contract ``repro.analysis`` verifies for every
        valid config of every op x profile: a violation here means the
        planner would hand the drivers an execution that cannot launch
        (non-positive grid/block), mis-reshapes (stage product != tile),
        overflows the physical VMEM pool, or disagrees with its own pass
        accounting.  Checks live on the dataclass so plan builders and the
        analysis pass can never drift apart.
        """
        out: List[str] = []
        if self.tile_n < 1 or self.rows < 1:
            out.append(f"non-positive tile geometry: tile_n={self.tile_n} "
                       f"rows={self.rows}")
        if self.passes < 1:
            out.append(f"non-positive pass count: {self.passes}")
        if self.vmem_bytes <= 0 or self.steps_per_pass <= 0:
            out.append(f"non-positive accounting: vmem={self.vmem_bytes} "
                       f"steps_per_pass={self.steps_per_pass}")
        if self.stages:
            prod = 1
            for r in self.stages:
                prod *= r
            if prod != self.tile_n:
                out.append(f"stage radix product {prod} != tile_n "
                           f"{self.tile_n} (stages={self.stages})")
        if any(g < 1 for g in self.grid):
            out.append(f"non-positive grid dim: {self.grid}")
        if self.xla_passes < 0:
            out.append(f"negative xla_passes: {self.xla_passes}")
        if self.launches \
                and self.passes != len(self.launches) + self.xla_passes:
            out.append(f"passes={self.passes} disagrees with "
                       f"{len(self.launches)} launches "
                       f"+ {self.xla_passes} xla passes")
        for launch in self.launches:
            if any(g < 1 for g in launch.grid) \
                    or any(b < 1 for b in launch.block_shape):
                out.append(f"launch {launch.name}: non-positive shape "
                           f"grid={launch.grid} block={launch.block_shape}")
            if launch.vmem_bytes > spec.vmem_bytes:
                out.append(f"launch {launch.name}: vmem {launch.vmem_bytes} "
                           f"exceeds the physical pool {spec.vmem_bytes}")
            block = launch.block_shape[0] * launch.block_shape[1] \
                * self.element_bytes
            if launch.vmem_bytes < block:
                out.append(f"launch {launch.name}: scratch {launch.vmem_bytes}"
                           f" cannot hold its own BlockSpec block {block} "
                           f"({launch.block_shape} x {self.element_bytes}B)")
        if self.stage_vmem_bytes \
                and max(self.stage_vmem_bytes) > spec.vmem_bytes:
            out.append(f"stage vmem {max(self.stage_vmem_bytes)} exceeds "
                       f"the physical pool {spec.vmem_bytes}")
        return out

    def resources(self) -> Dict[str, float]:
        """Architectural accounting in the shape ``core.analytical`` scores.

        Every quantity is read off the plan — there is no independent
        re-derivation left in the analytical model or the featurizer.
        """
        return {
            "grid": float(self.grid_size),
            "vmem": float(self.vmem_bytes),
            "occupancy": min(self.occupancy, 1.0),
            "ilp": float(self.ilp),
            "radix": float(self.radix),
            "passes": float(self.passes),
            "block_bytes": float(self.block_bytes),
            "seq_tiles": float(self.seq_tiles),
            "stage_count": float(self.stage_count),
            "steps_per_pass": float(self.steps_per_pass),
            "ragged": 1.0 if self.ragged else 0.0,
            "lane_eff": float(self.lane_eff),
            "sublane_eff": float(self.sublane_eff),
        }


# ---------------------------------------------------------------------------
# Per-family builders
# ---------------------------------------------------------------------------

def _occ(tile_n: int, rows: int, spec: HardwareProfile) -> Tuple[int, float, float, float]:
    trailing = min(tile_n, spec.lane_count * spec.sublane_count)
    lane = lane_utilization(trailing, spec)
    sub = sublane_utilization(rows, spec)
    return trailing, lane, sub, lane * max(sub, 0.5)


def _is_linrec(wl: Workload) -> bool:
    return wl.op in ("rglru",) or wl.variant in _LINREC_VARIANTS


def _prefix_plan(wl: Workload, cfg: Mapping[str, int], spec: HardwareProfile,
                 seq_limit: int) -> StagePlan:
    eb = effective_element_bytes(wl.op, wl.dtype)
    ib = dtype_bytes(wl.dtype)
    batch = max(wl.batch, 1)
    tile_n = min(int(cfg.get("tile_n", wl.n)), wl.n)
    rows = int(cfg.get("rows_per_program", 1))
    radix = int(cfg.get("radix", 2))
    unroll = int(cfg.get("unroll", 1))
    stages = stage_radices(tile_n, radix)
    seq_tiles = max(wl.n // max(tile_n, 1), 1)
    # rglru is a gate→linrec chain: fused, the elementwise gate runs inside
    # the scan kernel's first stage (same launches, one fewer HBM pass);
    # unfused, the XLA gate materializes b = sqrt(1-a^2)*u through HBM —
    # one extra pass that never shows up as a pallas launch
    gate_xla = 1 if wl.op == "rglru" and not int(cfg.get("fuse", 0)) else 0
    planes = 3 if _is_linrec(wl) else 2          # (a, b) in + h out vs in + out
    carry = rows * 4                             # f32 cross-tile carry scratch
    io = planes * rows * tile_n * ib
    trailing, lane, sub, occ = _occ(tile_n, rows, spec)
    stage_vmem = tuple(io + carry + r * rows * tile_n * 4 for r in stages)
    ragged = is_ragged(stages, radix, tile_n)

    if seq_tiles > seq_limit and tile_n < wl.n:
        # §IV-C m-kernel path: per-chunk scan, chunk-carry scan, apply.
        p, length = seq_tiles, tile_n
        rows1 = fit_block(rows, batch * p)
        rows2 = fit_block(rows, batch)
        c_stages = stage_radices(p, radix)
        # linrec's chunk kernel (scan_linrec_prod_pallas) keeps a fourth
        # plane resident: the per-chunk prefix-products output the carry
        # scan composes
        l1_planes = planes + (1 if planes == 3 else 0)
        l1 = Launch("chunk-scan", (batch * p // rows1, 1), (rows1, length),
                    stages, l1_planes * rows1 * length * ib + rows1 * 4)
        l2 = Launch("carry-scan", (batch // rows2, 1), (rows2, p),
                    c_stages, planes * rows2 * p * ib + rows2 * 4)
        l3 = Launch("apply-entry", (batch * p // rows1,), (rows1, length),
                    (), (planes + 1) * rows1 * length * ib)
        launches = (l1, l2, l3)
        return StagePlan(
            op=wl.op, variant=wl.variant, n=wl.n, batch=batch, dtype=wl.dtype,
            kind="multipass", tile_n=tile_n, rows=rows, radix=radix,
            stages=stages, seq_tiles=seq_tiles, grid=l1.grid,
            launches=launches, passes=len(launches) + gate_xla,
            xla_passes=gate_xla,
            vmem_bytes=max(l.vmem_bytes for l in launches),
            stage_vmem_bytes=stage_vmem,
            block_bytes=rows * tile_n * eb, element_bytes=eb,
            trailing=trailing, lane_eff=lane, sublane_eff=sub, occupancy=occ,
            ilp=unroll * (2 if cfg.get("in_register") else 1), ragged=ragged,
            steps_per_pass=float(len(stages)))

    grid = (batch // rows, seq_tiles)
    launch = Launch(wl.op, grid, (rows, tile_n), stages, io + carry)
    return StagePlan(
        op=wl.op, variant=wl.variant, n=wl.n, batch=batch, dtype=wl.dtype,
        kind="fused", tile_n=tile_n, rows=rows, radix=radix, stages=stages,
        seq_tiles=seq_tiles, grid=grid, launches=(launch,),
        passes=1 + gate_xla, xla_passes=gate_xla,
        vmem_bytes=launch.vmem_bytes, stage_vmem_bytes=stage_vmem,
        block_bytes=rows * tile_n * eb, element_bytes=eb, trailing=trailing,
        lane_eff=lane, sublane_eff=sub, occupancy=occ,
        ilp=unroll * (2 if cfg.get("in_register") else 1), ragged=ragged,
        steps_per_pass=float(len(stages)))


def _ssd_plan(wl: Workload, cfg: Mapping[str, int], spec: HardwareProfile,
              seq_limit: int) -> StagePlan:
    """SSD chain: intra-chunk kernel → linrec over chunk transitions →
    apply.  Unfused, phase B is a child prefix plan on the shared blocks
    and the chain runs as three launches with HBM roundtrips between;
    ``fuse=1`` collapses phase B + apply into one sequential-grid launch
    whose VMEM carry holds the running (S, P) entry state — the chunk
    states feed the recurrence without ever leaving the core (two-phase).

    Model-level plan: the phase count and chunk staging are exact, but the
    state dims (S, P) are runtime shapes a ``Workload`` does not carry, so
    the unfused phase-B child models the nc-length transition scan per
    (batch) row, not the S*P row fan-out ``driver.linrec_rows`` resolves
    at launch.  ``plan_for_chain(wl, cfg, dims=(S, P))`` rebuilds the
    exact embedded launches for the conformance suite."""
    base = _prefix_plan(wl, cfg, spec, seq_limit)
    chunk = base.tile_n
    nc = max(wl.n // max(chunk, 1), 1)
    if nc <= 1:
        # single chunk: intra kernel alone already yields the answer
        return dataclasses.replace(base, kind="fused", seq_tiles=1)
    intra = Launch("ssd-intra", (base.batch, nc), (1, chunk), (),
                   base.vmem_bytes)
    if int(cfg.get("fuse", 0)):
        state_apply = Launch("ssd-state-apply", (base.batch, nc),
                             (1, chunk), (), base.vmem_bytes)
        launches = (intra, state_apply)
        return dataclasses.replace(
            base, kind="two-phase", seq_tiles=nc, launches=launches,
            passes=len(launches), children=())
    child = _prefix_plan(
        Workload(op="scan", n=nc, batch=base.batch, dtype=wl.dtype,
                 variant="linrec"),
        {"tile_n": nc, "rows_per_program": 1,
         "radix": cfg.get("radix", 2)}, spec, seq_limit)
    apply_ = Launch("ssd-apply", (base.batch, nc), (1, chunk), (),
                    base.vmem_bytes)
    launches = (intra,) + child.launches + (apply_,)
    return dataclasses.replace(
        base, kind="three-phase", seq_tiles=nc, launches=launches,
        passes=len(launches), children=(child,))


def _tridiag_plan(wl: Workload, cfg: Mapping[str, int], spec: HardwareProfile
                  ) -> StagePlan:
    eb = effective_element_bytes(wl.op, wl.dtype)        # 4 coefficients
    ib = dtype_bytes(wl.dtype)
    batch = max(wl.batch, 1)
    rows = int(cfg.get("rows_per_program", 1))
    radix = int(cfg.get("radix", 2))
    n = wl.n
    trailing, lane, sub, occ = _occ(n, rows, spec)
    ilp = int(cfg.get("unroll", 1)) * (2 if cfg.get("in_register") else 1)

    if wl.variant == "pcr":
        steps = max(1, math.ceil(math.log2(max(n, 2))))
        stages = (2,) * steps
        io = 5 * rows * n * ib                 # a,b,c,d in + x out
        grid = (batch // rows,)
        launch = Launch("pcr", grid, (rows, n), stages, io)
        return StagePlan(
            op=wl.op, variant=wl.variant, n=n, batch=batch, dtype=wl.dtype,
            kind="fused", tile_n=n, rows=rows, radix=2, stages=stages,
            seq_tiles=1, grid=grid, launches=(launch,), passes=1,
            vmem_bytes=io,
            stage_vmem_bytes=tuple(io + 2 * rows * n * 4 for _ in stages),
            block_bytes=rows * n * eb, element_bytes=eb, trailing=trailing,
            lane_eff=lane, sublane_eff=sub, occupancy=occ, ilp=ilp,
            ragged=False, steps_per_pass=float(steps))

    # XLA-fused variants (cr / lf / wm / thomas): no pallas launches; the
    # logical circuit still has a stage structure the models consume
    # (for wm the nominal fan-in is the tuned radix; cr/lf/thomas halve).
    nominal = radix if wl.variant == "wm" else 2
    stages = stage_radices(n, nominal)
    vmem = rows * n * eb * 2                    # double-buffered row estimate
    ragged = is_ragged(stages, nominal, n)
    return StagePlan(
        op=wl.op, variant=wl.variant, n=n, batch=batch, dtype=wl.dtype,
        kind="xla", tile_n=n, rows=rows, radix=radix, stages=stages,
        seq_tiles=1, grid=(batch // max(rows, 1),), launches=(), passes=1,
        vmem_bytes=vmem, stage_vmem_bytes=tuple(vmem for _ in stages),
        block_bytes=rows * n * eb, element_bytes=eb, trailing=trailing,
        lane_eff=lane, sublane_eff=sub, occupancy=occ, ilp=ilp,
        ragged=ragged, steps_per_pass=float(max(len(stages), 1)))


def _fft_fused_plan(wl: Workload, cfg: Mapping[str, int], spec: HardwareProfile
                    ) -> StagePlan:
    eb = effective_element_bytes("fft", wl.dtype)        # interleaved re/im
    batch = max(wl.batch, 1)
    rows = fit_block(int(cfg.get("rows_per_program", 4)), batch)
    radix = int(cfg.get("radix", 2))
    n = wl.n
    stages = stage_radices(n, radix)
    io = 4 * rows * n * 4                      # re/im in + re/im out, f32
    trailing, lane, sub, occ = _occ(n, rows, spec)
    grid = (batch // rows,)
    launch = Launch("fft", grid, (rows, n), stages, io)
    return StagePlan(
        op="fft", variant=wl.variant, n=n, batch=batch, dtype=wl.dtype,
        kind="fused", tile_n=n, rows=rows, radix=radix, stages=stages,
        seq_tiles=1, grid=grid, launches=(launch,), passes=1, vmem_bytes=io,
        stage_vmem_bytes=tuple(io + 2 * r * rows * (n // max(r, 1)) * 4
                               for r in stages),
        block_bytes=rows * n * eb, element_bytes=eb, trailing=trailing,
        lane_eff=lane, sublane_eff=sub, occupancy=occ,
        ilp=int(cfg.get("unroll", 1)), ragged=is_ragged(stages, radix, n),
        steps_per_pass=float(len(stages)))


def _large_fft_plan(wl: Workload, cfg: Mapping[str, int], spec: HardwareProfile,
                    seq_limit: int, max_tile: Optional[int]) -> StagePlan:
    """Four-step decomposition N = n1*n2 (paper §IV-C), recursive.

    Column FFTs (length n2) and row FFTs (length n1) are child plans; the
    launch list is their concatenation, so ``passes`` counts exactly the
    kernel launches the driver performs (m = 2, or 3 when the column side
    recurses — the paper's N >= 2^19 case on its 48KB-tile device).
    """
    cap = max_tile if max_tile is not None else resident_tile_cap(wl, spec)
    batch = max(wl.batch, 1)
    n = wl.n
    n1 = fit_block(min(int(cfg.get("tile_n", cap)), cap), n)
    n2 = max(n // n1, 1)
    sub_cfg = dict(cfg)
    sub_cfg["tile_n"] = n1
    col_wl = Workload(op="fft" if n2 <= cap else "large_fft", n=n2,
                      batch=batch * n1, dtype=wl.dtype, variant=wl.variant)
    col = build_plan(col_wl, sub_cfg, profile=spec, seq_limit=seq_limit,
                     max_tile=cap)
    row = _fft_fused_plan(
        Workload(op="fft", n=n1, batch=batch * n2, dtype=wl.dtype,
                 variant=wl.variant), sub_cfg, spec)
    launches = col.launches + row.launches
    return StagePlan(
        op=wl.op, variant=wl.variant, n=n, batch=batch, dtype=wl.dtype,
        kind="multipass", tile_n=n1, rows=row.rows, radix=row.radix,
        stages=row.stages, seq_tiles=1, grid=row.grid, launches=launches,
        passes=len(launches), vmem_bytes=max(p.vmem_bytes for p in (col, row)),
        stage_vmem_bytes=row.stage_vmem_bytes, block_bytes=row.block_bytes,
        element_bytes=row.element_bytes, trailing=row.trailing,
        lane_eff=row.lane_eff, sublane_eff=row.sublane_eff,
        occupancy=row.occupancy, ilp=row.ilp, ragged=row.ragged,
        steps_per_pass=row.steps_per_pass, children=(col, row))


def _attention_plan(wl: Workload, cfg: Mapping[str, int], spec: HardwareProfile
                    ) -> StagePlan:
    batch = max(wl.batch, 1)
    eb = effective_element_bytes(wl.op, wl.dtype)
    bq = int(cfg.get("block_q", 128))
    bk = int(cfg.get("block_k", 128))
    grid = (batch * max(wl.n // bq, 1),)
    vmem = (bq + 2 * bk) * 128 * eb * 2
    steps = max(wl.n // bk, 1)
    return StagePlan(
        op=wl.op, variant=wl.variant, n=wl.n, batch=batch, dtype=wl.dtype,
        kind="fused", tile_n=bk, rows=bq, radix=2, stages=(),
        seq_tiles=steps, grid=grid, launches=(), passes=1, vmem_bytes=vmem,
        stage_vmem_bytes=(), block_bytes=vmem // 2, element_bytes=eb,
        trailing=bk, lane_eff=lane_utilization(bk, spec),
        sublane_eff=sublane_utilization(bq, spec),
        occupancy=lane_utilization(bk, spec),
        # the flash kernel has no unroll knob (its inner loop IS the
        # block_k walk), so the plan must not report phantom ILP from one
        ilp=1, ragged=False,
        steps_per_pass=float(steps))


def _matmul_plan(wl: Workload, cfg: Mapping[str, int], spec: HardwareProfile
                 ) -> StagePlan:
    batch = max(wl.batch, 1)
    eb = effective_element_bytes(wl.op, wl.dtype)
    bm = int(cfg.get("block_m", 128))
    bn = int(cfg.get("block_n", 128))
    bk = int(cfg.get("block_k", 128))
    grid = (max(batch // bm, 1), max(wl.n // bn, 1))
    vmem = (bm * bk + bk * bn) * eb * 2
    occ = min(bn / spec.mxu_dim, 1.0) * min(bm / spec.mxu_dim, 1.0)
    steps = max(wl.n // bk, 1)
    return StagePlan(
        op=wl.op, variant=wl.variant, n=wl.n, batch=batch, dtype=wl.dtype,
        kind="fused", tile_n=bn, rows=bm, radix=2, stages=(),
        seq_tiles=steps, grid=grid, launches=(), passes=1, vmem_bytes=vmem,
        stage_vmem_bytes=(), block_bytes=vmem // 2, element_bytes=eb,
        trailing=bn, lane_eff=lane_utilization(bn, spec),
        sublane_eff=sublane_utilization(bm, spec), occupancy=occ,
        ilp=bk // 128 or 1, ragged=False, steps_per_pass=float(steps))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _resolve_profile(profile: Optional[HardwareProfile],
                     spec: Optional[HardwareProfile]) -> HardwareProfile:
    """Canonical ``profile=`` with the deprecated ``spec=`` alias."""
    if spec is not None:
        warnings.warn("spec=... is deprecated; pass profile=...",
                      DeprecationWarning, stacklevel=3)
        if profile is None:
            profile = spec
    return profile if profile is not None else active_profile()


def build_plan(wl: Workload, cfg: Mapping[str, int], *,
               profile: Optional[HardwareProfile] = None,
               spec: Optional[HardwareProfile] = None,
               seq_limit: int = DEFAULT_SEQ_LIMIT,
               max_tile: Optional[int] = None) -> StagePlan:
    """The staged execution of ``cfg`` on ``wl`` (uncached; see plan_for).

    ``profile`` is the canonical device argument; ``spec=`` is a
    deprecated alias from the pre-policy API.
    """
    wl = wl.canonical()
    spec = _resolve_profile(profile, spec)
    if wl.op in ("scan", "ssd", "rglru"):
        if wl.op == "ssd":
            return _ssd_plan(wl, cfg, spec, seq_limit)
        return _prefix_plan(wl, cfg, spec, seq_limit)
    if wl.op == "tridiag":
        return _tridiag_plan(wl, cfg, spec)
    if wl.op == "fft":
        return _fft_fused_plan(wl, cfg, spec)
    if wl.op == "large_fft":
        return _large_fft_plan(wl, cfg, spec, seq_limit, max_tile)
    if wl.op == "attention":
        return _attention_plan(wl, cfg, spec)
    if wl.op == "matmul":
        return _matmul_plan(wl, cfg, spec)
    # unknown op: a degenerate single-launch plan keeps generic consumers
    # (featurizer, analytical tiering) total rather than raising
    return _prefix_plan(wl, cfg, spec, seq_limit)


# ---------------------------------------------------------------------------
# Chain planning: sequences of ops as one staged execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChainLink:
    """One op of a chain and what executing it costs.

    ``kind`` records where the link's work happens: ``"pallas"`` links own
    the launches in ``launches``; ``"xla"`` links run as XLA ops costing
    ``passes`` HBM roundtrips with no pallas launch; ``"fused"`` links are
    folded into a neighbouring link's launch (zero launches, zero passes
    of their own — the whole point of the ``fuse`` knob).
    """

    name: str                       # link tag ("gate", "linrec", "intra"...)
    kind: str                       # "pallas" | "xla" | "fused"
    launches: Tuple[Launch, ...]    # launches this link issues itself
    passes: int                     # HBM roundtrips this link costs
    plan: Optional[StagePlan] = None   # the link's own plan when it has one


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """A sequence of ops planned as one staged execution.

    ``plan`` is the flattened :class:`StagePlan` (what ``resources()``,
    the analytical model and the featurizer consume — built by
    ``plan_for`` with the same config); ``links`` is the per-op view the
    drivers dispatch from.  ``launches`` concatenates the links' launch
    lists in driver order — the conformance contract is that a
    ``capture_launches`` trace of the chain's execution equals it.
    """

    op: str
    links: Tuple[ChainLink, ...]
    plan: StagePlan

    @property
    def launches(self) -> Tuple[Launch, ...]:
        return tuple(l for link in self.links for l in link.launches)

    @property
    def passes(self) -> int:
        return sum(link.passes for link in self.links)

    def check(self, spec: HardwareProfile) -> List[str]:
        """Chain-level violations on top of the flattened plan's own."""
        out = self.plan.check(spec)
        if self.passes != self.plan.passes:
            out.append(f"chain passes {self.passes} disagree with the "
                       f"flattened plan's {self.plan.passes}")
        for link in self.links:
            if link.kind == "fused" and (link.launches or link.passes):
                out.append(f"link {link.name}: fused links own no launches "
                           f"or passes")
            if link.kind == "pallas" and link.passes != len(link.launches):
                out.append(f"link {link.name}: {link.passes} passes vs "
                           f"{len(link.launches)} launches")
        return out


def _rglru_chain(wl: Workload, cfg: Mapping[str, int], plan: StagePlan
                 ) -> ChainPlan:
    fused = bool(int(cfg.get("fuse", 0)))
    gate = ChainLink("gate", "fused" if fused else "xla", (), 0 if fused
                     else 1)
    linrec = ChainLink("linrec", "pallas", plan.launches,
                       len(plan.launches), plan=plan)
    return ChainPlan(op=wl.op, links=(gate, linrec), plan=plan)


def _ssd_chain(wl: Workload, cfg: Mapping[str, int], plan: StagePlan,
               spec: HardwareProfile, seq_limit: int,
               dims: Optional[Tuple[int, int]]) -> ChainPlan:
    if plan.kind == "fused":            # nc <= 1: intra kernel alone
        intra = ChainLink("intra", "pallas", plan.launches,
                          len(plan.launches), plan=plan)
        return ChainPlan(op=wl.op, links=(intra,), plan=plan)
    nc = plan.seq_tiles
    intra = ChainLink("intra", "pallas", plan.launches[:1], 1)
    if plan.kind == "two-phase":
        # phase B + apply share the sequential state-apply launch: the
        # linrec link's carry lives in that launch's VMEM scratch
        linrec = ChainLink("linrec", "fused", (), 0)
        apply_ = ChainLink("apply", "pallas", plan.launches[1:], 1)
        return ChainPlan(op=wl.op, links=(intra, linrec, apply_), plan=plan)
    # unfused: phase B is the embedded linrec block.  With the runtime
    # state dims the embedded plan is exact — the (S, P) fan-out
    # ``driver.linrec_rows`` resolves at launch; without them, fall back
    # to the flattened plan's model-level child.
    if dims is not None and _linrec_space_valid_model(nc):
        s, p = dims
        embed_batch = plan.batch * s * p
        embed_wl = Workload(op="scan", n=nc, batch=embed_batch,
                            dtype="float32", variant="linrec")
        # mirror the scan normalizer's defaults for the threaded config
        # ({"tile_n": nc, "radix": cfg radix}): rows fit from the default 8
        embed_cfg = {"tile_n": nc,
                     "rows_per_program": fit_block(8, embed_batch),
                     "radix": int(cfg.get("radix", 2))}
        child = build_plan(embed_wl, embed_cfg, profile=spec,
                           seq_limit=seq_limit)
        linrec = ChainLink("linrec", "pallas", child.launches,
                           len(child.launches), plan=child)
    elif dims is not None:
        # odd nc: the embedded block falls back to the XLA reference
        linrec = ChainLink("linrec", "xla", (), 1)
    else:
        child = plan.children[0] if plan.children else None
        launches = child.launches if child is not None else ()
        linrec = ChainLink("linrec", "pallas", launches, len(launches),
                           plan=child)
    apply_ = ChainLink("apply", "pallas", plan.launches[-1:], 1)
    chain_plan = plan
    if dims is not None:
        # re-flatten around the exact embedded launches so chain-level
        # pass accounting stays consistent (launch count can only match)
        launches = plan.launches[:1] + linrec.launches + plan.launches[-1:]
        chain_plan = dataclasses.replace(
            plan, launches=launches, passes=len(launches) + plan.xla_passes
            + (1 if linrec.kind == "xla" else 0),
            xla_passes=plan.xla_passes + (1 if linrec.kind == "xla" else 0),
            children=(linrec.plan,) if linrec.plan is not None else ())
    return ChainPlan(op=wl.op, links=(intra, linrec, apply_),
                     plan=chain_plan)


def _linrec_space_valid_model(n: int) -> bool:
    """Planner-side mirror of ``driver._linrec_space_valid`` (kept here so
    the pure-Python planner never imports the jax-backed driver)."""
    return n >= 2 and n % 2 == 0


def plan_for_chain(wl: Workload, cfg: Mapping[str, int], *,
                   dims: Optional[Tuple[int, int]] = None,
                   profile: Optional[HardwareProfile] = None,
                   seq_limit: int = DEFAULT_SEQ_LIMIT) -> ChainPlan:
    """Plan ``wl``'s op — a chain for composite ops — as one staged
    execution.

    For ``rglru`` the chain is gate→linrec; for ``ssd`` it is
    intra→linrec→apply, and passing the runtime state dims ``dims=(S, P)``
    makes the embedded phase-B launches exact (a ``capture_launches``
    trace of the executed chain equals ``chain.launches``).  Every other
    op is a single-link chain around its regular ``plan_for`` plan.
    """
    wl = wl.canonical()
    spec = _resolve_profile(profile, None)
    plan = plan_for(wl, cfg, profile=spec, seq_limit=seq_limit)
    if wl.op == "rglru":
        return _rglru_chain(wl, cfg, plan)
    if wl.op == "ssd":
        return _ssd_chain(wl, cfg, plan, spec, seq_limit, dims)
    link = ChainLink(wl.op or "op", "pallas" if plan.launches else "xla",
                     plan.launches, plan.passes, plan=plan)
    return ChainPlan(op=wl.op, links=(link,), plan=plan)


@functools.lru_cache(maxsize=65536)
def _plan_cached(op: str, variant: str, n: int, batch: int, dtype: str,
                 cfg_items: Tuple[Tuple[str, int], ...], spec: HardwareProfile,
                 seq_limit: int, max_tile: Optional[int]) -> StagePlan:
    wl = Workload(op=op, n=n, batch=batch, dtype=dtype, variant=variant)
    return build_plan(wl, dict(cfg_items), profile=spec, seq_limit=seq_limit,
                      max_tile=max_tile)


def plan_for(wl: Workload, cfg: Mapping[str, int], *,
             profile: Optional[HardwareProfile] = None,
             spec: Optional[HardwareProfile] = None,
             seq_limit: int = DEFAULT_SEQ_LIMIT,
             max_tile: Optional[int] = None) -> StagePlan:
    """Memoized ``build_plan`` — the resolve/dispatch hot path and the
    featurizer hit the same plan thousands of times per space.

    ``profile`` is the canonical device argument; ``spec=`` is a
    deprecated alias from the pre-policy API.
    """
    wl = wl.canonical()
    spec = _resolve_profile(profile, spec)
    return _plan_cached(wl.op, wl.variant, wl.n, wl.batch, wl.dtype,
                        tuple(sorted(cfg.items())), spec, seq_limit, max_tile)
