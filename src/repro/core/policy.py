"""Policies — how a session turns a metric vector into "better".

Vector objectives (``repro.core.objective``) answer *what happened*:
``time_s``, ``energy_j``, ``peak_vmem_bytes`` per config.  A
:class:`Policy` answers *what to optimize*: it scalarizes the vector into
the lower-is-better number every search strategy, journal consumer, and
DB ranking already speaks.  Four policies ship (the embedded-deployment
axes from the paper's setting; see docs/tuning.md):

* ``latency``    — minimize ``time_s`` (the historical behavior, and the
  default everywhere: with it, nothing in the stack changes numerically);
* ``energy``     — minimize ``energy_j`` (modeled joules; falls back to
  ``time_s`` for objectives that emit no energy axis, e.g. wallclock);
* ``edp``        — minimize the energy-delay product ``energy_j * time_s``
  (the classic balanced metric for embedded parts);
* ``memory_cap`` — minimize ``time_s`` subject to
  ``peak_vmem_bytes <= cap`` (over-cap configs are penalty-clamped; the
  cap defaults to the profile's ``vmem_budget``).

:class:`PolicyObjective` adapts any vector objective to the scalar
protocol under a policy, so Bayesian/random/ML/online searches tune for
energy without knowing energy exists.  ``pareto_front`` computes the
non-dominated set over metric columns — the sweep engine journals one
front per (workload, objective) and every policy picks its winner from
the same measurements (see ``repro.tuning.sweep``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.objective import (METRIC_ENERGY, METRIC_PEAK_VMEM,
                                  METRIC_TIME, Measurement, Objective,
                                  PENALTY_TIME, metric_penalty)
from repro.core.space import Config, SearchSpace
from repro.hw.profiles import HardwareProfile, active_profile

POLICY_NAMES = ("latency", "energy", "edp", "memory_cap")


@dataclasses.dataclass(frozen=True)
class Policy:
    """One scalarization of the metric vector; frozen, hashable, keyable."""

    name: str                       # one of POLICY_NAMES
    cap_bytes: Optional[float] = None   # memory_cap's budget, else None

    @property
    def key(self) -> str:
        """Stable identity for DB keys and journal/objective signatures."""
        if self.name == "memory_cap" and self.cap_bytes is not None:
            return f"memory_cap[{int(self.cap_bytes)}]"
        return self.name

    @property
    def prune_safe(self) -> bool:
        """Whether analytical-dominance pruning may precede this policy.

        The pruning model ranks candidates by *latency*; keeping its top-k
        and then optimizing a different axis would silently search the
        wrong subset.  Only ``latency`` itself is safe.
        """
        return self.name == "latency"

    # -- scalarization -------------------------------------------------------
    # Scalar and column forms mirror each other element-for-element (same
    # double-precision expressions), so per-config and batched policy
    # evaluation agree to floating-point identity — the same contract the
    # objectives keep between __call__ and batch_eval.

    def scalarize(self, metrics: Mapping[str, float]) -> float:
        """Lower-is-better scalar for one metric vector.

        Missing axes fall back to ``time_s`` (a time-only measurement under
        the ``energy`` policy ranks by time); an over-cap ``memory_cap``
        vector returns ``inf`` — callers clamp non-finite scalars to the
        penalty (see :class:`PolicyObjective`).
        """
        t = float(metrics[METRIC_TIME])
        if self.name == "latency":
            return t
        if self.name == "energy":
            return self._axis(metrics, METRIC_ENERGY, t)
        if self.name == "edp":
            return t * self._axis(metrics, METRIC_ENERGY, t)
        if self.name == "memory_cap":
            vmem = self._axis(metrics, METRIC_PEAK_VMEM, 0.0)
            cap = self.cap_bytes if self.cap_bytes is not None else math.inf
            return t if vmem <= cap else math.inf
        raise ValueError(f"unknown policy {self.name!r}")

    def scalarize_cols(self, cols: Mapping[str, np.ndarray]) -> np.ndarray:
        """Column form of ``scalarize`` (NaN axes fall back per-row)."""
        t = np.asarray(cols[METRIC_TIME], dtype=np.float64)
        if self.name == "latency":
            return t
        if self.name == "energy":
            return self._axis_col(cols, METRIC_ENERGY, t)
        if self.name == "edp":
            return t * self._axis_col(cols, METRIC_ENERGY, t)
        if self.name == "memory_cap":
            vmem = self._axis_col(cols, METRIC_PEAK_VMEM, np.zeros_like(t))
            cap = self.cap_bytes if self.cap_bytes is not None else math.inf
            return np.where(vmem <= cap, t, np.inf)
        raise ValueError(f"unknown policy {self.name!r}")

    @staticmethod
    def _axis(metrics: Mapping[str, float], name: str, fallback: float) -> float:
        v = metrics.get(name)
        return fallback if v is None or (isinstance(v, float) and math.isnan(v)) \
            else float(v)

    @staticmethod
    def _axis_col(cols: Mapping[str, np.ndarray], name: str,
                  fallback: np.ndarray) -> np.ndarray:
        v = cols.get(name)
        if v is None:
            return fallback
        v = np.asarray(v, dtype=np.float64)
        return np.where(np.isnan(v), fallback, v)


def get_policy(policy: Union[str, Policy, None],
               profile: Optional[HardwareProfile] = None) -> Policy:
    """Resolve a policy name (or pass a Policy through).

    ``memory_cap`` needs a byte budget: an explicit ``memory_cap:<bytes>``
    suffix wins, else the profile's ``vmem_budget`` (the active profile
    when none is given) — so the cap is always concrete.
    """
    if policy is None:
        return Policy("latency")
    if isinstance(policy, Policy):
        return policy
    name = str(policy)
    cap: Optional[float] = None
    if ":" in name:
        name, _, cap_s = name.partition(":")
        cap = float(cap_s)
    if name not in POLICY_NAMES:
        raise ValueError(f"unknown policy {name!r}; known: "
                         f"{', '.join(POLICY_NAMES)}")
    if name == "memory_cap" and cap is None:
        prof = profile if profile is not None else active_profile()
        cap = float(prof.vmem_budget)
    return Policy(name, cap if name == "memory_cap" else None)


def policies() -> Tuple[str, ...]:
    return POLICY_NAMES


def policy_scalar_cols(policy: Policy,
                       cols: Mapping[str, np.ndarray]) -> np.ndarray:
    """Penalty-clamped policy scalars for metric columns.

    Rows the batched protocol marks failed (``time_s`` at the exact
    penalty clamp) and rows whose scalar is non-finite (over-cap under
    ``memory_cap``) come back as ``PENALTY_TIME`` — matching what the
    scalar :class:`PolicyObjective` path reports for them.
    """
    s = policy.scalarize_cols(cols)
    t = np.asarray(cols[METRIC_TIME], dtype=np.float64)
    return np.where(np.isfinite(s) & (t != PENALTY_TIME), s, PENALTY_TIME)


# ---------------------------------------------------------------------------
# Pareto front
# ---------------------------------------------------------------------------

def pareto_mask(cols: Mapping[str, np.ndarray],
                names: Optional[Sequence[str]] = None) -> np.ndarray:
    """Boolean mask of the non-dominated rows (all metrics lower-is-better).

    A row is dominated when another row is <= on every axis and < on at
    least one.  Ties on every axis keep both rows (duplicate configs on
    the front are real alternatives).  Failed rows (penalty-clamped time)
    are excluded up front — they lose on every axis by construction.
    """
    names = tuple(names) if names is not None else tuple(cols)
    t = np.asarray(cols[METRIC_TIME], dtype=np.float64)
    mat = np.stack([np.asarray(cols[n], dtype=np.float64) for n in names],
                   axis=1)
    keep = t != PENALTY_TIME
    for i in np.flatnonzero(keep):
        if not keep[i]:
            continue
        le = np.all(mat <= mat[i], axis=1)
        lt = np.any(mat < mat[i], axis=1)
        if np.any(le & lt & keep):
            keep[i] = False
        else:
            # i dominates these rows; dropping them now shrinks later scans
            keep &= ~(np.all(mat >= mat[i], axis=1)
                      & np.any(mat > mat[i], axis=1))
    return keep


def pareto_front(cols: Mapping[str, np.ndarray], cfgs: Sequence[Config],
                 names: Optional[Sequence[str]] = None
                 ) -> Tuple[Tuple[Config, Dict[str, float]], ...]:
    """(config, metric-vector) tuples for the non-dominated set."""
    names = tuple(names) if names is not None else tuple(cols)
    mask = pareto_mask(cols, names)
    return tuple((cfgs[i], {n: float(cols[n][i]) for n in names})
                 for i in np.flatnonzero(mask))


# ---------------------------------------------------------------------------
# PolicyObjective
# ---------------------------------------------------------------------------

class PolicyObjective(Objective):
    """A vector objective scalarized under a policy.

    The adapter that lets every existing search strategy optimize any
    policy: ``__call__`` returns a Measurement whose ``time_s`` IS the
    policy scalar (the full metric vector rides along in ``metrics``), and
    ``batch_eval`` scalarizes the inner ``batch_eval_metrics`` columns.
    Under ``latency`` the scalar equals the measured time exactly, so
    wrapping is a numeric no-op.

    The signature appends ``|policy=<key>`` — a journal of policy scalars
    can never be resumed as raw times (or vice versa).
    """

    def __init__(self, inner: Objective, policy: Union[str, Policy]):
        self.inner = inner
        self.policy = get_policy(policy, getattr(inner, "spec", None))

    @property
    def spec(self) -> Optional[HardwareProfile]:
        return getattr(self.inner, "spec", None)

    def metric_names(self) -> Tuple[str, ...]:
        return self.inner.metric_names()

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        m = self.inner(space, cfg)
        if not m.valid:
            return Measurement(PENALTY_TIME, False, meta=dict(m.meta))
        s = self.policy.scalarize(m.metrics)
        if not math.isfinite(s):    # e.g. over the memory_cap budget
            return Measurement(PENALTY_TIME, False, meta=dict(m.meta),
                               metrics=dict(m.metrics))
        out = Measurement(s, True, meta=dict(m.meta), metrics=dict(m.metrics))
        # __post_init__ mirrors time_s (the policy scalar) into the vector;
        # restore the real seconds so the metric axes stay truthful
        out.metrics[METRIC_TIME] = m.time_s
        return out

    def batch_eval(self, space: SearchSpace, cfgs: Sequence[Config], *,
                   assume_valid: bool = False) -> np.ndarray:
        cols = self.inner.batch_eval_metrics(space, cfgs,
                                             assume_valid=assume_valid)
        return policy_scalar_cols(self.policy, cols)

    def batch_eval_metrics(self, space: SearchSpace, cfgs: Sequence[Config],
                           *, assume_valid: bool = False
                           ) -> Dict[str, np.ndarray]:
        cols = self.inner.batch_eval_metrics(space, cfgs,
                                             assume_valid=assume_valid)
        # mirror __call__: a config the policy rejects outright (non-finite
        # scalar, e.g. over the memory_cap budget) is a failed measurement —
        # it reports the penalty on EVERY axis, not its raw numbers
        s = self.policy.scalarize_cols(cols)
        bad = ~np.isfinite(s)
        if np.any(bad):
            cols = {n: np.where(bad, metric_penalty(n), v)
                    for n, v in cols.items()}
        return cols

    def signature(self) -> str:
        return f"{self.inner.signature()}|policy={self.policy.key}"
