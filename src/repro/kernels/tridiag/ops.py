"""Tridiagonal solver library: PCR (Pallas), CR, LF, WM (+ Thomas baseline).

The four parallel variants mirror the BPLG solver family (paper §III):
  pcr — Parallel Cyclic Reduction, full-width log2(n) steps (Pallas kernel);
  cr  — Cyclic Reduction, forward halving + back substitution;
  lf  — Ladner-Fischer: the LU-elimination recurrences recast as parallel
        prefixes (2x2 Mobius matrices for the pivots — the paper's "each
        element is composed of two equations" — plus two linear-recurrence
        scans for the substitution sweeps);
  wm  — Wang&Mou divide-and-conquer: the same prefix math evaluated chunk-
        wise (sequential inside a chunk of `radix * 16` elements, parallel
        across chunks) — the radix is the tunable fan-in, as in the paper.

`solve(..., variant=...)` resolves the configuration for the
(op="tridiag", variant, n, batch) workload through the TunerSession.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.space import Workload, fit_block, tridiag_space
from repro.kernels.blocks import driver
from repro.kernels.blocks.plan import plan_for, wm_chunk
from repro.kernels.tridiag.kernel import pcr_pallas
from repro.kernels.tridiag.ref import thomas_ref
from repro.tuning import default_session, on_cpu, plan_execution, tuned_kernel

# systems longer than this route the LF substitution sweeps through the
# multi-pass scan driver (paper §IV-C m-kernel path for tridiag)
LF_MULTIPASS_MIN = 1 << 15


def _normalize(cfg, wl, dims=None):
    """Variant-aware projection onto the knobs each solver actually
    consumes, so the resolved config uniquely determines the executed
    kernel (what the TuningDB records is what ran):

      pcr         -> rows_per_program, unroll;
      wm          -> radix plus the DERIVED chunk (the dispatch-time
                     ``radix * 16`` clamp moved here, single-sourced in
                     ``blocks.plan.wm_chunk``);
      cr/lf/thomas -> no knobs (their spaces are singletons).
    """
    if wl.variant == "wm":
        radix = cfg.get("radix", 2)
        return {"radix": radix, "chunk": wm_chunk(radix, wl.n)}
    if wl.variant in ("cr", "lf", "thomas"):
        return {}
    return {"rows_per_program": fit_block(cfg.get("rows_per_program", 8),
                                          max(wl.batch, 1)),
            "unroll": cfg.get("unroll", 1)}


# ---------------------------------------------------------------------------
# CR — cyclic reduction
# ---------------------------------------------------------------------------

@functools.partial(jax.jit)
def cr_solve(a, b, c, d):
    batch, n = a.shape
    levels = []
    while a.shape[-1] > 2:
        am, bm, cm, dm = (jnp.pad(v[..., :-1], ((0, 0), (1, 0)))
                          for v in (a, b, c, d))
        bm = bm.at[..., 0].set(1.0)
        ap, bp, cp, dp = (jnp.pad(v[..., 1:], ((0, 0), (0, 1)))
                          for v in (a, b, c, d))
        bp = bp.at[..., -1].set(1.0)
        alpha = -a / bm
        gamma = -c / bp
        a2 = alpha * am
        b2 = b + alpha * cm + gamma * ap
        c2 = gamma * cp
        d2 = d + alpha * dm + gamma * dp
        levels.append((a, b, c, d))
        a, b, c, d = (v[..., 1::2] for v in (a2, b2, c2, d2))
    # solve the 2x2 (or 1x1) core directly
    if a.shape[-1] == 1:
        x = d / b
    else:
        det = b[..., 0] * b[..., 1] - c[..., 0] * a[..., 1]
        x0 = (d[..., 0] * b[..., 1] - c[..., 0] * d[..., 1]) / det
        x1 = (b[..., 0] * d[..., 1] - d[..., 0] * a[..., 1]) / det
        x = jnp.stack([x0, x1], axis=-1)
    # back substitution
    for (a0, b0, c0, d0) in reversed(levels):
        xfull = jnp.zeros(a0.shape, a0.dtype)
        xfull = xfull.at[..., 1::2].set(x)
        xm = jnp.pad(xfull[..., :-1], ((0, 0), (1, 0)))
        xp = jnp.pad(xfull[..., 1:], ((0, 0), (0, 1)))
        xeven = (d0 - a0 * xm - c0 * xp) / b0
        xfull = xfull.at[..., 0::2].set(xeven[..., 0::2])
        x = xfull
    return x


# ---------------------------------------------------------------------------
# LF — parallel-prefix formulation
# ---------------------------------------------------------------------------

def _pivot_prefix(a, b, c):
    """LU pivots e_i via normalized 2x2 Mobius-matrix prefix products."""
    cm = jnp.pad(c[..., :-1], ((0, 0), (1, 0)))
    m00 = b
    m01 = -a * cm
    m10 = jnp.ones_like(b)
    m11 = jnp.zeros_like(b)
    # first matrix encodes e_0 = b_0 directly: [b0, 0; 1, 0] works since
    # v_{-1} = [1, 0]^T  ->  v_0 = [b0, 1]^T (after the ratio, e_0 = b0).
    m01 = m01.at[..., 0].set(0.0)

    def combine(x, y):
        # y (newer) @ x (older), normalized for scale stability
        y00, y01, y10, y11 = y
        x00, x01, x10, x11 = x
        z00 = y00 * x00 + y01 * x10
        z01 = y00 * x01 + y01 * x11
        z10 = y10 * x00 + y11 * x10
        z11 = y10 * x01 + y11 * x11
        s = jnp.maximum(jnp.maximum(jnp.abs(z00), jnp.abs(z01)),
                        jnp.maximum(jnp.abs(z10), jnp.abs(z11))) + 1e-30
        return z00 / s, z01 / s, z10 / s, z11 / s

    p00, p01, p10, p11 = jax.lax.associative_scan(
        combine, (m00, m01, m10, m11), axis=-1)
    # v_i = P_i [1, 0]^T = [p00, p10]
    return p00 / p10


def _linrec(a, b, reverse=False):
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    if reverse:
        a = jnp.flip(a, -1)
        b = jnp.flip(b, -1)
    _, h = jax.lax.associative_scan(combine, (a, b), axis=-1)
    return jnp.flip(h, -1) if reverse else h


@functools.partial(jax.jit)
def lf_solve(a, b, c, d):
    e = _pivot_prefix(a, b, c)
    em = jnp.pad(e[..., :-1], ((0, 0), (1, 0)), constant_values=1.0)
    alpha = -a / em
    alpha = alpha.at[..., 0].set(0.0)
    y = _linrec(alpha, d)                      # forward substitution
    x = _linrec(-c / e, y / e, reverse=True)   # back substitution
    return x


def lf_solve_multipass(a, b, c, d, *, use_pallas: bool = True,
                       interpret: bool = False):
    """LF with the substitution sweeps on the multi-pass scan driver.

    The pivot prefix stays the normalized 2x2 scan (scale stability), but
    the forward/back linear recurrences run as the shared carry-chain
    building block — pallas-fused for small n, the §IV-C three-kernel
    decomposition once the row exceeds the resident tile.
    """
    e = _pivot_prefix(a, b, c)
    em = jnp.pad(e[..., :-1], ((0, 0), (1, 0)), constant_values=1.0)
    alpha = (-a / em).at[..., 0].set(0.0)
    y = driver.linrec_rows(alpha, d, use_pallas=use_pallas,
                           interpret=interpret)
    x = driver.linrec_rows(jnp.flip(-c / e, -1), jnp.flip(y / e, -1),
                           use_pallas=use_pallas, interpret=interpret)
    return jnp.flip(x, -1)


# ---------------------------------------------------------------------------
# WM — divide-and-conquer (chunked prefix)
# ---------------------------------------------------------------------------

def _chunked_linrec(a, b, chunk: int, reverse=False):
    """linrec via sequential scan inside chunks + associative scan across."""
    if reverse:
        a = jnp.flip(a, -1)
        b = jnp.flip(b, -1)
    batch, n = a.shape
    p = n // chunk
    ar = a.reshape(batch, p, chunk)
    br = b.reshape(batch, p, chunk)

    def step(carry, ab):
        ai, bi = ab
        h = ai * carry + bi
        return h, h

    # within-chunk, with zero entry state: gives local response + local
    # cumulative products
    _, hT = jax.lax.scan(step, jnp.zeros((batch, p), a.dtype),
                         (jnp.moveaxis(ar, -1, 0), jnp.moveaxis(br, -1, 0)))
    h_local = jnp.moveaxis(hT, 0, -1)                     # (batch, p, chunk)
    a_cum = jnp.cumprod(ar, axis=-1)
    # chunk transfer: state_out = A_chunk * state_in + B_chunk
    A_chunk = a_cum[..., -1]
    B_chunk = h_local[..., -1]

    def combine(l, r):
        al, bl = l
        ar_, br_ = r
        return al * ar_, ar_ * bl + br_

    _, carry_in = jax.lax.associative_scan(combine, (A_chunk, B_chunk), axis=-1)
    # entry state of chunk k = exit state of chunk k-1
    entry = jnp.pad(carry_in[..., :-1], ((0, 0), (1, 0)))
    h = h_local + a_cum * entry[..., None]
    h = h.reshape(batch, n)
    return jnp.flip(h, -1) if reverse else h


def wm_solve(a, b, c, d, chunk: int = 32):
    e = _pivot_prefix(a, b, c)   # pivots via tree prefix (shared)
    em = jnp.pad(e[..., :-1], ((0, 0), (1, 0)), constant_values=1.0)
    alpha = (-a / em).at[..., 0].set(0.0)
    y = _chunked_linrec(alpha, d, chunk)
    x = _chunked_linrec(-c / e, y / e, chunk, reverse=True)
    return x


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

@tuned_kernel("tridiag", space=tridiag_space, pallas=pcr_pallas,
              reference=thomas_ref, normalize=_normalize,
              variants=("pcr", "cr", "lf", "wm", "thomas"))
def solve(a, b, c, d, variant: str = "pcr", config: Optional[dict] = None,
          interpret: Optional[bool] = None):
    """Tuned batched tridiagonal solve; x with A x = d."""
    batch, n = a.shape

    def cfg():
        return default_session().resolve(
            Workload(op="tridiag", n=n, batch=batch, variant=variant),
            config=config)

    if variant == "pcr":
        interpret = on_cpu() if interpret is None else interpret
        c_ = cfg()
        plan = plan_for(Workload(op="tridiag", n=n, batch=batch,
                                 variant="pcr"), c_)
        return driver.launch(
            pcr_pallas, plan.launches[0], a, b, c, d,
            rows_per_program=c_["rows_per_program"], unroll=c_["unroll"],
            interpret=interpret)
    if variant == "cr":
        return cr_solve(a, b, c, d)
    if variant == "lf":
        if n > LF_MULTIPASS_MIN:
            use_pallas, interpret = plan_execution(None, interpret)
            return lf_solve_multipass(a, b, c, d, use_pallas=use_pallas,
                                      interpret=interpret)
        return lf_solve(a, b, c, d)
    if variant == "wm":
        return wm_solve(a, b, c, d, chunk=cfg()["chunk"])
    if variant == "thomas":
        return thomas_ref(a, b, c, d)
    raise ValueError(f"unknown tridiag variant {variant!r}")
