"""minitron-4b: 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000 —
pruned nemotron [arXiv:2407.14679]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab=256000, activation="swiglu",
    activation_strategy="sp",
))
