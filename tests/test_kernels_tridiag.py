"""Tridiagonal solvers (PCR Pallas + CR/LF/WM) vs Thomas/dense oracles."""
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests
import hypothesis.strategies as st
import jax
import numpy as np
from hypothesis import given, settings

from repro.kernels.tridiag import ops
from repro.kernels.tridiag.kernel import pcr_pallas
from repro.kernels.tridiag.ref import (dense_solve_ref, random_system,
                                       residual, thomas_ref)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("n", [64, 128, 256, 1024])
@pytest.mark.parametrize("variant", ["pcr", "cr", "lf", "wm"])
def test_solver_matches_thomas(n, variant):
    a, b, c, d = random_system(KEY, 8, n)
    x = ops.solve(a, b, c, d, variant=variant,
                  config={"rows_per_program": 4, "unroll": 1, "radix": 2})
    xr = thomas_ref(a, b, c, d)
    np.testing.assert_allclose(x, xr, rtol=1e-3, atol=1e-4)
    assert float(residual(a, b, c, d, x)) < 1e-3


def test_pcr_pallas_vs_dense_small():
    a, b, c, d = random_system(KEY, 4, 32)
    x = pcr_pallas(a, b, c, d, rows_per_program=2, interpret=True)
    xd = dense_solve_ref(a, b, c, d)
    np.testing.assert_allclose(x, xd, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("rows", [1, 2, 8])
def test_pcr_rows_sweep(rows):
    a, b, c, d = random_system(KEY, 8, 128)
    x = pcr_pallas(a, b, c, d, rows_per_program=rows, interpret=True)
    assert float(residual(a, b, c, d, x)) < 1e-3


def test_wm_chunk_sweep():
    a, b, c, d = random_system(KEY, 4, 512)
    for radix in [2, 4, 8]:
        x = ops.solve(a, b, c, d, variant="wm",
                      config={"radix": radix, "rows_per_program": 4})
        assert float(residual(a, b, c, d, x)) < 1e-3


@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 256]))
@settings(max_examples=8, deadline=None)
def test_random_diag_dominant_systems_solve(seed, n):
    key = jax.random.PRNGKey(seed)
    a, b, c, d = random_system(key, 4, n)
    for variant in ["pcr", "lf"]:
        x = ops.solve(a, b, c, d, variant=variant,
                      config={"rows_per_program": 4, "unroll": 1, "radix": 2})
        assert float(residual(a, b, c, d, x)) < 1e-2
