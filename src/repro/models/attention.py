"""Attention blocks: GQA/MQA self-attention (+RoPE, local windows, KV cache)
and cross-attention (enc-dec, VLM)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels.attention.ops import attention as attention_op
from repro.models.layers import dense, init_dense, rope


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": init_dense(kq, d, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": init_dense(kk, d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": init_dense(kv, d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": init_dense(ko, cfg.n_heads * hd, d, dtype),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    b, l, _ = x.shape
    return x.reshape(b, l, n, hd)


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return x
    b, l, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, l, h, n_rep, d)
                            ).reshape(b, l, h * n_rep, d)


def _score_constraint(h: int, lq: int, model_axis: int) -> Optional[P]:
    """Sharding for the (B, H, Lq, Lk) score tensor — the largest activation
    in every attention cell. Prefer head (TP) sharding; archs whose head
    count doesn't divide the model axis (gemma-2b: 8, minitron: 24,
    whisper: 20) fall back to query-sequence sharding (context-parallel
    style), which is always divisible for the assigned shapes.

    Non-constrained dims stay UNCONSTRAINED so the batch sharding keeps
    propagating (a None here would *replicate* the batch dim — a hard
    constraint, measured as a 16x memory blow-up)."""
    if not model_axis:
        return None
    U = P.UNCONSTRAINED
    if h % model_axis == 0:
        return P(U, "model", U, U)
    if lq % model_axis == 0:
        return P(U, U, "model", U)
    return None


def _attention_core(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, window: Optional[int], compute_dtype,
                    model_axis: int, q_offset) -> jax.Array:
    """One (B, Lq, H, D) x (B, Lk, H, D) attention tile; q_offset is the
    global position of q[0] minus kpos[0] (supports q-chunking)."""
    bq, lq, h, d = q.shape
    lk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    cons = _score_constraint(h, lq, model_axis)
    if cons is not None:
        s = jax.lax.with_sharding_constraint(s, cons)
    qpos = jnp.arange(lq) + q_offset
    kpos = jnp.arange(lk)
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(compute_dtype)
    if cons is not None:
        p = jax.lax.with_sharding_constraint(p, cons)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


# chunk the query dim once the full (Lq, Lk) score tensor would exceed this
# many elements per (batch, head) — softmax is per-q-row, so q-chunking is
# EXACT (flash-attention's insight, realized with lax.scan + remat in XLA)
_SCORE_ELEMS_LIMIT = 4096 * 4096
_Q_CHUNK = 1024


def _attention_4d(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, window: Optional[int],
                  compute_dtype, model_axis: int = 0) -> jax.Array:
    """XLA-path attention keeping (B, L, H, D) layout end-to-end.

    Never merges the data-sharded batch dim with the model-sharded head dim
    (a (B*H, ...) reshape defeats GSPMD propagation and replicates the
    (S, S) score tensors — measured 500+ GiB/device on train_4k cells).
    Long sequences scan over q-chunks so only a (chunk, Lk) score block is
    ever live; each chunk is rematted in the backward pass.
    """
    bq, lq, h, d = q.shape
    lk = k.shape[1]
    base_offset = lk - lq
    if lq * lk <= _SCORE_ELEMS_LIMIT or lq % _Q_CHUNK or lq == lk == 0:
        return _attention_core(q, k, v, causal=causal, window=window,
                               compute_dtype=compute_dtype,
                               model_axis=model_axis, q_offset=base_offset)

    nc = lq // _Q_CHUNK
    qr = jnp.moveaxis(q.reshape(bq, nc, _Q_CHUNK, h, d), 1, 0)

    def body(_, xs):
        idx, qb = xs

        def run(qb):
            return _attention_core(
                qb, k, v, causal=causal, window=window,
                compute_dtype=compute_dtype, model_axis=model_axis,
                q_offset=idx * _Q_CHUNK + base_offset)

        return None, jax.checkpoint(run)(qb)

    _, ob = jax.lax.scan(body, None, (jnp.arange(nc), qr))
    return jnp.moveaxis(ob, 0, 1).reshape(bq, lq, h, d)


def self_attention(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                   positions: jax.Array,
                   cache: Optional[Dict] = None,
                   window: Optional[int] = None,
                   compute_dtype=jnp.bfloat16
                   ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, L, D).

    cache layouts:
      full:   {"k","v": (B, L_max, Hkv, hd)} — slot index == position;
      window: additionally {"pos": (B, W) int32} — ring buffer of W slots
              holding the absolute position written into each slot.
    Training/prefill: cache None (pure forward). Decode: L == 1; the cache
    is updated at `positions` and attention masks by true positions, so
    uninitialized slots never reach the softmax.
    """
    b, l, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = _split_heads(dense(p["wq"], x, compute_dtype), hq, hd)
    k = _split_heads(dense(p["wk"], x, compute_dtype), hkv, hd)
    v = _split_heads(dense(p["wv"], x, compute_dtype), hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    n_rep = hq // max(hkv, 1)

    if cache is not None and l == 1:
        pos = positions[:, 0]                                    # (B,)
        cache_len = cache["k"].shape[1]
        barange = jnp.arange(b)
        if "pos" in cache:                                       # ring buffer
            slot = jnp.mod(pos, cache_len)
            slot_pos = cache["pos"].at[barange, slot].set(pos)
        else:
            slot = pos
            slot_pos = jnp.arange(cache_len)[None, :] * jnp.ones(
                (b, 1), jnp.int32)
        ck = cache["k"].at[barange, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[barange, slot].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        if "pos" in cache:
            new_cache["pos"] = slot_pos

        kk = _repeat_kv(ck.astype(compute_dtype), n_rep)         # (B,S,H,hd)
        vv = _repeat_kv(cv.astype(compute_dtype), n_rep)
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        s = jnp.einsum("bhd,bshd->bhs", q[:, 0], kk).astype(jnp.float32)
        s = s * scale
        mask = slot_pos <= pos[:, None]                          # causal/valid
        if window is not None:
            mask &= slot_pos > (pos[:, None] - window)
        s = jnp.where(mask[:, None, :], s, -jnp.inf)
        pattn = jax.nn.softmax(s, axis=-1).astype(compute_dtype)
        o = jnp.einsum("bhs,bshd->bhd", pattn, vv)[:, None]      # (B,1,H,hd)
        o = o.reshape(b, l, hq * hd)
        return dense(p["wo"], o, compute_dtype), new_cache

    # training / prefill full-sequence path
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    if cfg.use_pallas:
        # real-TPU path: flash kernel over flattened rows (shard_mapped on
        # device; block sizes from the TuningDB)
        qf = q.transpose(0, 2, 1, 3).reshape(b * hq, l, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(b * hq, -1, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(b * hq, -1, hd)
        of = attention_op(qf, kf, vf, causal=True, window=window,
                          use_pallas=True)
        o = of.reshape(b, hq, l, hd).transpose(0, 2, 1, 3)
    else:
        o = _attention_4d(q, k, v, causal=True, window=window,
                          compute_dtype=compute_dtype,
                          model_axis=cfg.model_axis_size)
    o = o.reshape(b, l, hq * hd)
    return dense(p["wo"], o, compute_dtype), None


def cross_attention(p: Dict, x: jax.Array, memory: jax.Array,
                    cfg: ModelConfig, compute_dtype=jnp.bfloat16) -> jax.Array:
    """x: (B, L, D) queries over encoder/vision memory (B, M, D)."""
    b, l, _ = x.shape
    m = memory.shape[1]
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = _split_heads(dense(p["wq"], x, compute_dtype), hq, hd)
    k = _split_heads(dense(p["wk"], memory, compute_dtype), hkv, hd)
    v = _split_heads(dense(p["wv"], memory, compute_dtype), hkv, hd)
    n_rep = hq // max(hkv, 1)
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    if cfg.use_pallas:
        qf = q.transpose(0, 2, 1, 3).reshape(b * hq, l, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(b * hq, m, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(b * hq, m, hd)
        of = attention_op(qf, kf, vf, causal=False, use_pallas=True)
        o = of.reshape(b, hq, l, hd).transpose(0, 2, 1, 3)
    else:
        o = _attention_4d(q, k, v, causal=False, window=None,
                          compute_dtype=compute_dtype,
                          model_axis=cfg.model_axis_size)
    o = o.reshape(b, l, hq * hd)
    return dense(p["wo"], o, compute_dtype)
