"""Tuned scan entry points (prefix sum + linear recurrence).

Every call resolves its configuration through the default
:class:`repro.tuning.TunerSession` — DB hit (offline-tuned), else the
memoized analytical model (online, zero evaluations) — the paper's
deployment flow, then builds the :class:`StagePlan` that fixes the staged
execution (mixed-radix stage sequence, grid, carry scratch).  The plan is
the same object the analytical model and the ML featurizer consume, so
what runs is what was modeled.  ``plan.kind == "multipass"`` routes
large-N workloads through the §IV-C three-kernel driver.

Shapes are normalized to (batch, n) rows; callers with higher-rank arrays
flatten leading dims.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.core.space import Workload, fit_block, scan_space
from repro.kernels.blocks import driver
from repro.kernels.blocks.plan import plan_for
from repro.kernels.scan.kernel import scan_add_pallas, scan_linrec_pallas
from repro.kernels.scan.ref import scan_add_ref, scan_linrec_assoc_ref
from repro.tuning import default_session, plan_execution, tuned_kernel


def _normalize(cfg, wl, dims=None):
    """Fit tuned knobs to the (batch, n) launch geometry; project to the
    kwargs the scan kernels accept (``in_register`` is a space-only knob;
    linrec's fold order is fixed, so its ``unroll`` is dropped with the
    same variant-awareness its search space applies)."""
    out = {
        "rows_per_program": fit_block(cfg.get("rows_per_program", 8),
                                      max(wl.batch, 1)),
        "tile_n": fit_block(cfg.get("tile_n", wl.n), wl.n),
        "radix": cfg.get("radix", 2),
    }
    if wl.variant != "linrec" and wl.op != "rglru":
        out["unroll"] = cfg.get("unroll", 1)
    if wl.op == "rglru":
        # chain-fusion boundary: keep the knob in the resolved config so
        # the dispatch (and the plan it records) sees the tuned value
        out["fuse"] = cfg.get("fuse", 0)
    return out


def _plan_workload(wl, linrec: bool):
    """Workload the PLAN is built for: both entry points share op="scan"
    and accept any registered variant (DB keys stay caller-chosen), but
    the plan's plane accounting must follow the kernel that actually runs
    — linrec keeps three resident planes, prefix-sum two — so a legacy
    ``linear_recurrence(variant="ks")`` call still gets a linrec plan."""
    want = "linrec" if linrec else ("ks" if wl.variant == "linrec"
                                    else wl.variant)
    return wl if wl.variant == want else dataclasses.replace(wl, variant=want)


@tuned_kernel("scan", space=scan_space, pallas=scan_add_pallas,
              reference=scan_add_ref, normalize=_normalize,
              variants=("ks", "lf", "linrec"))
def prefix_sum(x: jax.Array, variant: str = "ks",
               config: Optional[dict] = None,
               interpret: Optional[bool] = None,
               use_pallas: Optional[bool] = None) -> jax.Array:
    """Inclusive row-wise prefix sum with tuned blocking."""
    batch, n = x.shape
    use_pallas, interpret = plan_execution(use_pallas, interpret)
    if not use_pallas:
        return scan_add_ref(x)
    wl = Workload(op="scan", n=n, batch=batch, variant=variant)
    cfg = default_session().resolve(wl, config=config)
    plan = plan_for(_plan_workload(wl, linrec=False), cfg)
    if plan.kind == "multipass":
        return driver.multipass_scan_add(x, plan, unroll=cfg.get("unroll", 1),
                                         interpret=interpret)
    return driver.launch(scan_add_pallas, plan.launches[0], x,
                         rows_per_program=plan.rows, tile_n=plan.tile_n,
                         stages=plan.stages, unroll=cfg.get("unroll", 1),
                         interpret=interpret)


@tuned_kernel("scan", space=scan_space, pallas=scan_linrec_pallas,
              reference=scan_linrec_assoc_ref, normalize=_normalize,
              variants=("ks", "lf", "linrec"))
def linear_recurrence(a: jax.Array, b: jax.Array, variant: str = "linrec",
                      config: Optional[dict] = None,
                      interpret: Optional[bool] = None,
                      use_pallas: Optional[bool] = None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t row-wise with tuned blocking.

    The workhorse behind RG-LRU layers and SSD inter-chunk state scans.
    """
    batch, n = a.shape
    use_pallas, interpret = plan_execution(use_pallas, interpret)
    if not use_pallas:
        return scan_linrec_assoc_ref(a, b)
    wl = Workload(op="scan", n=n, batch=batch, variant=variant)
    cfg = default_session().resolve(wl, config=config)
    plan = plan_for(_plan_workload(wl, linrec=True), cfg)
    if plan.kind == "multipass":
        return driver.multipass_linrec(a, b, plan, interpret=interpret)
    return driver.launch(scan_linrec_pallas, plan.launches[0], a, b,
                         rows_per_program=plan.rows, tile_n=plan.tile_n,
                         stages=plan.stages, interpret=interpret)
