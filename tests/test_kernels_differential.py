"""Differential kernel-vs-reference suite over dtype x odd/prime shapes.

The case table lives in ``tests/conftest.py`` (one row per
``kernels/*/ops.py`` entry point); ``pytest_generate_tests`` fans it out.
Each case runs the *public* entry with ``config=None`` — the full
session-resolution pipeline (analytical prior -> per-op normalizer ->
launch-geometry fitting) has to survive shapes the tuner never saw:
prime batches, non-power-of-two lengths.
"""
from conftest import kernel_ops_entries


def test_kernel_matches_reference(kernel_case):
    kernel_case()


def test_table_covers_every_registered_kernel():
    """Adding a @tuned_kernel entry point without a differential-table row
    must fail here — coverage is opt-out-proof, like known_ops() for the
    ML suite."""
    from repro.tuning import registered_kernels
    from repro.tuning.registry import _OP_MODULES, _ensure_registered

    for op in _OP_MODULES:
        _ensure_registered(op)
    registered = set()
    for name, spec in registered_kernels().items():
        registered.add(spec.entry_name)
    covered = set(kernel_ops_entries())
    # tridiag's one entry point (solve) is table-covered per variant;
    # fft's ifft is the same kernel inverted (roundtrip-tested in
    # test_kernels_fft.py)
    aliases = {"solve": {"solve_pcr", "solve_cr", "solve_lf", "solve_wm"},
               "ifft": {"fft"}}
    missing = []
    for entry in registered:
        names = aliases.get(entry, {entry})
        if not names & covered:
            missing.append(entry)
    assert not missing, \
        f"kernels/*/ops.py entry points without a differential case: {missing}"


def test_odd_length_scan_space_is_empty():
    """Pin the boundary the table respects: odd n has no valid radix
    config — resolution must fail loudly, not silently mis-launch."""
    from repro.core import Workload, build_space

    space = build_space(Workload(op="scan", n=97, batch=4, variant="ks"))
    assert space.enumerate_valid() == []


def test_odd_batch_space_builds_after_floor_pow2_fix():
    """Odd batches used to trip pow2_range's power-of-two assert inside
    the space builders; a serve engine with 3 active slots is legal."""
    from repro.core import Workload, build_space
    from repro.core.space import floor_pow2

    assert floor_pow2(1) == 1 and floor_pow2(7) == 4 and floor_pow2(8) == 8
    for op, variant in (("scan", "ks"), ("tridiag", "pcr"),
                        ("fft", "stockham")):
        space = build_space(Workload(op=op, n=256, batch=3, variant=variant))
        cands = space.enumerate_valid()
        assert cands, f"{op}: no valid config for an odd batch"
        assert all(c.get("rows_per_program", 1) == 1 for c in cands)
