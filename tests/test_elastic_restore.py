"""Elastic scaling: a checkpoint written under one device layout restores
into a different (shrunken) layout — global shapes are layout-invariant."""
import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models.model import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import plan_elastic_remesh
from repro.train.step import TrainHParams, init_train_state


def test_checkpoint_restores_across_remesh(tmp_path):
    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    hp = TrainHParams()
    state = init_train_state(model, hp, jax.random.PRNGKey(0))
    m = CheckpointManager(str(tmp_path), async_write=False)
    m.save(3, state)

    # simulate losing a host: plan the shrunken mesh, then restore the
    # same global state (shapes unchanged -> straight load + device_put
    # under the new layout)
    plan = plan_elastic_remesh(available_chips=224, model_axis=16,
                               target_batch=256)
    assert plan.data_axis == 14
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), state)
    step, restored = m.restore_latest(like)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
