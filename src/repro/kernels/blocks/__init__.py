"""BPLG-style building-block layer.

``plan`` (pure Python — safe for the numpy-only analytical/ML stack) is
re-exported here; ``primitives`` and ``driver`` import jax and must be
imported explicitly by kernel code:

    from repro.kernels.blocks.plan import StagePlan, build_plan, plan_for
    from repro.kernels.blocks import primitives, driver   # jax layers
"""
from repro.kernels.blocks.plan import (DEFAULT_SEQ_LIMIT, Launch, StagePlan,
                                       build_plan, plan_for, stage_radices,
                                       stage_strides, wm_chunk)

__all__ = ["DEFAULT_SEQ_LIMIT", "Launch", "StagePlan", "build_plan",
           "plan_for", "stage_radices", "stage_strides", "wm_chunk"]
