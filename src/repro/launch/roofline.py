import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs / (chips x 197e12)
    memory term     = HLO_bytes / (chips x 819e9)
    collective term = collective_bytes / (chips x 50e9)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). XLA:CPU reports
them for the per-device partitioned module, so chips-normalization is
already done; we multiply back to global where needed for MODEL_FLOPS
ratios. Collective bytes are parsed from the optimized HLO text: the sum of
shard-local operand bytes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute, scaled by the collective's algorithmic
byte multiplier on a ring (all-gather/reduce-scatter: (n-1)/n x global
bytes; all-reduce: 2(n-1)/n; all-to-all: (n-1)/n; permute: 1).

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --arch X --shape Y [--multi-pod]
  PYTHONPATH=src python -m repro.launch.roofline --all     # full table
"""
import argparse
import json
import re
from typing import Any, Dict, List, Optional, Tuple

from repro.configs.base import SHAPES, all_archs, get_arch
from repro.hw.profiles import TPU_V5E as V5E

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:[%\w.\-]+)\s*=\s*[^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b", re.MULTILINE)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|c64|c128)\[([\d,]*)\]")

_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Computation definitions start at column 0 (module scope) as
    `[ENTRY ]%name (params...) -> result {`; params may nest parens, so the
    name is simply the first %token."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        is_def = (not line.startswith(" ") and stripped.endswith("{")
                  and "->" in stripped
                  and (stripped.startswith("%")
                       or stripped.startswith("ENTRY")))
        if is_def:
            tok = stripped.split()[1] if stripped.startswith("ENTRY") \
                else stripped.split()[0]
            name = tok.split("(")[0].lstrip("%").rstrip()
            cur = name
            comps[cur] = []
            if stripped.startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _collective_wire_bytes(line: str, kind: str) -> float:
    m = re.search(r"=\s*(\([^)]*\)|[\w\[\],{}]+)\s*" + kind, line)
    out_bytes = _shape_bytes(m.group(1)) if m else 0
    g = _REPLICA_GROUPS_RE.search(line)
    group_size = len(g.group(1).split(",")) if g else 2
    frac = (group_size - 1) / max(group_size, 1)
    if kind == "all-reduce":
        return 2 * frac * out_bytes
    if kind == "collective-permute":
        return float(out_bytes)
    return frac * out_bytes


_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum per-device collective wire bytes from optimized (post-SPMD) HLO,
    multiplying collectives inside `while` bodies by the loop trip count
    (XLA prints the body once; a scan-over-88-layers would otherwise be
    undercounted 88x). Trip counts are read from the largest integer
    constant in the loop's condition computation (the scan bound)."""
    comps = _split_computations(hlo_text)

    def comp_trip(cond_name: str) -> int:
        best = 1
        for line in comps.get(cond_name, []):
            for c in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(c.group(1)))
        return best

    per_kind: Dict[str, float] = {}
    count = 0
    visited_stack = set()

    def walk(name: str, mult: float) -> None:
        nonlocal count
        if name in visited_stack:       # recursion guard
            return
        visited_stack.add(name)
        for line in comps.get(name, []):
            kind_hit = None
            for kind in _KINDS:
                if re.search(rf"\b{kind}(?:-start)?\(", line):
                    kind_hit = kind
                    break
            if kind_hit and "=" in line:
                per_kind[kind_hit] = per_kind.get(kind_hit, 0.0) + \
                    mult * _collective_wire_bytes(line, kind_hit)
                count += 1
            # recurse into subcomputations
            if " while(" in line or "= while(" in line:
                body = re.search(r"body=%?([\w.\-]+)", line)
                cond = re.search(r"condition=%?([\w.\-]+)", line)
                trip = comp_trip(cond.group(1)) if cond else 1
                if body:
                    walk(body.group(1), mult * trip)
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                     line):
                    walk(m.group(1), mult)
        visited_stack.discard(name)

    walk("__entry__", 1.0)
    return {"per_device_wire_bytes": sum(per_kind.values()),
            "per_kind": per_kind, "count": count}


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train; the
    2*N*D forward-only version for prefill; 2*N_active*D per decode token.
    Enc-dec splits by token stream: decoder params x decoder tokens +
    encoder params x frame count."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    # active params: embeddings excluded (matmul-active weights only)
    from repro.launch.params import active_param_count, audio_split_params
    mult = 6.0 if shape.kind == "train" else 2.0
    dec_tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
    if cfg.family == "audio":
        enc_p, dec_p = audio_split_params(cfg)
        enc_tokens = (shape.global_batch * cfg.enc_len
                      if shape.kind != "decode" else 0)
        return mult * (dec_p * dec_tokens + enc_p * enc_tokens)
    return mult * active_param_count(cfg) * dec_tokens


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, chips: int) -> Dict[str, float]:
    spec = V5E
    return {
        "compute_s": flops_per_dev / spec.peak_bf16_flops,
        "memory_s": bytes_per_dev / spec.hbm_bandwidth,
        "collective_s": coll_bytes_per_dev / spec.ici_link_bandwidth,
    }


def analyze_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 arch_cfg=None, hp=None) -> Dict[str, Any]:
    from repro.launch.dryrun import lower_cell

    rec = lower_cell(arch, shape_name, multi_pod=multi_pod,
                     return_artifacts=True, arch_cfg=arch_cfg, hp=hp)
    if rec["status"] != "ok":
        return rec
    compiled = rec.pop("_compiled")
    rec.pop("_lowered")
    chips = 512 if multi_pod else 256
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    # primary flop/byte source: trip-count-exact jaxpr analysis (global
    # shapes -> per-chip under the realized sharding); cost_analysis() is
    # kept as the cross-check (XLA:CPU counts while bodies once)
    jc = rec.get("jaxpr_cost") or {}
    flops_dev = jc.get("flops", 0.0) / chips
    bytes_dev = jc.get("bytes", 0.0) / chips
    terms = roofline_terms(flops_dev, bytes_dev,
                           coll["per_device_wire_bytes"], chips)
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape_name)
    rec.update({
        "chips": chips,
        "collectives": coll,
        "roofline": terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_per_dev_costanalysis": rec["flops"],
        "useful_flops_ratio": mf / jc["flops"] if jc.get("flops") else 0,
        "step_time_bound_s": max(terms.values()),
        "mfu_upper_bound": (mf / chips / V5E.peak_bf16_flops)
        / max(max(terms.values()), 1e-12),
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/roofline")
    args = ap.parse_args()

    cells: List[Tuple[str, str]] = []
    if args.all:
        for arch in all_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        try:
            rec = analyze_cell(arch, shape, multi_pod=args.multi_pod)
        except Exception as e:
            import traceback
            rec = {"arch": arch, "shape": shape, "status": "FAILED",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-1500:]}
        tag = f"{arch}|{shape}"
        if rec["status"] == "ok":
            t = rec["roofline"]
            print(f"[roofline] {tag}: comp={t['compute_s']*1e3:.2f}ms "
                  f"mem={t['memory_s']*1e3:.2f}ms "
                  f"coll={t['collective_s']*1e3:.2f}ms "
                  f"dom={rec['dominant']} "
                  f"useful={rec['useful_flops_ratio']:.2f} "
                  f"mfu_ub={rec['mfu_upper_bound']:.3f}", flush=True)
        else:
            print(f"[roofline] {tag}: {rec['status']} "
                  f"{rec.get('reason', rec.get('error',''))}", flush=True)
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        with open(os.path.join(args.out, f"{arch}_{shape}_{mesh_tag}.json"),
                  "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
