"""Search spaces for kernel performance parameters (paper Table I, TPU-native).

A space is declared per (operation, input-parameters) pair:
  - Input Parameters (paper: `A`): problem size N, batch G, dtype — they
    characterize the workload and are NOT searched.
  - Performance Parameters (paper: `B`): the tunable knobs with power-of-two
    domains and validity constraints.

`Config` is an immutable mapping knob-name -> value. Spaces are small and
enumerable (as in the paper), so `enumerate_valid()` is exact and the
exhaustive search is feasible — that property is what makes the Phi metric
computable.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
import math
import warnings
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.hw.profiles import HardwareProfile, active_profile, dtype_bytes

Config = Dict[str, int]


def pow2_range(lo: int, hi: int) -> Tuple[int, ...]:
    """Inclusive powers of two from lo to hi."""
    assert lo > 0 and hi >= lo and lo & (lo - 1) == 0 and hi & (hi - 1) == 0
    out = []
    v = lo
    while v <= hi:
        out.append(v)
        v *= 2
    return tuple(out)


def floor_pow2(v: int) -> int:
    """Largest power of two <= v (v >= 1).

    Space builders bound their rows/tile domains with this so odd batch
    sizes (3 active serving slots, a ragged last shard) build a valid
    space instead of tripping ``pow2_range``'s power-of-two precondition.
    """
    v = int(v)
    assert v >= 1, v
    return 1 << (v.bit_length() - 1)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One Performance Parameter: a named discrete domain."""

    name: str
    domain: Tuple[int, ...]

    def index_of(self, value: int) -> int:
        return self.domain.index(value)


@dataclasses.dataclass(frozen=True)
class Workload:
    """Input Parameters `A`: what problem are we tuning for."""

    op: str                 # "scan" | "tridiag" | "fft" | "ssd" | "attention" | ...
    n: int                  # problem size (elements per problem / seq length)
    batch: int = 1          # simultaneous problems (paper: G batches)
    dtype: str = "float32"
    variant: str = ""       # e.g. "lf" | "ks" | "wm" | "pcr" | "cr" | "stockham"

    @property
    def key(self) -> str:
        return f"{self.op}:{self.variant or 'default'}:n{self.n}:b{self.batch}:{self.dtype}"

    def canonical(self) -> "Workload":
        """Canonical form: int dims, batch >= 1, dtype as a numpy name.

        Every config-resolution entry point funnels through this so that
        e.g. ``dtype=jnp.float32`` and ``dtype="float32"`` hit the same DB
        and cache keys.
        """
        dtype = self.dtype if isinstance(self.dtype, str) \
            else np.dtype(self.dtype).name
        n, batch = int(self.n), max(int(self.batch), 1)
        if dtype == self.dtype and n == self.n and batch == self.batch:
            return self
        return dataclasses.replace(self, n=n, batch=batch, dtype=dtype)


@dataclasses.dataclass
class SearchSpace:
    """Performance Parameters `B` + constraints for one workload."""

    workload: Workload
    params: Sequence[ParamSpec]
    constraints: Sequence[Callable[[Config, Workload], bool]] = ()
    # the hardware profile whose limits bound this space (validity
    # constraints capture it at build time; consumers read it for
    # budgets/geometry). Defaults to the process-wide active profile.
    spec: HardwareProfile = dataclasses.field(default_factory=active_profile)
    # memoized enumerate_valid(): every consumer (sweep, analytical rank,
    # strategies, featurizer) re-enumerates the same space; the constraint
    # closures are the expensive part, not the product itself
    _valid_cache: Optional[List[Config]] = dataclasses.field(
        default=None, repr=False, compare=False)

    def param(self, name: str) -> ParamSpec:
        for p in self.params:
            if p.name == name:
                return p
        raise KeyError(name)

    def is_valid(self, cfg: Config) -> bool:
        for p in self.params:
            if cfg.get(p.name) not in p.domain:
                return False
        return all(c(cfg, self.workload) for c in self.constraints)

    def enumerate_all(self) -> List[Config]:
        names = [p.name for p in self.params]
        out = []
        for values in itertools.product(*[p.domain for p in self.params]):
            out.append(dict(zip(names, values)))
        return out

    def enumerate_valid(self) -> List[Config]:
        if self._valid_cache is None:
            self._valid_cache = [c for c in self.enumerate_all()
                                 if self.is_valid(c)]
        # fresh list each call — callers sort/slice it (the config dicts
        # themselves are treated read-only everywhere)
        return list(self._valid_cache)

    # --- encoding for the GP surrogate: log2-normalized coordinates ---
    def encode(self, cfg: Config) -> List[float]:
        coords = []
        for p in self.params:
            dom = p.domain
            if len(dom) == 1:
                coords.append(0.0)
                continue
            lo, hi = math.log2(dom[0] + 1), math.log2(dom[-1] + 1)
            coords.append((math.log2(cfg[p.name] + 1) - lo) / (hi - lo))
        return coords

    def size(self) -> int:
        return len(self.enumerate_valid())


# ---------------------------------------------------------------------------
# Constraint builders shared by the kernel spaces
# ---------------------------------------------------------------------------

def vmem_fits(bytes_per_elem: int, buffers: int = 2,
              spec: Optional[HardwareProfile] = None):
    """Double-buffered fast-memory footprint must fit the profile's budget.

    footprint = rows_per_program * tile_n * bytes_per_elem * buffers
    The analogue of the paper's 48KB shared-memory-per-block constraint
    (which is literally what it becomes under the ``gpu_sm`` profile).
    """
    spec = spec if spec is not None else active_profile()

    def check(cfg: Config, wl: Workload) -> bool:
        tile_n = cfg.get("tile_n", wl.n)
        rows = cfg.get("rows_per_program", 1)
        return rows * tile_n * bytes_per_elem * buffers <= spec.vmem_budget

    return check


def tile_divides_n():
    def check(cfg: Config, wl: Workload) -> bool:
        tile_n = cfg.get("tile_n", wl.n)
        return tile_n <= wl.n and wl.n % tile_n == 0

    return check


def rows_divide_batch():
    def check(cfg: Config, wl: Workload) -> bool:
        rows = cfg.get("rows_per_program", 1)
        return rows <= max(wl.batch, 1) and max(wl.batch, 1) % rows == 0

    return check


def radix_compatible():
    """radix^k must reach tile_n, and unroll must cover the radix fan-in."""

    def check(cfg: Config, wl: Workload) -> bool:
        r = cfg.get("radix", 2)
        tile_n = cfg.get("tile_n", wl.n)
        if r > tile_n:
            return False
        # tile_n must be a power of the radix for a uniform circuit; mixed
        # radix (paper Fig 5's jagged WM line) is valid but penalized by the
        # objective, not the space.
        k = round(math.log(tile_n, r))
        return r ** k == tile_n or (r ** k) * 2 == tile_n or tile_n % r == 0

    return check


def in_register_rule(spec: Optional[HardwareProfile] = None):
    """`in_register` (shuffle analogue) only when one problem row fits a VREG
    tile region: n <= 8 lanes*sublanes worth of data we keep resident."""
    spec = spec if spec is not None else active_profile()

    def check(cfg: Config, wl: Workload) -> bool:
        if not cfg.get("in_register", 0):
            return True
        return wl.n <= spec.lane_count * spec.sublane_count

    return check


# ---------------------------------------------------------------------------
# Per-operation space declarations (paper Table I, adapted per DESIGN.md §2)
# ---------------------------------------------------------------------------

def scan_space(wl: Workload,
               spec: Optional[HardwareProfile] = None) -> SearchSpace:
    spec = spec if spec is not None else active_profile()
    eb = dtype_bytes(wl.dtype)
    max_rows = floor_pow2(min(512, max(wl.batch, 1)))
    # variant-aware knob pruning: the linrec kernel's fold order is fixed
    # by the (a, b) composition algebra, so sweeping `unroll` there only
    # duplicated configs (inflated exhaustive sweeps, label noise in the
    # ML dataset)
    unroll_dom = (1,) if wl.variant == "linrec" else (1, 2, 4, 8)
    params = [
        ParamSpec("tile_n", tuple(v for v in pow2_range(128, max(wl.n, 128)) if v <= wl.n) or (wl.n,)),
        ParamSpec("rows_per_program", pow2_range(1, max_rows)),
        ParamSpec("radix", (2, 4, 8)),          # tree fan-in per level
        ParamSpec("unroll", unroll_dom),        # node-ops per VPU step
        ParamSpec("in_register", (0, 1)),
    ]
    if wl.op in ("ssd", "rglru"):
        # chain-fusion boundary knob: 1 folds the op's neighbouring chain
        # links into a shared launch (rglru's gate into the scan kernel's
        # first stage, SSD's phase B + apply into one sequential launch),
        # 0 breaks at the historical kernel boundaries — each break is a
        # full HBM roundtrip the analytical model charges as a pass.
        # Plain scans have no chain, so the knob would be dead there.
        params.append(ParamSpec("fuse", (0, 1)))
    return SearchSpace(
        wl,
        params,
        constraints=(
            vmem_fits(eb, spec=spec),
            tile_divides_n(),
            rows_divide_batch(),
            radix_compatible(),
            in_register_rule(spec),
        ),
        spec=spec,
    )


def linrec_space(wl: Workload,
                 spec: Optional[HardwareProfile] = None) -> SearchSpace:
    """Scan space with the linrec-dead knobs pruned (rglru & friends)."""
    return scan_space(dataclasses.replace(wl, variant=wl.variant or "linrec"),
                      spec)


def tridiag_space(wl: Workload,
                  spec: Optional[HardwareProfile] = None) -> SearchSpace:
    spec = spec if spec is not None else active_profile()
    # each element is an equation: 4 coefficients (a,b,c,d)
    eb = 4 * dtype_bytes(wl.dtype)
    if wl.variant in ("cr", "lf", "thomas"):
        # these variants consume no tuned knobs at all (XLA-fused solves);
        # a singleton space keeps sweeps/datasets free of duplicate configs
        params = [
            ParamSpec("tile_n", (wl.n,)),
            ParamSpec("rows_per_program", (1,)),
            ParamSpec("radix", (2,)),
            ParamSpec("unroll", (1,)),
            ParamSpec("in_register", (0,)),
        ]
        return SearchSpace(wl, params, constraints=(vmem_fits(eb, spec=spec),),
                           spec=spec)
    max_rows = floor_pow2(min(256, max(wl.batch, 1)))
    radix_dom = (2, 4, 8) if wl.variant == "wm" else (2,)  # paper: only WM retunes r
    # wm runs as an XLA chunked prefix: rows/unroll/in_register shape
    # nothing it executes, so only the radix (-> chunk) is swept
    rows_dom = (1,) if wl.variant == "wm" else pow2_range(1, max_rows)
    unroll_dom = (1,) if wl.variant == "wm" else (1, 2, 4)
    in_reg_dom = (0,) if wl.variant == "wm" else (0, 1)
    params = [
        ParamSpec("tile_n", (wl.n,)),           # whole system stays resident
        ParamSpec("rows_per_program", rows_dom),
        ParamSpec("radix", radix_dom),
        ParamSpec("unroll", unroll_dom),
        ParamSpec("in_register", in_reg_dom),
    ]
    return SearchSpace(
        wl,
        params,
        constraints=(
            vmem_fits(eb, spec=spec),
            rows_divide_batch(),
            radix_compatible(),
            in_register_rule(spec),
        ),
        spec=spec,
    )


def fft_space(wl: Workload,
              spec: Optional[HardwareProfile] = None) -> SearchSpace:
    spec = spec if spec is not None else active_profile()
    eb = 2 * dtype_bytes(wl.dtype)  # complex: interleaved re/im
    max_rows = floor_pow2(min(256, max(wl.batch, 1)))
    params = [
        ParamSpec("tile_n", (wl.n,)),
        ParamSpec("rows_per_program", pow2_range(1, max_rows)),
        ParamSpec("radix", (2, 4, 8, 16)),      # Stockham radix (paper: {2,4,8,16})
        ParamSpec("unroll", (1, 2, 4)),
        ParamSpec("in_register", (0,)),          # paper: no shuffle for FFT
    ]
    return SearchSpace(
        wl,
        params,
        constraints=(vmem_fits(eb, spec=spec), rows_divide_batch(),
                     radix_compatible()),
        spec=spec,
    )


def large_fft_space(wl: Workload, max_tile: int = 4096,
                    spec: Optional[HardwareProfile] = None) -> SearchSpace:
    """Multi-pass FFT (paper §IV-C): N exceeds the on-chip tile -> m passes.

    The space covers (tile_n per pass, radix per pass, rows). tile_n here is
    the per-pass working-set S; m = ceil(log(N)/log(S)).
    """
    spec = spec if spec is not None else active_profile()
    eb = 2 * dtype_bytes(wl.dtype)
    max_rows = floor_pow2(min(64, max(wl.batch, 1)))
    tiles = tuple(v for v in pow2_range(256, max_tile))
    params = [
        ParamSpec("tile_n", tiles),
        ParamSpec("rows_per_program", pow2_range(1, max_rows)),
        ParamSpec("radix", (2, 4, 8, 16)),
        ParamSpec("unroll", (1, 2, 4)),
        ParamSpec("in_register", (0,)),
    ]

    def tile_le_n(cfg: Config, w: Workload) -> bool:
        return cfg["tile_n"] <= w.n

    return SearchSpace(
        wl,
        params,
        constraints=(vmem_fits(eb, spec=spec), rows_divide_batch(),
                     radix_compatible(), tile_le_n),
        spec=spec,
    )


def attention_space(wl: Workload,
                    spec: Optional[HardwareProfile] = None) -> SearchSpace:
    """Flash-attention block sizes (beyond-paper application of the method).

    wl.n = kv sequence length; wl.batch = #(batch*heads) rows.
    """
    spec = spec if spec is not None else active_profile()
    # no `unroll` knob: the flash kernel's inner loop is the block_k walk —
    # there is nothing to unroll independently of block_k, so sweeping it
    # only duplicated configs (the repro.analysis dead-knob detector flags
    # exactly this class; same pruning as linrec's unroll)
    params = [
        ParamSpec("block_q", (128, 256, 512, 1024)),
        ParamSpec("block_k", (128, 256, 512, 1024, 2048)),
        ParamSpec("rows_per_program", (1,)),
        ParamSpec("radix", (2,)),
        ParamSpec("in_register", (0,)),
    ]

    def blocks_fit(cfg: Config, w: Workload) -> bool:
        head_dim = 128
        eb = 2  # bf16
        # q-block + k-block + v-block + scores
        foot = (cfg["block_q"] + 2 * cfg["block_k"]) * head_dim * eb
        foot += cfg["block_q"] * cfg["block_k"] * 4
        return foot * 2 <= spec.vmem_budget and cfg["block_k"] <= w.n \
            and cfg["block_q"] <= w.n

    return SearchSpace(wl, params, constraints=(blocks_fit,), spec=spec)


def matmul_space(wl: Workload,
                 spec: Optional[HardwareProfile] = None) -> SearchSpace:
    """Tiled matmul (M=batch, K=N=wl.n simplification for tuning demos)."""
    spec = spec if spec is not None else active_profile()
    params = [
        ParamSpec("block_m", (128, 256, 512)),
        ParamSpec("block_n", (128, 256, 512, 1024)),
        ParamSpec("block_k", (128, 256, 512, 1024, 2048)),
    ]

    def fits(cfg: Config, w: Workload) -> bool:
        eb = 2
        foot = (cfg["block_m"] * cfg["block_k"] + cfg["block_k"] * cfg["block_n"]) * eb
        foot += cfg["block_m"] * cfg["block_n"] * 4
        return foot * 2 <= spec.vmem_budget

    return SearchSpace(wl, params, constraints=(fits,), spec=spec)


_SPACE_BUILDERS: Dict[str, Callable[[Workload], SearchSpace]] = {
    "scan": scan_space,
    "tridiag": tridiag_space,
    "fft": fft_space,
    "large_fft": large_fft_space,
    "ssd": scan_space,        # the SSD inter-chunk scan shares the scan space
    "rglru": linrec_space,    # rglru IS a linrec: dead unroll knob pruned
    "attention": attention_space,
    "matmul": matmul_space,
}


def build_space(wl: Workload,
                profile: Optional[HardwareProfile] = None, *,
                spec: Optional[HardwareProfile] = None) -> SearchSpace:
    """Search space for ``wl`` bounded by ``profile`` (default: active
    profile).  ``spec=`` is a deprecated alias for ``profile=`` (the name
    the pre-policy API used — see docs/hardware.md).

    Externally registered builders that predate the profile layer may not
    take a ``spec`` argument; they are called without one and keep their
    own bounds.
    """
    if spec is not None:
        warnings.warn("build_space(spec=...) is deprecated; pass profile=...",
                      DeprecationWarning, stacklevel=2)
        if profile is None:
            profile = spec
    try:
        builder = _SPACE_BUILDERS[wl.op]
    except KeyError:
        raise KeyError(f"no search space registered for op={wl.op!r}") from None
    if profile is None:
        return builder(wl)
    try:
        params = inspect.signature(builder).parameters
        accepts_spec = "spec" in params or any(
            p.kind is p.VAR_KEYWORD for p in params.values())
    except (TypeError, ValueError):
        accepts_spec = False
    return builder(wl, spec=profile) if accepts_spec else builder(wl)


def register_space(op: str, builder: Callable[[Workload], SearchSpace]) -> None:
    _SPACE_BUILDERS[op] = builder


# ---------------------------------------------------------------------------
# Shared config normalization (launch-geometry fitting)
# ---------------------------------------------------------------------------
# Tuned configs are stored for the workload they were searched on; at launch
# time the knobs must still divide the actual array dims (a stored tile of
# 512 against n=384, say). Every kernel family used to carry its own copy of
# this halving descent; it lives here now and per-op normalizers in
# kernels/*/ops.py compose it.

def fit_block(value: int, dim: int) -> int:
    """Largest v <= min(value, dim) reachable by halving with dim % v == 0."""
    v = int(max(min(value, dim), 1))
    while dim % v:
        v //= 2
    return max(v, 1)


def normalize_config(cfg: Mapping[str, int], wl: Workload,
                     dims: Optional[Mapping[str, int]] = None) -> Config:
    """Generic normalizer: snap row/tile knobs to the workload dims.

    Per-op normalizers registered via ``repro.tuning.tuned_kernel`` take
    precedence; this fallback handles any op without one.
    """
    out = dict(cfg)
    if "rows_per_program" in out:
        out["rows_per_program"] = fit_block(out["rows_per_program"],
                                            max(wl.batch, 1))
    if "tile_n" in out:
        out["tile_n"] = fit_block(out["tile_n"], wl.n)
    return out
