"""FFT kernels (Stockham Pallas + four-step) vs jnp.fft oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fft.kernel import fft_pallas
from repro.kernels.fft.ops import fft, ifft
from repro.kernels.fft.ref import fft_ref, four_step_ref, stockham_jnp

RNG = np.random.default_rng(1)


def _cx(batch, n):
    return jnp.asarray(RNG.normal(size=(batch, n))
                       + 1j * RNG.normal(size=(batch, n)), jnp.complex64)


@pytest.mark.parametrize("n", [64, 256, 1024])
@pytest.mark.parametrize("radix", [2, 4, 8, 16])
def test_stockham_kernel_all_radices(n, radix):
    x = _cx(4, n)
    got = fft(x, config={"radix": radix, "rows_per_program": 2, "tile_n": n},
              interpret=True)
    ref = fft_ref(x)
    err = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
    assert err < 1e-4, f"n={n} radix={radix}: {err}"


def test_mixed_radix_sizes():
    # 128 = 16 * 8: ragged final stage exercises the mixed-radix path
    x = _cx(2, 128)
    got = fft(x, config={"radix": 16, "rows_per_program": 2, "tile_n": 128},
              interpret=True)
    err = float(jnp.max(jnp.abs(got - fft_ref(x))))
    assert err < 1e-3


def test_four_step_large():
    x = _cx(2, 2**15)
    got = fft(x, config={"radix": 8, "rows_per_program": 2, "tile_n": 1024},
              interpret=True)
    ref = fft_ref(x)
    err = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
    assert err < 1e-4


def test_roundtrip():
    x = _cx(2, 512)
    cfg = {"radix": 4, "rows_per_program": 2, "tile_n": 512}
    rt = ifft(fft(x, config=cfg, interpret=True), config=cfg, interpret=True)
    assert float(jnp.max(jnp.abs(rt - x))) < 1e-4


def test_ref_formulations_agree():
    x = _cx(2, 1024)
    ref = fft_ref(x)
    for r in [2, 4, 8]:
        err = float(jnp.max(jnp.abs(stockham_jnp(x, r) - ref)))
        assert err < 1e-3
    err = float(jnp.max(jnp.abs(four_step_ref(x, 64) - ref)))
    assert err < 1e-3


def test_split_plane_kernel_direct():
    x = _cx(4, 256)
    re, im = jnp.real(x), jnp.imag(x)
    yre, yim = fft_pallas(re, im, rows_per_program=2, radix=4, interpret=True)
    ref = fft_ref(x)
    err = float(jnp.max(jnp.abs((yre + 1j * yim) - ref)))
    assert err < 1e-3
