"""Hardware profiles — parametric machine models as data, not code.

The paper's headline claim is performance *portability*: the same
analytical and ML tuning methodologies retarget from a server GPU to an
embedded Jetson by swapping the machine model underneath (PAPER.md
§III–V).  This module is that swap point.  A :class:`HardwareProfile` is
a frozen dataclass of architectural constants (peak rates, memory
hierarchy, tiling geometry, launch/DMA/sync latencies, mesh geometry — a
strict superset of the historical ``TpuSpec``) plus the machine-model
response curves evaluated against it (lane/sublane utilization, DMA
bandwidth ramp, ILP issue factor).

Every layer that used to import ``hw.tpu.V5E`` directly now carries a
profile: ``SearchSpace`` validity bounds, ``StagePlan`` VMEM/pass
accounting, the cost-model objective, ``TunerSession`` (profile names key
TuningDB entries and sweep-journal signatures), and the ML featurizer
(device columns, so one forest can pool rows across profiles).

Registry
--------
Three concrete profiles ship (see docs/hardware.md for the field
glossary and how to add a device):

* ``tpu_v5e``   — the historical constants, **bit-identical** costs to the
  pre-profile ``TPUCostModelObjective`` (pinned by fixture test);
* ``gpu_sm``    — a CUDA-core/SMEM-shaped profile in the spirit of the
  paper's GM20B table, with the Pallas Triton backend's geometry (warp
  lanes, tensor-core tile, kernel-relaunch sync);
* ``cpu_interpret`` — the pallas interpret-mode host, so the profile
  layer is exercisable in CI without accelerators.

``active_profile()`` resolves ``$REPRO_HW_PROFILE`` (default
``tpu_v5e``), which is how the CI profile matrix retargets the whole
stack without touching call sites.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """One device's architectural constants (the paper's Table of limits).

    Field defaults ARE the TPU v5e machine model — ``HardwareProfile()``
    is bit-identical to the historical ``TpuSpec()`` so every cost the
    pre-profile stack computed is reproduced exactly.
    """

    name: str = "tpu_v5e"
    # --- identity ---
    kind: str = "tpu"                     # "tpu" | "gpu" | "cpu"
    backend: str = "pallas_tpu"           # "pallas_tpu" | "pallas_triton"
    #                                       | "interpret"
    # --- per-chip peak rates ---
    peak_bf16_flops: float = 197e12       # FLOP/s per chip, matrix-unit bf16
    peak_f32_flops: float = 98.5e12       # matrix-unit f32
    peak_vpu_flops: float = 3.2e12        # vector/elementwise f32
    hbm_bandwidth: float = 819e9          # B/s per chip
    ici_link_bandwidth: float = 50e9      # B/s per interconnect link
    # --- memory hierarchy ---
    hbm_bytes: int = 16 * 2**30           # device memory per chip
    vmem_bytes: int = 128 * 2**20         # fast on-chip scratch pool
    vmem_budget: int = 64 * 2**20         # usable budget for kernel
    #                                       working sets (SearchSpace bound)
    # --- tiling geometry ---
    lane_count: int = 128                 # trailing vector dim (warp width
    #                                       on GPU, SIMD lanes on CPU)
    sublane_count: int = 8                # second-to-last vector dim
    mxu_dim: int = 128                    # matrix-unit edge (tensor-core
    #                                       tile on GPU)
    # --- pipeline model ---
    dma_latency_s: float = 2e-6           # per-block DMA issue latency
    kernel_launch_s: float = 5e-6         # fixed kernel-launch overhead
    pass_sync_s: float = 1.5e-6           # per-pass barrier/flush cost
    dma_half_bytes: int = 64 * 2**10      # DMA ramp half-saturation point
    ilp_base: float = 0.55                # issue utilization at unroll=1
    ilp_slope: float = 0.15               # utilization gained per doubling
    # --- power model (energy = idle + compute-activity + data-movement) ---
    idle_w: float = 60.0                  # static draw while a kernel runs
    peak_compute_w: float = 140.0         # dynamic draw of busy compute units
    hbm_pj_per_byte: float = 150.0        # pJ per byte moved through HBM/DDR
    # --- mesh geometry ---
    chips_per_pod: int = 256


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

TPU_V5E = HardwareProfile()

GPU_SM = HardwareProfile(
    name="gpu_sm",
    kind="gpu",
    backend="pallas_triton",
    # Ampere-class server part (where the Pallas Triton backend runs),
    # with the CUDA-core/SMEM field shape of the paper's GM20B table
    peak_bf16_flops=165e12,               # tensor-core bf16
    peak_f32_flops=19.5e12,               # tensor-core tf32-ish
    peak_vpu_flops=19.5e12,               # CUDA-core f32
    hbm_bandwidth=1555e9,
    ici_link_bandwidth=600e9,             # NVLink
    hbm_bytes=40 * 2**30,
    vmem_bytes=40 * 2**20,                # L2 slice + SMEM pool
    vmem_budget=512 * 2**10,              # per-CTA staging budget (SMEM +
    #                                       register file the scheduler can
    #                                       keep resident per program)
    lane_count=32,                        # warp width
    sublane_count=4,                      # scheduler partitions per SM
    mxu_dim=16,                           # tensor-core tile edge
    dma_latency_s=1e-6,
    kernel_launch_s=8e-6,                 # CUDA launch overhead
    pass_sync_s=4e-6,                     # global barrier == kernel relaunch
    dma_half_bytes=32 * 2**10,            # coalescing saturates earlier
    ilp_base=0.60,
    ilp_slope=0.10,
    idle_w=90.0,                          # server-part static draw
    peak_compute_w=310.0,                 # SM array at full issue
    hbm_pj_per_byte=180.0,                # HBM2e access energy
    chips_per_pod=8,                      # one NVLink island
)

CPU_INTERPRET = HardwareProfile(
    name="cpu_interpret",
    kind="cpu",
    backend="interpret",
    # pallas interpret mode on the CI host: AVX-ish vector unit, DDR
    # bandwidth, LLC as the "VMEM" analogue.  Exists so the profile layer
    # (spaces, plans, objectives, DB keying) is exercisable in CI without
    # accelerators — the constants are deliberately round.
    peak_bf16_flops=5e10,                 # bf16 emulated: slower than f32
    peak_f32_flops=1e11,
    peak_vpu_flops=1e11,
    hbm_bandwidth=40e9,
    ici_link_bandwidth=10e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=32 * 2**20,                # last-level cache
    vmem_budget=4 * 2**20,                # per-program resident working set
    lane_count=8,                         # AVX f32 lanes
    sublane_count=1,
    mxu_dim=8,
    dma_latency_s=1e-7,
    kernel_launch_s=50e-6,                # interpret-mode dispatch is slow
    pass_sync_s=1e-6,
    dma_half_bytes=4 * 2**10,             # streaming saturates quickly
    ilp_base=0.70,
    ilp_slope=0.10,
    idle_w=20.0,                          # host package at light load
    peak_compute_w=45.0,                  # vector units saturated
    hbm_pj_per_byte=400.0,                # DDR access is energy-expensive
    chips_per_pod=1,
)

_PROFILES: Dict[str, HardwareProfile] = {}


def register_profile(profile: HardwareProfile) -> HardwareProfile:
    """Add (or replace) a profile in the registry; returns it."""
    _PROFILES[profile.name] = profile
    return profile


def get_profile(name: str) -> HardwareProfile:
    try:
        return _PROFILES[name]
    except KeyError:
        raise ValueError(f"unknown hardware profile {name!r}; registered: "
                         f"{', '.join(profiles())}") from None


def profiles() -> Tuple[str, ...]:
    return tuple(sorted(_PROFILES))


def active_profile() -> HardwareProfile:
    """The process-wide default profile: ``$REPRO_HW_PROFILE`` or tpu_v5e.

    Read per call (cheap dict lookups), so tests and the CI matrix can
    retarget the stack by environment without import-order traps.
    """
    return get_profile(os.environ.get("REPRO_HW_PROFILE", "tpu_v5e"))


for _p in (TPU_V5E, GPU_SM, CPU_INTERPRET):
    register_profile(_p)


# ---------------------------------------------------------------------------
# Profile distance (cross-device transfer weighting)
# ---------------------------------------------------------------------------

# rate/geometry fields that shape a kernel's operating point; latencies are
# included because pass-heavy configs trade differently on launch-expensive
# devices
_DISTANCE_FIELDS = (
    "peak_vpu_flops", "peak_f32_flops", "hbm_bandwidth", "vmem_budget",
    "lane_count", "sublane_count", "mxu_dim", "kernel_launch_s",
    "pass_sync_s", "dma_half_bytes",
)


def profile_distance(a: HardwareProfile, b: HardwareProfile) -> float:
    """Mean |log2 ratio| over the rate/geometry fields; 0.0 iff identical.

    The transfer-seeding weight is ``exp(-distance)``: a device twice as
    fast in every dimension is "one octave away" and its journal evidence
    is discounted accordingly — close devices transfer almost fully,
    wildly different ones barely at all.
    """
    total = 0.0
    for field in _DISTANCE_FIELDS:
        va, vb = float(getattr(a, field)), float(getattr(b, field))
        total += abs(math.log2(max(va, 1e-30) / max(vb, 1e-30)))
    return total / len(_DISTANCE_FIELDS)


# ---------------------------------------------------------------------------
# Machine-model response curves
# ---------------------------------------------------------------------------
# Scalar and vectorized forms mirror each other element-for-element so
# batched and per-config evaluation agree to floating-point identity (the
# sweep engine depends on this).

def dtype_bytes(dtype) -> int:
    return np.dtype(dtype).itemsize


def effective_element_bytes(op: str, dtype) -> int:
    """Bytes one logical element of ``op`` moves through memory.

    Per-family multipliers over the raw dtype width: a tridiagonal element
    is an equation of 4 coefficients, an FFT element is an interleaved
    complex pair. The single source of truth for the analytical model, the
    cost objective, and the ML featurizer — which must agree, since the
    learned labels come from the cost model.
    """
    eb = dtype_bytes(dtype)
    if op == "tridiag":
        return 4 * eb
    if op in ("fft", "large_fft"):
        return 2 * eb
    return eb


def lane_utilization(trailing_dim: int,
                     spec: HardwareProfile = TPU_V5E) -> float:
    """Fraction of the lane dim that does useful work.

    The analogue of warp occupancy in the paper's guideline: a trailing
    dim of 96 on a 128-lane device wastes 25% of every vector issue; a
    trailing dim of 384 is three full tiles -> 1.0.
    """
    lanes = spec.lane_count
    if trailing_dim <= 0:
        return 0.0
    if trailing_dim >= lanes:
        full, rem = divmod(trailing_dim, lanes)
        used = full * lanes + rem
        tiles = full + (1 if rem else 0)
        return used / (tiles * lanes)
    return trailing_dim / lanes


def sublane_utilization(second_dim: int,
                        spec: HardwareProfile = TPU_V5E) -> float:
    sub = spec.sublane_count
    if second_dim <= 0:
        return 0.0
    if second_dim >= sub:
        full, rem = divmod(second_dim, sub)
        tiles = full + (1 if rem else 0)
        return second_dim / (tiles * sub)
    return second_dim / sub


def dma_efficiency(block_bytes: int,
                   spec: HardwareProfile = TPU_V5E) -> float:
    """Memory-bandwidth ramp: small transfers underutilize the system.

    Modeled as ``b / (b + b_half)`` with the half-saturation point a
    profile constant (64 KiB fits TPU DMA engines; GPUs coalesce earlier,
    CPUs stream-prefetch earlier still).
    """
    b_half = spec.dma_half_bytes
    return block_bytes / (block_bytes + b_half)


def ilp_factor(unroll: int, spec: HardwareProfile = TPU_V5E) -> float:
    """Issue-pipeline utilization vs in-kernel ILP (the paper's premise iii).

    One node-op per step leaves issue bubbles; saturates as unroll grows,
    with profile-specific base and slope.
    """
    return min(1.0, spec.ilp_base + spec.ilp_slope * math.log2(max(unroll, 1)))


# ---------------------------------------------------------------------------
# Vectorized counterparts (numpy arrays in, arrays out)
# ---------------------------------------------------------------------------

def lane_utilization_arr(trailing_dim, spec: HardwareProfile = TPU_V5E):
    t = np.asarray(trailing_dim, dtype=np.float64)
    lanes = float(spec.lane_count)
    full = np.floor(t / lanes)
    rem = t - full * lanes
    tiles = full + (rem > 0)
    multi = t / np.maximum(tiles * lanes, 1.0)
    out = np.where(t >= lanes, multi, t / lanes)
    return np.where(t <= 0, 0.0, out)


def sublane_utilization_arr(second_dim, spec: HardwareProfile = TPU_V5E):
    s = np.asarray(second_dim, dtype=np.float64)
    sub = float(spec.sublane_count)
    full = np.floor(s / sub)
    rem = s - full * sub
    tiles = full + (rem > 0)
    multi = s / np.maximum(tiles * sub, 1.0)
    out = np.where(s >= sub, multi, s / sub)
    return np.where(s <= 0, 0.0, out)


def dma_efficiency_arr(block_bytes, spec: HardwareProfile = TPU_V5E):
    b = np.trunc(np.asarray(block_bytes, dtype=np.float64))
    b_half = spec.dma_half_bytes
    return b / (b + b_half)


def ilp_factor_arr(unroll, spec: HardwareProfile = TPU_V5E):
    u = np.maximum(np.asarray(unroll, dtype=np.float64), 1.0)
    return np.minimum(1.0, spec.ilp_base + spec.ilp_slope * np.log2(u))
