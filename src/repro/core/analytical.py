"""Analytical model-driven tuning (paper §IV-A, adapted to TPU per DESIGN.md §2).

Zero-evaluation tuner: scores every valid configuration with an ordinal
occupancy model and returns the argmax. This is the *online* methodology —
it answers immediately from architectural reasoning, exactly like the paper's
guideline answers from the GM20B occupancy table (Fig 3a).

TPU guideline (re-derivation of the paper's four rules):
  1. Prefer configs achieving BOTH full pipeline overlap (>= OVERLAP_GRID
     grid programs, double-buffered VMEM fit) AND full lane utilization.
  2. Else maximize grid parallelism while lane utilization stays in
     [0.60, 1.00] (the paper's warp-occupancy band).
  3. Else maximize lane utilization; among ties prefer larger unroll (ILP).
  4. If the pattern admits a larger radix, prefer it even at reduced grid
     parallelism (fewer passes/sync points, more ILP).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.space import Config, SearchSpace

OVERLAP_GRID = 4          # grid programs needed for full DMA/compute overlap
OCCUPANCY_BAND = (0.60, 1.00)

# keys every resources() dict carries (the plan <-> model contract);
# repro.analysis verifies presence and finiteness for every valid config
# of every op x profile, so the expert model can never silently read a
# missing quantity as 0
RESOURCE_KEYS = ("grid", "vmem", "occupancy", "ilp", "radix", "passes",
                 "block_bytes", "seq_tiles", "stage_count", "steps_per_pass",
                 "ragged", "lane_eff", "sublane_eff")


@dataclasses.dataclass
class AnalyticalScore:
    tier: int              # 3 = rule-1 configs, 2 = rule-2, 1 = rule-3 (higher better)
    pass_rank: float       # paper §IV-C premise: minimize the number of
    #                        passes/kernels FIRST (each extra pass is a full
    #                        HBM roundtrip) — ranks above the radix choice.
    #                        Chain-aware: StagePlan.passes counts XLA chain
    #                        links too (``xla_passes``), so the chain-fusion
    #                        knob (``fuse``) is rewarded here — a fused
    #                        chain's saved HBM pass ranks before any
    #                        blocking preference
    seq_rank: float        # TPU twist on the same premise: a fused carry
    #                        chain serializes its column tiles, so fewer
    #                        sequential tiles rank next
    radix_rank: float      # rule 4
    block_rank: float      # TPU adaptation of the paper's Ba maximization:
    #                        once >= OVERLAP_GRID programs keep the pipeline
    #                        full, BIGGER DMA blocks win (grid programs are
    #                        sequential per core, unlike CUDA blocks/SM)
    occupancy: float
    ilp_rank: float

    def key(self) -> Tuple:
        # Lexicographic: tier, then pass count (§IV-C), then carry-chain
        # depth, then radix (rule 4 overrides block choice), then the
        # tier-specific objective, then ILP tie-break.
        return (self.tier, self.pass_rank, self.seq_rank, self.radix_rank,
                self.block_rank, self.occupancy, self.ilp_rank)


def resources(space: SearchSpace, cfg: Config) -> Dict[str, float]:
    """Architectural resource accounting for one candidate config.

    Everything is read off the :class:`~repro.kernels.blocks.plan.StagePlan`
    — the exact staged execution the kernel drivers will launch — so the
    expert model and the kernels cannot disagree about pass counts, VMEM
    footprints or stage structure.  Public entry point for consumers that
    stack on the analytical model, notably ``repro.tuning.ml.features``.
    """
    # late import: repro.core.__init__ -> analytical must not re-enter
    # blocks.plan while the package is still initializing
    from repro.kernels.blocks.plan import plan_for

    return plan_for(space.workload, cfg, profile=space.spec).resources()


def score(space: SearchSpace, cfg: Config,
          res: Optional[Dict[str, float]] = None) -> AnalyticalScore:
    """Guideline score; pass ``res`` from :func:`resources` to avoid
    recomputing the accounting when the caller already has it."""
    if res is None:
        res = resources(space, cfg)
    spec = space.spec
    fits = res["vmem"] <= spec.vmem_budget
    full_overlap = res["grid"] >= OVERLAP_GRID and fits
    occ = res["occupancy"]
    lo, hi = OCCUPANCY_BAND

    if full_overlap and occ >= 0.999:
        tier = 3
    elif fits and lo <= occ <= hi:
        tier = 2
    elif fits:
        tier = 1
    else:
        tier = 0

    # rule 4: larger radix preferred when it cuts passes/steps — but only
    # stage sequences that stay at the nominal fan-in throughout; a ragged
    # mixed-radix tail needs an extra odd step and more synchronizations
    # (the paper's own observation on WM's jagged performance), so the
    # expert ranks every exact radix above every mixed one.  The raggedness
    # comes from the plan's actual stage sequence, not a re-derivation.
    exact = 0 if res.get("ragged") else 1
    radix_rank = exact * 16.0 + math.log2(max(res["radix"], 2))
    # TPU rule 1/2 objective: biggest DMA block that still leaves the
    # pipeline >= OVERLAP_GRID programs deep (saturating at 4 MiB, past
    # which the DMA ramp is flat).
    if res["grid"] >= OVERLAP_GRID:
        block_rank = math.log2(min(max(res["block_bytes"], 1), 4 * 2**20))
    else:
        block_rank = -1.0   # starves the pipeline: strictly worse
    return AnalyticalScore(tier, -res["passes"],
                           -math.log2(max(res.get("seq_tiles", 1), 1)),
                           radix_rank, block_rank, occ,
                           math.log2(max(res["ilp"], 1)))


class AnalyticalTuner:
    """Ranks the valid space with the guideline; no objective evaluations."""

    name = "analytical"

    def suggest(self, space: SearchSpace) -> Config:
        best: Optional[Config] = None
        best_key: Optional[Tuple] = None
        for cfg in space.enumerate_valid():
            k = score(space, cfg).key()
            if best_key is None or k > best_key:
                best, best_key = cfg, k
        if best is None:
            raise ValueError(f"search space for {space.workload.key} has no valid config")
        return best

    def rank(self, space: SearchSpace, top: int = 5) -> List[Config]:
        cfgs = space.enumerate_valid()
        cfgs.sort(key=lambda c: score(space, c).key(), reverse=True)
        return cfgs[:top]
