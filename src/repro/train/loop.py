"""Training loop: data -> jitted train_step -> metrics/checkpoints, with the
fault-tolerance hooks wired in (auto-resume, straggler log, watchdog,
injectable failures for tests)."""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.models.model import Model
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import (FaultInjector, HeartbeatWatchdog,
                               StragglerDetector)
from repro.train.step import TrainHParams, init_train_state, make_train_step

PyTree = Any


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_last: int = 3
    seed: int = 0


def run_training(model: Model, hp: TrainHParams, loop: LoopConfig,
                 data: Iterator[Dict[str, np.ndarray]],
                 state: Optional[PyTree] = None,
                 device_put: Optional[Callable] = None,
                 injector: Optional[FaultInjector] = None,
                 log: Callable[[str], None] = print) -> Dict[str, Any]:
    """Returns {"state", "history", "resumed_from", "straggler_events"}."""
    step_fn = jax.jit(make_train_step(model, hp), donate_argnums=(0,))

    ckpt = (CheckpointManager(loop.checkpoint_dir, keep_last=loop.keep_last)
            if loop.checkpoint_dir else None)
    start_step = 0
    if state is None:
        state = init_train_state(model, hp, jax.random.PRNGKey(loop.seed))
        if ckpt is not None:
            resumed, restored = ckpt.restore_latest(
                jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), state))
            if restored is not None:
                state = jax.tree.map(jax.numpy.asarray, restored)
                start_step = resumed
                log(f"[loop] auto-resumed from step {resumed}")

    straggler = StragglerDetector()
    watchdog = HeartbeatWatchdog()
    history = []
    t_prev = time.perf_counter()
    try:
        for step in range(start_step, loop.total_steps):
            batch = next(data)
            if device_put is not None:
                batch = device_put(batch)
            if injector is not None:
                injector.maybe_fail(step)
            state, metrics = step_fn(state, batch)
            # block on the loss to get a truthful step time
            loss = float(metrics["loss"])
            now = time.perf_counter()
            dt = now - t_prev
            t_prev = now
            watchdog.beat()
            if straggler.observe(step, dt):
                log(f"[loop] straggler at step {step}: {dt:.3f}s "
                    f"(ema {straggler.ema:.3f}s)")
            if step % loop.log_every == 0 or step == loop.total_steps - 1:
                rec = {"step": step, "loss": loss,
                       "accuracy": float(metrics["accuracy"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "step_time_s": dt}
                history.append(rec)
                log(f"[loop] step {step}: loss={loss:.4f} "
                    f"acc={rec['accuracy']:.3f} gnorm={rec['grad_norm']:.2f} "
                    f"dt={dt:.2f}s")
            if ckpt is not None and (step + 1) % loop.checkpoint_every == 0:
                ckpt.save(step + 1, state)
    finally:
        # a crash (e.g. injected node failure) must not lose an in-flight
        # async checkpoint write: the restart resumes from it
        if ckpt is not None:
            try:
                ckpt.wait()
            except RuntimeError:
                # only suppress while another exception is propagating —
                # a write failure on the normal path must surface
                if sys.exc_info()[0] is None:
                    raise
    if ckpt is not None:
        ckpt.save(loop.total_steps, state)
        ckpt.wait()
    return {"state": state, "history": history, "resumed_from": start_step,
            "straggler_events": straggler.events}
