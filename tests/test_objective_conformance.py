"""Conformance: every Objective subclass's batch_eval == a sequential
__call__ loop, bit for bit.

PR 3's sweep engine routes ALL exhaustive evaluation through
``Objective.batch_eval``; the ground-truth optimum, the Phi denominators,
and the ML training labels are only correct if the batched protocol is
*exactly* the scalar protocol (valid -> time_s, invalid -> the penalty
clamp).  This suite locks that invariant for every subclass in the repo —
including ones whose batch_eval is the inherited default — and fails
when a new subclass ships without a conformance factory, so future
objectives (like the online wall-clock one this PR adds) cannot dodge it.

Factories return FRESH (objective, space, configs) per call: the scalar
loop and the batched pass each run on their own instance, so stateful
objectives (caches) must agree from a cold start, not by replaying
whatever the other path populated.
"""
import numpy as np
import pytest

from repro.core import TPUCostModelObjective, Workload, build_space
from repro.core.objective import (CachedObjective, Objective, PENALTY_TIME,
                                  WallClockObjective)

RNG_SEED = 20260802


def _iter_subclasses(cls):
    for sub in cls.__subclasses__():
        yield sub
        yield from _iter_subclasses(sub)


def _sample_configs(space, k=24, invalid=2, seed=RNG_SEED):
    """Randomized mix of valid configs and invalid mutants."""
    rng = np.random.default_rng(seed)
    cands = space.enumerate_valid()
    idx = rng.permutation(len(cands))[:k]
    cfgs = [dict(cands[int(i)]) for i in idx]
    for j in range(min(invalid, len(cfgs))):
        bad = dict(cfgs[j])
        knob = sorted(bad)[j % len(bad)]
        bad[knob] = 999                      # outside every domain
        cfgs.append(bad)
    return cfgs


# ---------------------------------------------------------------------------
# Factories: name -> () -> (objective, space, configs)
# ---------------------------------------------------------------------------

def _tpu_cost_model():
    space = build_space(Workload(op="scan", n=512, batch=2**17, variant="lf"))
    return TPUCostModelObjective(noise=0.02), space, _sample_configs(space)


def _gpu_cost_model():
    """The profile-parameterized cost model on a non-default device: the
    batch fast path must stay bit-identical to the scalar loop under
    every registered profile's constants, not just tpu_v5e's."""
    from repro.core.objective import CostModelObjective
    from repro.hw.profiles import GPU_SM

    space = build_space(Workload(op="scan", n=512, batch=2**17,
                                 variant="lf"), GPU_SM)
    return CostModelObjective(GPU_SM, noise=0.02), space, \
        _sample_configs(space)


def _cached():
    space = build_space(Workload(op="fft", n=256, batch=2**14,
                                 variant="stockham"))
    obj = CachedObjective(TPUCostModelObjective(noise=0.02))
    # duplicates: the batch path must answer repeats from its cache with
    # the identical measurement the scalar loop would re-read
    cfgs = _sample_configs(space, k=12)
    return obj, space, cfgs + cfgs[:4]


def _wallclock():
    """Deterministic wall clock: the runner's thunk advances the fake
    clock by a config-derived amount, so both the scalar loop and the
    batched walk measure exactly that per-config duration."""
    space = build_space(Workload(op="tridiag", n=128, batch=8,
                                 variant="pcr"))

    class FakeClock:
        t = 0.0

        def __call__(self):
            return self.t

    clock = FakeClock()

    def runner(wl, cfg):
        dt = 1e-6 * (1.0 + sum(cfg.values()) % 97)

        def thunk():
            clock.t += dt
        return thunk

    obj = WallClockObjective(runner, reps=3, warmup=1)
    obj._fake_clock = clock                      # picked up by the test
    return obj, space, _sample_configs(space, k=10)


def _online_wallclock():
    from repro.tuning.online import OnlineWallClockObjective
    from repro.tuning.sweep import config_key

    space = build_space(Workload(op="scan", n=256, batch=2**18,
                                 variant="ks"))
    cfgs = _sample_configs(space, k=16)
    rng = np.random.default_rng(RNG_SEED + 1)
    times = {}
    for cfg in cfgs[:10]:                        # the rest: never measured
        times[config_key(cfg)] = list(rng.uniform(1e-4, 1e-2, size=5))
    return OnlineWallClockObjective(times, source="conformance"), space, cfgs


def _policy_energy():
    """PolicyObjective: the scalar protocol must see the policy scalar
    exactly as the batched protocol computes it from the metric columns."""
    from repro.core.policy import PolicyObjective

    space = build_space(Workload(op="scan", n=512, batch=2**17,
                                 variant="lf"))
    obj = PolicyObjective(TPUCostModelObjective(noise=0.02), "energy")
    return obj, space, _sample_configs(space, k=16)


def _policy_memory_cap():
    """memory_cap clamps over-budget configs to the penalty in BOTH
    protocols; a tight cap guarantees the clamp actually fires."""
    from repro.core.policy import Policy, PolicyObjective

    space = build_space(Workload(op="fft", n=256, batch=2**14,
                                 variant="stockham"))
    obj = PolicyObjective(TPUCostModelObjective(),
                          Policy("memory_cap", cap_bytes=2.0 * 256 * 64 * 8))
    return obj, space, _sample_configs(space, k=16)


def _multipass():
    from repro.core.multikernel import MultiPassObjective

    space = build_space(Workload(op="large_fft", n=2**20, batch=64,
                                 variant="stockham"))
    return MultiPassObjective(), space, _sample_configs(space, k=12)


def _compiled_roofline():
    from repro.core.distributed_tuning import (CompiledRooflineObjective,
                                               distributed_space)

    space = distributed_space("qwen1.5-0.5b", "train_4k")
    # two valid configs only: each evaluation lowers and compiles a cell
    cfgs = _sample_configs(space, k=2, invalid=0)
    return CompiledRooflineObjective(), space, cfgs


FACTORIES = {
    # TPUCostModelObjective is an alias of CostModelObjective (the
    # subclass name discovery sees); the second entry runs the same
    # conformance on a non-default hardware profile
    "CostModelObjective": _tpu_cost_model,
    "CostModelObjective_gpu_sm": _gpu_cost_model,
    "CachedObjective": _cached,
    "WallClockObjective": _wallclock,
    "OnlineWallClockObjective": _online_wallclock,
    "MultiPassObjective": _multipass,
    "CompiledRooflineObjective": _compiled_roofline,
    # one per policy family: fallback scalarization (energy) and the
    # constraint clamp (memory_cap); latency wrapping is a numeric no-op
    "PolicyObjective": _policy_energy,
    "PolicyObjective_memory_cap": _policy_memory_cap,
}


def test_every_repro_objective_subclass_has_a_factory():
    """New Objective subclasses must register a conformance factory."""
    # import every module that defines objectives so discovery is complete
    import repro.core.distributed_tuning   # noqa: F401
    import repro.core.multikernel          # noqa: F401
    import repro.core.objective            # noqa: F401
    import repro.core.policy               # noqa: F401
    import repro.tuning.online             # noqa: F401

    missing = sorted(
        cls.__name__ for cls in _iter_subclasses(Objective)
        if cls.__module__.startswith("repro")
        and cls.__name__ not in FACTORIES)
    assert not missing, \
        f"Objective subclasses without a conformance factory: {missing} — " \
        f"add one to tests/test_objective_conformance.py::FACTORIES"


@pytest.mark.parametrize("name", sorted(FACTORIES))
def test_batch_eval_bit_identical_to_sequential_loop(name, monkeypatch):
    import time as time_mod

    factory = FACTORIES[name]

    def measure_scalar():
        obj, space, cfgs = factory()
        if hasattr(obj, "_fake_clock"):
            monkeypatch.setattr(time_mod, "perf_counter", obj._fake_clock)
        out = np.empty(len(cfgs))
        for i, cfg in enumerate(cfgs):
            m = obj(space, cfg)
            out[i] = m.time_s if m.valid else PENALTY_TIME
        return out

    def measure_batched():
        obj, space, cfgs = factory()
        if hasattr(obj, "_fake_clock"):
            monkeypatch.setattr(time_mod, "perf_counter", obj._fake_clock)
        return obj.batch_eval(space, cfgs)

    seq = measure_scalar()
    batched = measure_batched()
    assert batched.dtype == np.float64 and len(batched) == len(seq)
    assert np.array_equal(seq, batched), \
        f"{name}: batch_eval diverged from the sequential loop at " \
        f"{np.flatnonzero(seq != batched)[:5]}"


@pytest.mark.parametrize("name", sorted(FACTORIES))
def test_batch_eval_empty_candidate_set(name):
    obj, space, _ = FACTORIES[name]()
    out = obj.batch_eval(space, [])
    assert len(out) == 0


def test_signature_distinguishes_parameterizations():
    """Same-class objectives with different measurement parameters must
    not share a journal identity (the resume-corruption vector)."""
    from repro.tuning.online import OnlineWallClockObjective

    assert TPUCostModelObjective(noise=0.0).signature() \
        != TPUCostModelObjective(noise=0.5).signature()
    assert OnlineWallClockObjective({}, source="serve").signature() \
        != OnlineWallClockObjective({}, source="replay").signature()


@pytest.mark.parametrize("name", sorted(FACTORIES))
def test_batch_eval_metrics_bit_identical_to_scalar_loop(name, monkeypatch):
    """The vector protocol obeys the same contract per metric axis:
    batch_eval_metrics == a sequential __call__ loop reading each axis
    (invalid configs -> that axis's penalty), bit for bit."""
    import time as time_mod

    from repro.core.objective import metric_penalty

    factory = FACTORIES[name]

    def scalar_cols():
        obj, space, cfgs = factory()
        if hasattr(obj, "_fake_clock"):
            monkeypatch.setattr(time_mod, "perf_counter", obj._fake_clock)
        names = obj.metric_names()
        cols = {n: np.empty(len(cfgs)) for n in names}
        for i, cfg in enumerate(cfgs):
            m = obj(space, cfg)
            for n in names:
                cols[n][i] = m.metric(n, metric_penalty(n)) if m.valid \
                    else metric_penalty(n)
        return names, cols

    def batched_cols():
        obj, space, cfgs = factory()
        if hasattr(obj, "_fake_clock"):
            monkeypatch.setattr(time_mod, "perf_counter", obj._fake_clock)
        return obj.batch_eval_metrics(space, cfgs)

    names, seq = scalar_cols()
    batched = batched_cols()
    assert set(batched) == set(names)
    for n in names:
        assert np.array_equal(seq[n], batched[n]), \
            f"{name}: batch_eval_metrics[{n}] diverged from the scalar " \
            f"loop at {np.flatnonzero(seq[n] != batched[n])[:5]}"
