"""End-to-end training loop: loss decreases; checkpoint-restart works."""
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.data.pipeline import Batcher, DataConfig
from repro.models.model import build_model
from repro.train.fault import FaultInjector
from repro.train.loop import LoopConfig, run_training
from repro.train.step import TrainHParams


def _setup(arch="qwen1.5-0.5b", steps=8):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    hp = TrainHParams(peak_lr=5e-3, warmup_steps=2, total_steps=steps,
                      z_weight=0.0)
    data = iter(Batcher(DataConfig(vocab=cfg.vocab, seq_len=64,
                                   global_batch=4)))
    return model, hp, data


def test_loss_decreases():
    model, hp, data = _setup(steps=12)
    out = run_training(model, hp, LoopConfig(total_steps=12, log_every=1),
                       data, log=lambda *_: None)
    hist = out["history"]
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert np.isfinite(last) and last < first


def test_checkpoint_restart_after_injected_failure(tmp_path):
    model, hp, data = _setup(steps=8)
    loop = LoopConfig(total_steps=8, checkpoint_dir=str(tmp_path),
                      checkpoint_every=2, log_every=100)
    inj = FaultInjector(fail_at_steps=(5,))
    with pytest.raises(RuntimeError, match="injected node failure"):
        run_training(model, hp, loop, data, injector=inj,
                     log=lambda *_: None)
    # restart: auto-resumes from step 4's checkpoint and completes
    model2, hp2, data2 = _setup(steps=8)
    out = run_training(model2, hp2, loop, data2, injector=inj,
                       log=lambda *_: None)
    assert out["resumed_from"] >= 4
    assert out["history"][-1]["step"] == 7


def test_grad_accum_equivalence():
    """micro_steps=2 produces the same loss trajectory scale (sanity)."""
    model, hp, data = _setup(steps=4)
    import dataclasses
    hp2 = dataclasses.replace(hp, micro_steps=2)
    out = run_training(model, hp2, LoopConfig(total_steps=4, log_every=1),
                       data, log=lambda *_: None)
    assert np.isfinite(out["history"][-1]["loss"])
