"""StagePlan invariants + kernel/model conformance.

The building-block contract (ISSUE 5 acceptance): for every config in a
space's ``enumerate_valid()``, the StagePlan's ``passes``/``vmem_bytes``/
grid must match what the rebuilt scan/fft/tridiag kernels actually launch
— counted through ``driver.capture_launches`` and re-derived here from
the kernels' own BlockSpec arithmetic, so the plan cannot drift from the
execution without failing this file.
"""
import math

import numpy as np
import pytest

from repro.core.space import Workload, build_space
from repro.hw.profiles import TPU_V5E as V5E
from repro.kernels.blocks import driver
from repro.kernels.blocks.plan import (DEFAULT_SEQ_LIMIT, build_plan,
                                       plan_for, stage_radices,
                                       stage_strides, wm_chunk)
from repro.tuning.registry import normalizer_for


# ---------------------------------------------------------------------------
# stage_radices invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 8, 12, 96, 97, 128, 384, 768, 1024])
@pytest.mark.parametrize("radix", [2, 3, 4, 8, 16])
def test_stage_radices_product_is_n(n, radix):
    stages = stage_radices(n, radix)
    assert math.prod(stages) == max(n, 1)
    assert all(r >= 2 for r in stages)
    # strides are the running product (the KS window after each level)
    strides = stage_strides(stages)
    for (r, s0), s1 in zip(zip(stages, strides), strides[1:]):
        assert s1 == s0 * r


def test_stage_radices_prefers_nominal_fan_in():
    assert stage_radices(512, 8) == (8, 8, 8)
    assert stage_radices(96, 8) == (8, 6, 2)      # ragged mixed-radix tail
    assert stage_radices(96, 3) == (3, 2, 2, 2, 2, 2)
    assert stage_radices(97, 2) == (97,)          # prime falls through whole


# ---------------------------------------------------------------------------
# Plan invariants over whole spaces
# ---------------------------------------------------------------------------

_WORKLOADS = [
    Workload(op="scan", n=256, batch=8, variant="ks"),
    Workload(op="scan", n=256, batch=8, variant="linrec"),
    Workload(op="tridiag", n=128, batch=8, variant="pcr"),
    Workload(op="tridiag", n=128, batch=8, variant="wm"),
    Workload(op="fft", n=128, batch=8, variant="stockham"),
    Workload(op="large_fft", n=2**15, batch=4, variant="stockham"),
    Workload(op="ssd", n=512, batch=16, variant=""),
    Workload(op="rglru", n=256, batch=32, variant=""),
]


@pytest.mark.parametrize("wl", _WORKLOADS, ids=lambda w: w.key)
def test_plan_invariants_over_valid_space(wl):
    space = build_space(wl)
    cfgs = space.enumerate_valid()
    assert cfgs
    for cfg in cfgs:
        plan = plan_for(wl, cfg)
        # the resident tile's stage sequence factors it exactly
        assert math.prod(plan.stages) == max(plan.tile_n, 1) \
            or plan.op in ("tridiag",)   # pcr/xla stage over n, radix 2
        if plan.op == "tridiag":
            assert math.prod(plan.stages) >= plan.n
        # valid configs fit the budget the spaces enforce
        assert plan.vmem_bytes <= V5E.vmem_budget * 2
        # HBM pass count == launch count + the chain's XLA links for
        # pallas-backed plans (rglru's unfused gate is an XLA pass)
        if plan.launches:
            assert plan.passes == len(plan.launches) + plan.xla_passes
        assert plan.seq_tiles >= 1 and plan.grid_size >= 1
        res = plan.resources()
        assert res["passes"] == plan.passes
        assert res["vmem"] == plan.vmem_bytes


def test_multipass_triggers_past_seq_limit():
    wl = Workload(op="scan", n=1024, batch=4, variant="ks")
    cfg = {"tile_n": 64, "rows_per_program": 2, "radix": 2, "unroll": 1}
    fused = build_plan(wl, cfg)
    assert fused.kind == "fused" and fused.passes == 1
    assert fused.seq_tiles == 16 <= DEFAULT_SEQ_LIMIT
    multi = build_plan(wl, cfg, seq_limit=8)
    assert multi.kind == "multipass" and multi.passes == 3
    assert [l.name for l in multi.launches] == \
        ["chunk-scan", "carry-scan", "apply-entry"]


def test_rglru_space_prunes_unroll_without_kernel_import():
    """The static _SPACE_BUILDERS entry and the @tuned_kernel registration
    must agree: the numpy-only ML path builds rglru spaces without ever
    importing the jax kernel module, and must see the pruned space."""
    space = build_space(Workload(op="rglru", n=512, batch=1024))
    assert space.param("unroll").domain == (1,)
    assert all(c["unroll"] == 1 for c in space.enumerate_valid())


def test_wm_chunk_single_source():
    """The normalizer's chunk and the plan's chunk are the same function —
    the resolved config uniquely determines the executed kernel."""
    wl = Workload(op="tridiag", n=256, batch=8, variant="wm")
    norm = normalizer_for("tridiag")({"radix": 8}, wl, None)
    assert norm == {"radix": 8, "chunk": wm_chunk(8, 256)}


# ---------------------------------------------------------------------------
# Launch conformance: what runs is what the plan promised
# ---------------------------------------------------------------------------

def _expected_scan_vmem(rows, tile, planes):
    return planes * rows * tile * 4 + rows * 4      # f32 io + carry scratch


def test_scan_conformance_every_valid_config():
    import jax.numpy as jnp

    from repro.kernels.scan.ops import prefix_sum
    from repro.kernels.scan.ref import scan_add_ref
    wl = Workload(op="scan", n=128, batch=4, variant="ks")
    space = build_space(wl)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 128)),
                    jnp.float32)
    ref = scan_add_ref(x)
    for cfg in space.enumerate_valid():
        norm = normalizer_for("scan")(cfg, wl, None)
        plan = plan_for(wl, norm)
        with driver.capture_launches() as rec:
            got = prefix_sum(x, config=cfg, interpret=True, use_pallas=True)
        assert len(rec) == plan.passes == 1
        launch = rec[0]
        rows, tile = norm["rows_per_program"], norm["tile_n"]
        # grid re-derived from the kernel's own BlockSpec arithmetic
        assert launch.grid == (4 // rows, 128 // tile) == plan.launches[0].grid
        assert launch.block_shape == (rows, tile)
        assert math.prod(launch.stages) == tile
        assert launch.vmem_bytes == _expected_scan_vmem(rows, tile, 2) \
            == plan.vmem_bytes
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-4)


def test_fft_conformance_every_valid_config():
    import jax.numpy as jnp

    from repro.kernels.fft.ops import fft
    from repro.kernels.fft.ref import fft_ref
    wl = Workload(op="fft", n=64, batch=4, variant="stockham")
    space = build_space(wl)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 64)) + 1j * rng.normal(size=(4, 64)),
                    jnp.complex64)
    ref = np.asarray(fft_ref(x))
    for cfg in space.enumerate_valid():
        norm = normalizer_for("fft")(cfg, wl, None)
        plan = plan_for(wl, norm)
        with driver.capture_launches() as rec:
            got = fft(x, config=cfg, interpret=True)
        assert len(rec) == plan.passes == 1
        launch = rec[0]
        rows = plan.rows
        assert launch.grid == (4 // rows,) == plan.launches[0].grid
        assert math.prod(launch.stages) == 64
        assert launch.vmem_bytes == 4 * rows * 64 * 4 == plan.vmem_bytes
        err = np.max(np.abs(np.asarray(got) - ref)) / np.max(np.abs(ref))
        assert err < 1e-4


def test_pcr_conformance_every_valid_config():
    import jax

    from repro.kernels.tridiag import ops
    from repro.kernels.tridiag.ref import random_system, thomas_ref
    wl = Workload(op="tridiag", n=64, batch=4, variant="pcr")
    space = build_space(wl)
    a, b, c, d = random_system(jax.random.PRNGKey(7), 4, 64)
    ref = np.asarray(thomas_ref(a, b, c, d))
    for cfg in space.enumerate_valid():
        norm = normalizer_for("tridiag")(cfg, wl, None)
        plan = plan_for(wl, norm)
        with driver.capture_launches() as rec:
            got = ops.solve(a, b, c, d, variant="pcr", config=cfg,
                            interpret=True)
        assert len(rec) == plan.passes == 1
        launch = rec[0]
        rows = norm["rows_per_program"]
        assert launch.grid == (4 // rows,) == plan.launches[0].grid
        assert launch.vmem_bytes == 5 * rows * 64 * 4 == plan.vmem_bytes
        assert len(launch.stages) == math.ceil(math.log2(64))
        np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-3,
                                   atol=1e-3)


def test_multipass_scan_add_three_launches_match_reference():
    import jax.numpy as jnp

    from repro.kernels.scan.ref import scan_add_ref
    wl = Workload(op="scan", n=512, batch=4, variant="ks")
    cfg = {"tile_n": 64, "rows_per_program": 2, "radix": 4, "unroll": 2}
    plan = build_plan(wl, cfg, seq_limit=4)
    assert plan.kind == "multipass"
    x = jnp.asarray(np.random.default_rng(2).normal(size=(4, 512)),
                    jnp.float32)
    with driver.capture_launches() as rec:
        got = driver.multipass_scan_add(x, plan, interpret=True)
    assert [l.name for l in rec] == [l.name for l in plan.launches]
    assert [l.grid for l in rec] == [l.grid for l in plan.launches]
    np.testing.assert_allclose(np.asarray(got), np.asarray(scan_add_ref(x)),
                               rtol=2e-5, atol=2e-4)


def test_multipass_scan_public_entry_bf16_single_quantization():
    """Past the seq limit the PUBLIC prefix_sum routes multipass; sub-f32
    dtypes must carry inter-launch state in f32 and quantize once at the
    output (parity with the fused path's f32 VMEM carry scratch)."""
    import jax.numpy as jnp

    from repro.kernels.scan.ops import prefix_sum

    x = jnp.asarray(np.random.default_rng(5).normal(size=(2, 16384)),
                    jnp.bfloat16)
    with driver.capture_launches() as rec:
        got = prefix_sum(x, config={"tile_n": 128, "radix": 4,
                                    "rows_per_program": 2, "unroll": 2},
                         interpret=True, use_pallas=True)
    assert len(rec) == 3 and got.dtype == jnp.bfloat16
    ref = np.cumsum(np.asarray(x, np.float64), axis=1)
    rel = np.max(np.abs(np.asarray(got, np.float64) - ref)
                 / np.maximum(np.abs(ref), 1))
    assert rel < 2e-2, rel


def test_multipass_linrec_three_launches_match_reference():
    import jax.numpy as jnp

    from repro.kernels.scan.ref import scan_linrec_assoc_ref
    wl = Workload(op="scan", n=512, batch=4, variant="linrec")
    cfg = {"tile_n": 64, "rows_per_program": 2, "radix": 2}
    plan = build_plan(wl, cfg, seq_limit=4)
    assert plan.kind == "multipass" and plan.passes == 3
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.uniform(0.8, 0.99, size=(4, 512)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(4, 512)), jnp.float32)
    with driver.capture_launches() as rec:
        got = driver.multipass_linrec(a, b, plan, interpret=True)
    assert len(rec) == 3
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(scan_linrec_assoc_ref(a, b)),
        rtol=2e-4, atol=2e-4)


def test_four_step_fft_launches_match_plan():
    import jax.numpy as jnp

    from repro.kernels.fft.ops import fft
    from repro.kernels.fft.ref import fft_ref
    n = 768                                   # past the resident tile cap
    wl = Workload(op="large_fft", n=n, batch=2, variant="stockham")
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, n)) + 1j * rng.normal(size=(2, n)),
                    jnp.complex64)
    cfg = {"radix": 4, "rows_per_program": 4, "tile_n": 4096}
    from repro.core.multikernel import max_resident_tile
    plan = plan_for(wl, normalizer_for("large_fft")(cfg, wl, None),
                    max_tile=max_resident_tile(
                        Workload(op="fft", n=n, batch=2, variant="stockham")))
    with driver.capture_launches() as rec:
        got = fft(x, config=cfg, interpret=True)
    assert len(rec) == plan.passes == len(plan.launches)
    assert [l.grid for l in rec] == [l.grid for l in plan.launches]
    ref = np.asarray(fft_ref(x))
    err = np.max(np.abs(np.asarray(got) - ref)) / np.max(np.abs(ref))
    assert err < 1e-3


def test_lf_multipass_matches_lf():
    import jax

    from repro.kernels.tridiag import ops
    from repro.kernels.tridiag.ref import random_system
    a, b, c, d = random_system(jax.random.PRNGKey(11), 4, 256)
    base = np.asarray(ops.lf_solve(a, b, c, d))
    got = np.asarray(ops.lf_solve_multipass(a, b, c, d, use_pallas=True,
                                            interpret=True))
    np.testing.assert_allclose(got, base, rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# Chain plans: op sequences staged as one plan
# ---------------------------------------------------------------------------

def test_chain_plans_check_clean_over_valid_spaces():
    from repro.hw.profiles import get_profile
    from repro.kernels.blocks.plan import plan_for_chain
    spec = get_profile("tpu_v5e")
    for wl in (Workload(op="rglru", n=256, batch=32),
               Workload(op="ssd", n=512, batch=16, variant="chunked")):
        space = build_space(wl)
        assert any(c.get("fuse") for c in space.enumerate_valid())
        for cfg in space.enumerate_valid():
            norm = normalizer_for(wl.op)(cfg, wl, None)
            chain = plan_for_chain(wl, dict(cfg, **norm)
                                   if wl.op == "rglru" else cfg)
            assert chain.check(spec) == []
            # chain launches are exactly the plan's, chain passes the
            # plan's total (kernel passes + XLA links)
            assert tuple(chain.launches) == tuple(chain.plan.launches)
            assert chain.passes + chain.plan.xla_passes == chain.plan.passes \
                or chain.passes == chain.plan.passes


def test_rglru_chain_fuse_folds_gate_link():
    from repro.kernels.blocks.plan import plan_for_chain
    wl = Workload(op="rglru", n=256, batch=32)
    cfg = {"tile_n": 128, "rows_per_program": 8, "radix": 2}
    unfused = plan_for_chain(wl, dict(cfg, fuse=0))
    fused = plan_for_chain(wl, dict(cfg, fuse=1))
    assert [l.kind for l in unfused.links] == ["xla", "pallas"]
    assert [l.kind for l in fused.links] == ["fused", "pallas"]
    assert unfused.plan.xla_passes == 1 and fused.plan.xla_passes == 0
    assert fused.plan.passes == unfused.plan.passes - 1


def test_ssd_chain_fuse_collapses_phases():
    from repro.kernels.blocks.plan import plan_for_chain
    wl = Workload(op="ssd", n=512, batch=16, variant="chunked")
    cfg = {"tile_n": 128, "radix": 2}
    unfused = plan_for_chain(wl, dict(cfg, fuse=0), dims=(8, 16))
    fused = plan_for_chain(wl, dict(cfg, fuse=1), dims=(8, 16))
    assert [l.name for l in unfused.links] == ["intra", "linrec", "apply"]
    assert unfused.plan.kind == "three-phase" and unfused.passes == 3
    assert fused.plan.kind == "two-phase" and fused.passes == 2
    assert len(fused.launches) < len(unfused.launches)


def test_ssd_chain_odd_chunk_count_models_xla_fallback():
    """nc = 3 has no valid linrec space config; the unfused chain's middle
    link must be an XLA link (mirroring driver._linrec_space_valid), while
    the fused chain's sequential carry needs no fallback."""
    from repro.kernels.blocks.plan import plan_for_chain
    wl = Workload(op="ssd", n=384, batch=16, variant="chunked")
    unfused = plan_for_chain(wl, {"tile_n": 128, "fuse": 0}, dims=(8, 16))
    assert [l.kind for l in unfused.links] == ["pallas", "xla", "pallas"]
    fused = plan_for_chain(wl, {"tile_n": 128, "fuse": 1}, dims=(8, 16))
    assert [l.kind for l in fused.links] == ["pallas", "fused", "pallas"]
    assert len(fused.launches) == 2


def test_multipass_carry_unroll_clamped_at_extreme_seq_tiles():
    """Satellite fix: the workload-tuned unroll rides into the carry scan
    (l2) whose tile length is seq_tiles, not tile_n — at extreme
    seq_tiles/unroll combinations the driver must clamp, and the executed
    launches must still match the plan."""
    import jax.numpy as jnp

    from repro.kernels.scan.ref import scan_add_ref
    rng = np.random.default_rng(6)
    for tile, unroll in ((256, 8), (512, 8), (256, 4)):
        wl = Workload(op="scan", n=1024, batch=8, variant="ks")
        cfg = {"tile_n": tile, "rows_per_program": 8, "radix": 2,
               "unroll": unroll, "in_register": 0}
        plan = build_plan(wl, cfg, seq_limit=1)
        assert plan.kind == "multipass"
        assert plan.seq_tiles < unroll * 2   # the extreme corner
        x = jnp.asarray(rng.normal(size=(8, 1024)), jnp.float32)
        with driver.capture_launches() as rec:
            got = driver.multipass_scan_add(x, plan, unroll=unroll,
                                            interpret=True)
        assert [l.name for l in rec] == [l.name for l in plan.launches]
        assert [l.grid for l in rec] == [l.grid for l in plan.launches]
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(scan_add_ref(x)),
                                   rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# Model conformance: analytical + featurizer read the plan
# ---------------------------------------------------------------------------

def test_resources_are_plan_resources():
    from repro.core.analytical import resources
    for wl in _WORKLOADS:
        space = build_space(wl)
        for cfg in space.enumerate_valid()[:8]:
            assert resources(space, cfg) == plan_for(wl, cfg).resources()


def test_features_expose_plan_fields():
    from repro.tuning.ml.features import FEATURE_NAMES, featurize
    wl = Workload(op="scan", n=256, batch=8, variant="ks")
    space = build_space(wl)
    cfg = {"tile_n": 128, "rows_per_program": 2, "radix": 8, "unroll": 1,
           "in_register": 0}
    row = dict(zip(FEATURE_NAMES, featurize(space, cfg)))
    plan = plan_for(wl, cfg)
    assert row["log2_passes"] == math.log2(plan.passes) if plan.passes > 1 \
        else row["log2_passes"] == 0.0
    assert row["log2_seq_tiles"] == math.log2(plan.seq_tiles)
    assert row["ragged_tail"] == (1.0 if plan.ragged else 0.0)
    assert row["steps_per_pass"] == plan.stage_count
