"""Sweep engine: batched objectives, journaled resume, pruning, compare."""
import inspect
import sys

import numpy as np
import pytest

from repro.core import (CachedObjective, ExhaustiveSearch, RandomSearch,
                        TPUCostModelObjective, Workload, build_space)
from repro.core.bayesian import BayesianTuner
from repro.core.objective import PENALTY_TIME
from repro.core.transfer import TransferBayesianTuner
from repro.evaluation import check_report, compare_methods, format_report
from repro.tuning import TunerSession, register_strategy
from repro.tuning.session import _STRATEGIES
from repro.tuning.sweep import SweepJournal, run_sweep

SWEEP_WORKLOADS = [
    Workload(op="scan", n=512, batch=2**17, variant="lf"),
    Workload(op="scan", n=2048, batch=2**15, variant="ks"),
    Workload(op="ssd", n=512, batch=2**17),
    Workload(op="rglru", n=1024, batch=2**16),
    Workload(op="tridiag", n=256, batch=2**14, variant="wm"),
    Workload(op="tridiag", n=512, batch=2**14, variant="pcr"),
    Workload(op="tridiag", n=512, batch=2**14, variant="cr"),
    Workload(op="fft", n=1024, batch=2**12, variant="stockham"),
    Workload(op="large_fft", n=2**20, batch=8, variant="stockham"),
    Workload(op="attention", n=2048, batch=64, variant="flash"),
    Workload(op="matmul", n=1024, batch=1024),
]


class _Counting(TPUCostModelObjective):
    """Counts configs that reach the vectorized path."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.fresh = 0

    def batch_eval_metrics(self, space, cfgs, **kw):
        self.fresh += len(cfgs)
        return super().batch_eval_metrics(space, cfgs, **kw)

    def signature(self):
        return TPUCostModelObjective(noise=self.noise).signature()


class _Killed(_Counting):
    """Dies mid-sweep after `after` evaluations, like a preempted job."""

    def __init__(self, after, **kw):
        super().__init__(**kw)
        self.after = after

    def batch_eval_metrics(self, space, cfgs, **kw):
        if self.fresh >= self.after:
            raise KeyboardInterrupt
        return super().batch_eval_metrics(space, cfgs, **kw)


# ---------------------------------------------------------------------------
# Batched objective protocol
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wl", SWEEP_WORKLOADS, ids=lambda w: w.key)
@pytest.mark.parametrize("noise", [0.0, 0.02])
def test_batch_eval_matches_scalar(wl, noise):
    """The vectorized fast path is bit-identical to per-config calls."""
    obj = TPUCostModelObjective(noise=noise)
    space = build_space(wl)
    cands = space.enumerate_valid()
    scalar = np.array([obj(space, c).time_s for c in cands])
    batched = obj.batch_eval(space, cands, assume_valid=True)
    assert np.array_equal(scalar, batched)


def test_batch_eval_clamps_invalid():
    space = build_space(Workload(op="scan", n=256, batch=2**18, variant="lf"))
    good = space.enumerate_valid()[0]
    bad = dict(good, tile_n=999)
    times = TPUCostModelObjective().batch_eval(space, [good, bad])
    assert times[0] < PENALTY_TIME and times[1] == PENALTY_TIME


def test_batch_eval_heterogeneous_key_order():
    """Mixed key orders must not be silently mis-columned by the fast path."""
    space = build_space(Workload(op="scan", n=256, batch=2**18, variant="lf"))
    obj = TPUCostModelObjective()
    cands = space.enumerate_valid()[:6]
    shuffled = dict(reversed(list(cands[1].items())))   # same config, new order
    mixed = [cands[0], shuffled] + cands[2:]
    scalar = np.array([obj(space, c).time_s for c in mixed])
    assert np.array_equal(obj.batch_eval(space, mixed, assume_valid=True),
                          scalar)


def test_cached_objective_batch_keeps_slow_valid_configs():
    """A valid config modeled slower than the penalty clamp must not be
    cached as invalid (and clamped) by the batch path."""
    wl = Workload(op="scan", n=2**22, batch=2**26, variant="lf")
    space = build_space(wl)
    slow = space.enumerate_valid()[0]
    scalar_m = TPUCostModelObjective()(space, slow)
    assert scalar_m.valid and scalar_m.time_s > PENALTY_TIME   # the premise
    obj = CachedObjective(TPUCostModelObjective())
    batched = obj.batch_eval(space, [slow], assume_valid=True)
    assert batched[0] == scalar_m.time_s
    cached_m = obj(space, slow)
    assert cached_m.valid and cached_m.time_s == scalar_m.time_s


def test_cached_objective_batch_counts_unique():
    space = build_space(Workload(op="fft", n=256, batch=2**14,
                                 variant="stockham"))
    obj = CachedObjective(TPUCostModelObjective())
    cands = space.enumerate_valid()
    first = obj.batch_eval(space, cands, assume_valid=True)
    assert obj.evaluations == len(cands)
    again = obj.batch_eval(space, cands, assume_valid=True)
    assert obj.evaluations == len(cands)          # all cache hits
    assert np.array_equal(first, again)
    # scalar calls agree with the batch-cached measurements
    assert obj(space, cands[3]).time_s == first[3]


# ---------------------------------------------------------------------------
# The sweep: equivalence, journaled resume, pruning
# ---------------------------------------------------------------------------

def test_sweep_matches_seed_loop_semantics():
    """Same winner, same history, as the seed per-config loop."""
    wl = Workload(op="scan", n=512, batch=2**17, variant="lf")
    space = build_space(wl)
    obj = TPUCostModelObjective(noise=0.02)
    res = run_sweep(space, obj)
    seed_hist = []
    best_cfg, best_t = None, float("inf")
    for cfg in space.enumerate_valid():
        m = obj(space, cfg)
        t = m.time_s if m.valid else PENALTY_TIME
        seed_hist.append(t)
        if t < best_t:
            best_cfg, best_t = cfg, t
    assert res.best_config == best_cfg and res.best_time == best_t
    assert np.array_equal(np.asarray([t for _, t in res.history]),
                          np.asarray(seed_hist))
    assert res.stopped_by == "exhausted"
    assert res.evaluations == res.total and res.resumed == 0


def test_interrupted_sweep_resumes_without_reevaluating(tmp_path):
    """Kill a journaled sweep mid-flight; the rerun must skip everything
    already measured and return the identical winner (acceptance test)."""
    wl = Workload(op="scan", n=512, batch=2**17, variant="lf")
    space = build_space(wl)
    clean = run_sweep(space, TPUCostModelObjective(noise=0.02))

    killed = _Killed(after=150, noise=0.02)
    journal = SweepJournal.for_workload(str(tmp_path), wl, killed)
    with pytest.raises(KeyboardInterrupt):
        run_sweep(space, killed, journal=journal, chunk=64)
    survived = journal.load(wl, killed)
    assert 0 < len(survived) < clean.total

    resumed_obj = _Counting(noise=0.02)
    res = run_sweep(space, resumed_obj,
                    journal=SweepJournal.for_workload(str(tmp_path), wl,
                                                      resumed_obj))
    assert resumed_obj.fresh == clean.total - len(survived)
    assert res.resumed == len(survived)
    assert res.evaluations == resumed_obj.fresh
    assert res.best_config == clean.best_config
    assert res.best_time == clean.best_time
    assert [t for _, t in res.history] == [t for _, t in clean.history]

    # a third run answers fully from the journal
    idle = _Counting(noise=0.02)
    res3 = run_sweep(space, idle,
                     journal=SweepJournal.for_workload(str(tmp_path), wl,
                                                       idle))
    assert idle.fresh == 0 and res3.best_config == clean.best_config


def test_journal_rejects_foreign_header(tmp_path):
    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    other = Workload(op="fft", n=512, batch=2**14, variant="stockham")
    obj = TPUCostModelObjective()
    journal = SweepJournal.for_workload(str(tmp_path), wl, obj)
    run_sweep(build_space(wl), obj, journal=journal)
    with pytest.raises(ValueError, match="workload"):
        journal.load(other, obj)
    with pytest.raises(ValueError, match="objective"):
        journal.load(wl, TPUCostModelObjective(noise=0.5))


def test_wallclock_signature_carries_runner_identity():
    """Journals keyed by a bare class name would resume another kernel's
    times; the runner (and measurement params) must be in the signature."""
    from repro.core.objective import WallClockObjective

    def runner_a(wl, cfg):
        return lambda: None

    def runner_b(wl, cfg):
        return lambda: None

    sig_a = WallClockObjective(runner_a).signature()
    sig_b = WallClockObjective(runner_b).signature()
    assert sig_a != sig_b
    assert WallClockObjective(runner_a, reps=9).signature() != sig_a


def test_headerless_journal_quarantined_not_resumed(tmp_path):
    """A torn/missing header leaves entries unvalidatable: they must never
    be resumed, and the journal must heal instead of staying locked."""
    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    obj = TPUCostModelObjective()
    journal = SweepJournal.for_workload(str(tmp_path), wl, obj)
    with open(journal.path, "w") as f:          # torn very first write
        f.write('{"kind": "hea')
    assert journal.load(wl, obj) == {}
    assert (tmp_path / (journal.path.split("/")[-1] + ".corrupt")).exists()
    res = run_sweep(build_space(wl), obj,
                    journal=SweepJournal.for_workload(str(tmp_path), wl, obj))
    assert res.resumed == 0 and res.evaluations == res.total
    fresh = SweepJournal.for_workload(str(tmp_path), wl, obj)
    assert fresh.read_header() is not None      # healed with a real header


def test_append_after_torn_tail_does_not_glue(tmp_path):
    """A crash-resumed journal ends mid-line; the next append used to
    concatenate its first record onto the torn bytes, losing BOTH to the
    json parse. The writer must terminate the torn line first."""
    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    obj = TPUCostModelObjective()
    space = build_space(wl)
    cands = space.enumerate_valid()
    journal = SweepJournal.for_workload(str(tmp_path), wl, obj)
    journal.append(wl, obj, len(cands), [(cands[0], 1.0)])
    with open(journal.path, "a") as f:
        f.write('{"k": "torn-mid-wri')           # kill -9 mid-append
    resumed = SweepJournal(journal.path)         # fresh process
    resumed.append(wl, obj, len(cands), [(cands[1], 2.0)])
    done = resumed.load(wl, obj)
    from repro.tuning.sweep import config_key
    assert done[config_key(cands[0])] == 1.0
    assert done[config_key(cands[1])] == 2.0     # survived the torn tail
    assert len(done) == 2


def test_journal_nondict_json_lines_skipped(tmp_path):
    """Valid-JSON-but-not-an-object lines (e.g. '123') must be treated as
    noise, not crash load()/read_header()/entries()."""
    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    obj = TPUCostModelObjective()
    journal = SweepJournal.for_workload(str(tmp_path), wl, obj)
    with open(journal.path, "w") as f:
        f.write("123\n")
    assert journal.read_header() is None
    assert journal.load(wl, obj) == {}           # quarantined, not crashed
    fresh = SweepJournal.for_workload(str(tmp_path), wl, obj)
    space = build_space(wl)
    fresh.append(wl, obj, space.size(), [(space.enumerate_valid()[0], 1.0)])
    assert len(fresh.entries()) == 1


def test_journal_survives_torn_trailing_line(tmp_path):
    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    obj = TPUCostModelObjective()
    journal = SweepJournal.for_workload(str(tmp_path), wl, obj)
    res = run_sweep(build_space(wl), obj, journal=journal)
    with open(journal.path, "a") as f:
        f.write('{"k": "truncated mid-wri')     # kill -9 mid-append
    done = journal.load(wl, obj)
    assert len(done) == res.total               # torn line skipped


def test_analytical_pruning_keeps_topk():
    wl = Workload(op="scan", n=512, batch=2**17, variant="lf")
    space = build_space(wl)
    obj = TPUCostModelObjective()
    full = run_sweep(space, obj)
    pruned = run_sweep(space, obj, prune="analytical", top_k=50)
    assert pruned.total == 50
    assert pruned.pruned == full.total - 50
    assert pruned.stopped_by == "pruned"
    # the expert ranking should keep the optimum's neighbourhood
    assert pruned.best_time <= full.best_time * 1.2
    with pytest.raises(ValueError, match="prune"):
        run_sweep(space, obj, prune="nonsense")
    with pytest.raises(ValueError, match="top_k"):
        run_sweep(space, obj, prune="analytical", top_k=0)


def test_pruned_journal_excluded_from_dataset_until_complete(tmp_path):
    """A pruned sweep's journal must not masquerade as a complete
    enumeration for training labels; finishing the space rehabilitates it."""
    from repro.tuning.ml.dataset import dataset_from_journal

    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    space = build_space(wl)
    obj = TPUCostModelObjective()
    journal = SweepJournal.for_workload(str(tmp_path), wl, obj)
    run_sweep(space, obj, journal=journal, prune="analytical", top_k=8)
    assert journal.read_header()["pruned"] > 0
    assert len(dataset_from_journal(journal.path)) == 0   # unguaranteed

    # an unpruned sweep on the same journal completes the space
    full = run_sweep(space, obj, journal=journal)
    assert full.resumed == 8
    ds = dataset_from_journal(journal.path)
    assert len(ds) == full.total                          # now trainable


def test_cached_objective_batch_marks_measurement_failures_invalid():
    """assume_valid skips the space re-check only: a config the inner
    objective failed to measure (clamped to the penalty) must not be
    cached as a valid 60 s data point."""
    from repro.core.objective import Measurement, Objective

    class FailsOne(Objective):
        """Measurement-invalid on radix=8 (e.g. wallclock timeout / OOM);
        base-class batch_eval walks __call__, like any real host objective."""

        def __init__(self):
            self.model = TPUCostModelObjective()

        def __call__(self, space, cfg):
            if cfg.get("radix") == 8:
                return Measurement(PENALTY_TIME * 2, False)
            return self.model(space, cfg)

    space = build_space(Workload(op="fft", n=256, batch=2**14,
                                 variant="stockham"))
    obj = CachedObjective(FailsOne())
    cands = space.enumerate_valid()
    times = obj.batch_eval(space, cands, assume_valid=True)
    failed = [i for i, c in enumerate(cands) if c["radix"] == 8]
    assert failed and all(times[i] == PENALTY_TIME for i in failed)
    for i in failed:
        m = obj(space, cands[i])               # scalar read of the cache
        assert not m.valid and m.time_s == PENALTY_TIME


def test_pruned_winner_not_stored_as_exhaustive(tmp_path):
    """dataset_from_db trusts method='exhaustive' winners as group optima;
    a pruned sweep's winner carries no such guarantee."""
    from repro.tuning.ml.dataset import dataset_from_db

    session = TunerSession(db_path=str(tmp_path / "db.json"))
    wl = Workload(op="scan", n=512, batch=2**17, variant="lf")
    session.tune(wl, method="exhaustive", prune="analytical", top_k=16)
    entry = next(iter(session.db.entries().values()))
    assert entry["method"] == "exhaustive-pruned"
    assert len(dataset_from_db(session.db)) == 0   # excluded from labels
    # an unpruned sweep still stores (and trains) as before
    session.tune(wl, method="exhaustive")
    assert len(dataset_from_db(session.db)) == 1


def test_exhaustive_strategy_journals_through_session(tmp_path):
    session = TunerSession(db_path=str(tmp_path / "db.json"),
                           sweep_dir=str(tmp_path / "sweeps"))
    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    res = session.tune(wl, method="exhaustive")
    journals = list((tmp_path / "sweeps").glob("*.jsonl"))
    assert len(journals) == 1
    entries = SweepJournal(str(journals[0])).entries()
    assert len(entries) == len(res.history)
    assert session.lookup(wl) == res.best_config


def test_session_tolerates_legacy_strategy_signature(tmp_path):
    def legacy(space, objective, *, seed=0, max_evals=0):
        return ExhaustiveSearch().tune(space, objective)

    register_strategy("legacy_sweepless", legacy)
    try:
        session = TunerSession(db_path=str(tmp_path / "db.json"),
                               sweep_dir=str(tmp_path / "sweeps"))
        wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
        res = session.tune(wl, method="legacy_sweepless")
        assert res.stopped_by == "exhausted"
    finally:
        _STRATEGIES.pop("legacy_sweepless", None)


def test_sweep_faster_than_seed_loop():
    """Loose in-suite floor (3x) for the vectorization win; the >= 10x
    acceptance gate runs in benchmarks/bench_sweep.py on big spaces."""
    import time
    wl = Workload(op="ssd", n=1024, batch=2**16)
    space = build_space(wl)
    obj = TPUCostModelObjective()
    cands = space.enumerate_valid()

    def loop():
        return [obj(space, c).time_s for c in cands]

    t_loop = min(_timed(loop) for _ in range(3))
    t_batch = min(_timed(lambda: obj.batch_eval(space, cands,
                                                assume_valid=True))
                  for _ in range(3))
    assert t_loop / t_batch >= 3, \
        f"batched sweep only {t_loop / t_batch:.1f}x faster"


def _timed(fn):
    import time
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# stopped_by semantics (satellite fixes)
# ---------------------------------------------------------------------------

def test_random_search_stopped_by_semantics():
    space = build_space(Workload(op="tridiag", n=128, batch=4, variant="pcr"))
    size = space.size()
    obj = TPUCostModelObjective()
    capped = RandomSearch(max_evals=size - 2, seed=0).tune(space, obj)
    assert capped.stopped_by == "max_evals"
    assert capped.evaluations == size - 2
    full = RandomSearch(max_evals=size + 10, seed=0).tune(space, obj)
    assert full.stopped_by == "exhausted"     # enumerated the whole space
    assert full.evaluations == size


def test_transfer_stopped_by_semantics():
    wl = Workload(op="fft", n=512, batch=2**17, variant="stockham")
    space = build_space(wl)
    obj = CachedObjective(TPUCostModelObjective(noise=0.02))
    res = TransferBayesianTuner(seed=0, max_evals=5, patience=999).tune(
        space, obj, histories=())
    assert res.stopped_by == "max_evals"       # budget bound, not exhaustion
    assert res.evaluations == 5

    small = build_space(Workload(op="tridiag", n=128, batch=4, variant="pcr"))
    res2 = TransferBayesianTuner(seed=0, max_evals=500, patience=999).tune(
        small, CachedObjective(TPUCostModelObjective(noise=0.02)),
        histories=())
    assert res2.stopped_by == "exhausted"
    assert res2.evaluations == small.size()


# ---------------------------------------------------------------------------
# bayesian: pure numpy, no scipy (satellite fix)
# ---------------------------------------------------------------------------

def test_bayesian_works_with_scipy_blocked(monkeypatch):
    import repro.core.bayesian as bayes
    src = inspect.getsource(bayes)
    assert "import scipy" not in src and "from scipy" not in src, \
        "core.bayesian is documented as pure numpy"
    # block any sneaky import path and run a real BO loop
    monkeypatch.setitem(sys.modules, "scipy", None)
    monkeypatch.setitem(sys.modules, "scipy.special", None)
    space = build_space(Workload(op="fft", n=256, batch=2**14,
                                 variant="stockham"))
    res = BayesianTuner(seed=0, max_evals=10).tune(
        space, CachedObjective(TPUCostModelObjective()))
    assert space.is_valid(res.best_config)
    assert res.evaluations > 0


# ---------------------------------------------------------------------------
# Methodology comparison report
# ---------------------------------------------------------------------------

def test_compare_methods_report_structure_and_sanity():
    wls = [Workload(op="tridiag", n=n, batch=2**13, variant="pcr")
           for n in (128, 256)]
    report = compare_methods(
        wls, methods=("analytical", "bayesian", "random"),
        objective_factory=lambda: TPUCostModelObjective(noise=0.02),
        seed=0, max_evals=6)
    assert check_report(report) == []
    assert report["methods"] == ["analytical", "bayesian", "random"]
    assert len(report["workloads"]) == 2
    for row in report["workloads"]:
        assert row["exhaustive_evaluations"] == row["space_size"]
        for m in row["methods"].values():
            assert m["slowdown"] >= 1.0 - 1e-9       # never beats exhaustive
            assert m["efficiency"] <= 1.0 + 1e-9
    for agg in report["overall"].values():
        assert 0.0 < agg["phi"] <= 1.0 + 1e-9
    assert report["overall"]["analytical"]["total_evaluations"] == 0
    assert "tridiag" in format_report(report)


def test_compare_methods_journal_resume_survives_host_drift(tmp_path):
    """On a journal-resumed run, strategies must be scored on the sweep's
    recorded times — re-measuring on a 'faster host' would produce a false
    'beat exhaustive' violation."""
    from repro.core.objective import Measurement, Objective

    class Drifting(Objective):
        """Each instance measures 10x faster than the journal's writer."""

        def __init__(self, scale):
            self.model = TPUCostModelObjective()
            self.scale = scale

        def __call__(self, space, cfg):
            m = self.model(space, cfg)
            return Measurement(m.time_s * self.scale, m.valid)

        def signature(self):   # same identity -> journal resumes
            return "drifting-host"

    wls = [Workload(op="tridiag", n=128, batch=2**13, variant="pcr")]
    first = compare_methods(wls, methods=("random",),
                            objective_factory=lambda: Drifting(10.0),
                            seed=0, max_evals=4, journal_dir=str(tmp_path))
    assert check_report(first) == []
    # resumed run: the journal holds 10x-slower times than live measurement
    second = compare_methods(wls, methods=("random",),
                             objective_factory=lambda: Drifting(1.0),
                             seed=0, max_evals=4, journal_dir=str(tmp_path))
    assert check_report(second) == []
    row = second["workloads"][0]
    assert row["methods"]["random"]["slowdown"] >= 1.0 - 1e-9


def test_compare_methods_flags_exhaustive_beaten():
    """Phi > 1 is a bug detector: a strategy 'beating' exhaustive fails."""
    from repro.core.bayesian import TuneResult

    def cheat(space, objective, *, seed=0, max_evals=0, **_):
        cfg = space.enumerate_valid()[0]
        return TuneResult(cfg, 1e-12, 0, [(cfg, 1e-12)], "cheat")

    register_strategy("cheat", cheat)
    try:
        wls = [Workload(op="tridiag", n=128, batch=2**13, variant="pcr")]
        report = compare_methods(wls, methods=("cheat",), seed=0)
        failures = check_report(report)
        assert failures and "beat exhaustive" in failures[0]
    finally:
        _STRATEGIES.pop("cheat", None)
