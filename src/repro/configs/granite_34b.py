"""granite-34b: 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152 —
llama-arch code model [arXiv:2405.04324]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49152, activation="swiglu",
    activation_strategy="sp",
))
