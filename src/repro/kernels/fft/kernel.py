"""Pallas TPU kernel: batched complex FFT (self-sorting Stockham, radix-r).

Complex data is carried as split re/im f32 planes (TPU VREGs are real; the
paper's BPLG similarly multiplexes real/imaginary shared-memory planes for
large tiles, §V-C). Each grid program transforms `rows_per_program` whole
problems resident in VMEM.

The staged loop is driven by the plan's mixed-radix stage sequence
(``blocks.plan.stage_radices``): stage t applies the shared ``butterfly``
building block at that stage's fan-in.  Because the sequence factors n
exactly, the ragged final stage is just a smaller butterfly — the
historical ``rr = min(radix, n_cur)`` loop crashed at trace time whenever
an intermediate n_cur stopped dividing by the radix (radix 8 at n = 96).

Tunables: rows_per_program, radix; tile_n = n (whole-problem residency);
multi-pass large-N handled by the four-step driver in blocks/driver.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams
from repro.kernels.blocks import primitives as prim
from repro.kernels.blocks.plan import stage_radices

import jax.numpy as jnp


def _fft_kernel(re_ref, im_ref, ore_ref, oim_ref, *, n: int,
                stages: Tuple[int, ...], inverse: bool):
    sign = 1.0 if inverse else -1.0
    re = re_ref[...].astype(jnp.float32)
    im = im_ref[...].astype(jnp.float32)

    n_cur, s = n, 1
    for rr in stages:
        re, im = prim.butterfly(re, im, n=n, n_cur=n_cur, s=s, rr=rr,
                                sign=sign)
        n_cur, s = n_cur // rr, s * rr

    scale = (1.0 / n) if inverse else 1.0
    ore_ref[...] = (re * scale).astype(ore_ref.dtype)
    oim_ref[...] = (im * scale).astype(oim_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows_per_program", "radix",
                                             "stages", "inverse",
                                             "interpret"))
def fft_pallas(re: jax.Array, im: jax.Array, *, rows_per_program: int = 4,
               radix: int = 2, stages: Optional[Tuple[int, ...]] = None,
               inverse: bool = False, interpret: bool = False):
    """Row-wise complex FFT on split planes; returns (re, im)."""
    batch, n = re.shape
    rows = rows_per_program
    grid = (batch // rows,)
    spec = pl.BlockSpec((rows, n), lambda i: (i, 0))
    stages = prim.as_stages(stages) if stages else stage_radices(n, radix)
    kernel = functools.partial(_fft_kernel, n=n, stages=stages,
                               inverse=inverse)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(re.shape, re.dtype)] * 2,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(re, im)
