"""Batched serving example: continuous batching over mixed-length requests.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models.model import build_model
from repro.serve.engine import ServeEngine

cfg = get_arch("qwen1.5-0.5b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServeEngine(model, params, max_batch=4, max_len=128)

rng = np.random.default_rng(0)
for i in range(10):
    engine.submit(rng.integers(0, cfg.vocab, size=int(rng.integers(4, 20))),
                  max_new_tokens=8)
t0 = time.perf_counter()
done = engine.run()
dt = time.perf_counter() - t0
tokens = sum(len(r.output) for r in done)
print(f"[serve_lm] {len(done)} requests / {tokens} tokens in {dt:.2f}s "
      f"({tokens/dt:.1f} tok/s, continuous batching over 4 slots)")
for r in done[:3]:
    print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> {r.output}")
