"""Serving throughput: the optimized engine vs the replay baseline.

Replays one seeded multi-tenant trace (Poisson arrivals over three tenant
classes — see :mod:`repro.serve.trace`) through both engines built from
the same model/params:

  * reference — the seed's per-token replay prefill + host-loop decode
    (:class:`~repro.serve.reference.ReferenceEngine`);
  * optimized — single-dispatch chunked prefill, donated on-device decode
    with batched lazy harvest, threshold-batched admission
    (:class:`~repro.serve.engine.ServeEngine`).

Gates:

  * **throughput** — the optimized engine must serve >= 3x the reference's
    tokens/sec on the full trace (wall-clock: skipped in ``--smoke`` runs
    and under ``--no-assert``, shared CI runners are too noisy to gate);
  * **dispatch** — total prefill device calls <= sum over requests of
    ceil((prompt_len-1)/chunk), i.e. the O(prompt_len) replay is really
    gone (structural: always asserted);
  * **host sync** — at most one device->host transfer per engine step
    (structural: always asserted);
  * **fleet prior** — a replica warm-started from fleet journals reaches
    its incumbent with strictly fewer trial measurements than a cold
    replica on the same traffic (deterministic replay: always asserted).

    PYTHONPATH=src python benchmarks/bench_serving.py --json BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import math
import os
import tempfile
import time

import jax
import numpy as np

MIN_SPEEDUP = 3.0       # tokens/sec gate, optimized vs reference
PREFILL_CHUNK = 16
ADMIT_THRESHOLD = 4


def _serve_trace(engine, trace):
    """Submit the whole trace, drain it, return (tokens, seconds, done)."""
    for req in trace:
        engine.submit(req.prompt, max_new_tokens=req.max_new_tokens)
    t0 = time.perf_counter()
    done = engine.run(max_steps=200_000)
    dt = time.perf_counter() - t0
    return sum(len(r.output) for r in done), dt, done


def _throughput_rows(emit, model, cfg, params, *, seed, smoke):
    from repro.serve import (ReferenceEngine, ServeEngine, default_tenants,
                             synthetic_trace, trace_summary)

    horizon = 10 if smoke else 40
    trace = synthetic_trace(default_tenants(), horizon=horizon,
                            vocab=cfg.vocab, seed=seed)
    summary = trace_summary(trace)
    emit(f"serving,trace,requests,{summary['requests']}")
    emit(f"serving,trace,prompt_tokens,{summary['prompt_tokens']}")
    emit(f"serving,trace,decode_tokens,{summary['decode_tokens']}")

    eng = ServeEngine(model, params, max_batch=8, max_len=128,
                      prefill_chunk=PREFILL_CHUNK,
                      admit_threshold=ADMIT_THRESHOLD)
    eng.warmup()
    ref = ReferenceEngine(model, params, max_batch=8, max_len=128)
    # warm the reference's jitted decode outside the timed window too
    ref.submit(np.asarray([1, 2], np.int32), max_new_tokens=2)
    ref.run()
    ref.completed.clear()

    new_toks, new_dt, _ = _serve_trace(eng, trace)
    ref_toks, ref_dt, _ = _serve_trace(ref, trace)
    assert new_toks == ref_toks, "engines decoded different token counts"

    new_tps = new_toks / max(new_dt, 1e-9)
    ref_tps = ref_toks / max(ref_dt, 1e-9)
    speedup = new_tps / max(ref_tps, 1e-9)
    emit(f"serving,reference,tokens_per_s,{ref_tps:.1f}")
    emit(f"serving,optimized,tokens_per_s,{new_tps:.1f}")
    emit(f"serving,speedup,x,{speedup:.2f}")

    failures = []
    dispatch_bound = sum(
        math.ceil((len(r.prompt) - 1) / PREFILL_CHUNK) for r in trace)
    emit(f"serving,optimized,prefill_calls,{eng.prefill_calls}")
    emit(f"serving,optimized,prefill_call_bound,{dispatch_bound}")
    if eng.prefill_calls > dispatch_bound:
        failures.append(
            f"serving dispatch gate: {eng.prefill_calls} prefill calls > "
            f"per-request bound {dispatch_bound}")
    steps = eng._step_index
    emit(f"serving,optimized,steps,{steps}")
    emit(f"serving,optimized,host_transfers,{eng.host_transfers}")
    if eng.host_transfers > steps:
        failures.append(
            f"serving sync gate: {eng.host_transfers} host transfers over "
            f"{steps} steps (> 1 per step)")
    return speedup, failures


def _fleet_rows(emit, *, seed):
    """Deterministic fleet-prior gate via trace replay (no live engine)."""
    from repro.core.space import Workload, build_space
    from repro.tuning import (OnlineTuner, ReplayTrace, TunerSession,
                              measurements_to_incumbent, replay, warm_tuner)
    from repro.tuning.online import ranked_candidates
    from repro.tuning.sweep import config_key

    wl = Workload(op="scan", n=512, batch=2**17, variant="lf")
    root = tempfile.mkdtemp(prefix="bench_serving_fleet_")
    session = TunerSession(db_path=os.path.join(root, "db.json"))
    space = build_space(wl)
    prior = session.resolve_raw(wl)
    cands = ranked_candidates(space, 8, exclude=(config_key(prior),))
    best = cands[3]
    rng = np.random.default_rng(seed)

    def traffic(rep_seed):
        trace = ReplayTrace(wl, source="serve")
        del rep_seed
        for cfg, ms in [(prior, 2.0)] + [
                (c, 1.0 if i == 3 else 2.4) for i, c in enumerate(cands)]:
            for _ in range(40):
                trace.add(cfg, ms * 1e-3 * (1 + 0.05 * rng.uniform(-1, 1)))
        return trace

    dirs = []
    for i in range(2):
        d = os.path.join(root, f"replica{i}")
        dirs.append(d)
        tuner = OnlineTuner(wl, session, budget=64, store=False,
                            journal_dir=d, source="serve")
        replay(tuner, traffic(i))

    cold = OnlineTuner(wl, session, budget=64, store=False, source="serve")
    replay(cold, traffic(10))
    warm = warm_tuner(wl, dirs, session, source="serve", budget=64,
                      store=False)
    replay(warm, traffic(11))
    cold_cost = measurements_to_incumbent(cold)
    warm_cost = measurements_to_incumbent(warm)
    emit(f"serving,fleet,cold_measurements_to_incumbent,{cold_cost}")
    emit(f"serving,fleet,warm_measurements_to_incumbent,{warm_cost}")

    failures = []
    if cold.result().best_config != best or warm.result().best_config != best:
        failures.append("serving fleet gate: replicas did not converge on "
                        "the known-best config")
    if not (warm_cost < cold_cost):
        failures.append(
            f"serving fleet gate: warm start spent {warm_cost} trial "
            f"measurements vs cold {cold_cost} (must be strictly fewer)")
    return failures


def run(emit, *, seed: int = 0, smoke: bool = False,
        wallclock_gate: bool = True):
    """Returns a list of gate-failure strings (empty = all gates pass)."""
    from repro.configs.base import get_arch
    from repro.models.model import build_model

    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    speedup, failures = _throughput_rows(emit, model, cfg, params,
                                         seed=seed, smoke=smoke)
    if wallclock_gate and not smoke and speedup < MIN_SPEEDUP:
        failures.append(
            f"serving throughput gate: {speedup:.2f}x < {MIN_SPEEDUP:.0f}x "
            f"tokens/sec over the replay baseline")
    failures += _fleet_rows(emit, seed=seed)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_serving.json summary")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced trace for CI smoke runs (wall-clock gate "
                         "reported, not asserted)")
    ap.add_argument("--no-assert", action="store_true",
                    help="record the wall-clock speedup without gating on "
                         "it (noisy shared runners); structural gates "
                         "still assert")
    args = ap.parse_args()
    rows = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    failures = run(emit, seed=args.seed, smoke=args.smoke,
                   wallclock_gate=not args.no_assert)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "serving", "seed": args.seed,
                       "smoke": bool(args.smoke), "rows": rows,
                       "gate_failures": failures},
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")
    for failure in failures:
        print(f"# FAIL: {failure}")
    if failures:
        raise SystemExit(1)
    print("# acceptance ok: serving gates passed")


if __name__ == "__main__":
    main()
