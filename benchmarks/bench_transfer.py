"""Cross-device transfer seeding: warm-start value, quantified (the gate).

For each benchmark workload the tpu_v5e exhaustive sweep is journaled, then
a *target* device (gpu_sm) tunes the same workload twice with the same
budget and seed:

  * **cold** — TransferBayesianTuner with no prior histories (a plain
    Bayesian search: the baseline every device pays without the subsystem);
  * **warm** — ``strategy="transfer"``: the same tuner seeded from the
    source device's journal, profile-distance-reweighted
    (``repro.core.transfer``).

The metric is evaluations-to-optimum — how many objective evaluations the
search spends before first measuring the target device's exhaustive
winner (a search that never reaches it is charged its full budget).  The
CI gate asserts the warm total is at most half the cold total: transfer
seeding must at least double convergence speed, or the subsystem is not
paying for itself.

Standalone (the CI bench-smoke invocation):

  PYTHONPATH=src:. python benchmarks/bench_transfer.py \
      --json BENCH_transfer.json [--smoke]

exits non-zero when the gate fails; ``run.py --only transfer`` emits the
same rows as a section.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
from typing import List, Optional

from repro.core import CachedObjective, CostModelObjective, Workload, \
    build_space
from repro.core.exhaustive import ExhaustiveSearch
from repro.core.transfer import TransferBayesianTuner, transfer_strategy
from repro.evaluation import evals_to_optimum
from repro.hw.profiles import get_profile

SOURCE_PROFILE = "tpu_v5e"
TARGET_PROFILE = "gpu_sm"

# warm total evals-to-optimum must be <= this fraction of the cold total
GATE_RATIO = 0.50

CASES = [("scan", "lf", 256), ("scan", "lf", 1024),
         ("tridiag", "wm", 256), ("fft", "stockham", 256)]
SMOKE_CASES = [("scan", "lf", 256), ("tridiag", "wm", 256)]

MAX_EVALS = 32


def run(emit, seed: int = 0, smoke: bool = False,
        journal_dir: Optional[str] = None) -> List[str]:
    """Emit transfer rows; returns gate-failure strings (empty = pass)."""
    src = get_profile(SOURCE_PROFILE)
    dst = get_profile(TARGET_PROFILE)
    journal_dir = journal_dir or tempfile.mkdtemp(prefix="repro_bench_xfer_")
    cases = SMOKE_CASES if smoke else CASES
    seeds = [seed] if smoke else [seed, seed + 1, seed + 2]

    cold_total = 0
    warm_total = 0
    for op, variant, n in cases:
        wl = Workload(op=op, n=n, batch=max(2 ** 20 // n, 1), variant=variant)

        # source device: journal the exhaustive sweep (what transfer reads)
        ExhaustiveSearch(journal_dir=journal_dir).tune(
            build_space(wl, src), CostModelObjective(src))

        # target device: ground-truth optimum, then cold vs warm search
        space = build_space(wl, dst)
        ex = ExhaustiveSearch().tune(space, CostModelObjective(dst))
        for s in seeds:
            cold = TransferBayesianTuner(seed=s, max_evals=MAX_EVALS).tune(
                space, CachedObjective(CostModelObjective(dst)), ())
            warm = transfer_strategy(
                space, CachedObjective(CostModelObjective(dst)),
                seed=s, max_evals=MAX_EVALS, journal_dir=journal_dir)
            # a search that never measured the optimum pays its full budget
            c = evals_to_optimum(cold.history, ex.best_time) or MAX_EVALS
            w = evals_to_optimum(warm.history, ex.best_time) or MAX_EVALS
            cold_total += c
            warm_total += w
            emit(f"transfer,{op},{variant},{n},cold_seed{s},evals_to_opt,"
                 f"{c},{len(ex.history)}")
            emit(f"transfer,{op},{variant},{n},warm_seed{s},evals_to_opt,"
                 f"{w},{len(ex.history)}")

    ratio = warm_total / max(cold_total, 1)
    emit(f"transfer,ALL,,,warm_vs_cold,evals_ratio,{ratio:.4f},"
         f"gate<={GATE_RATIO}")
    failures: List[str] = []
    if ratio > GATE_RATIO:
        failures.append(
            f"transfer seeding too weak: warm evals-to-optimum "
            f"{warm_total} > {GATE_RATIO:.0%} of cold {cold_total} "
            f"(ratio {ratio:.3f})")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Cross-device transfer seeding benchmark + gate")
    ap.add_argument("--json", default=None,
                    help="write the rows + gate verdict here "
                         "(e.g. BENCH_transfer.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced case/seed matrix for CI")
    args = ap.parse_args(argv)

    rows: List[str] = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    failures = run(emit, seed=args.seed, smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "transfer", "seed": args.seed,
                       "smoke": bool(args.smoke),
                       "source": SOURCE_PROFILE, "target": TARGET_PROFILE,
                       "gate_ratio": GATE_RATIO, "rows": rows,
                       "failures": failures},
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    for failure in failures:
        print(f"[bench-transfer] FAIL: {failure}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
