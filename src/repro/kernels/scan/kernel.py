"""Pallas TPU kernel: batched radix-r prefix scan (add + linear-recurrence).

Layout: problems are rows of a (batch, n) array. The grid is
(batch/rows_per_program, n/tile_n); the column dimension is sequential on a
TPU core, so a VMEM scratch carries the running prefix across column tiles
(one streaming HBM pass; the parallel §IV-C multi-pass alternative lives in
``repro.kernels.blocks.driver``).

The in-block circuit is built from the shared building blocks
(``repro.kernels.blocks.primitives``): one ``shift_fold`` /
``linrec_level`` per stage of the plan's mixed-radix stage sequence
(``stage_radices`` — the paper's rule-4 radix lever, ragged final stage
included), plus the ``carry_*`` chain primitives across column tiles.

Tunable parameters consumed from the TuningDB config:
  tile_n, rows_per_program, radix, unroll (balanced-tree fold grouping;
  linrec's fold order is fixed by the algebra, so its space prunes it),
  in_register (space/model-only knob).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.blocks import primitives as prim
from repro.kernels.blocks.plan import stage_radices, stage_strides


def _scan_add_kernel(x_ref, o_ref, carry_ref, *, stages: Tuple[int, ...],
                     unroll: int, multi_tile: bool):
    if multi_tile:
        prim.carry_init(carry_ref)
    x = x_ref[...].astype(jnp.float32)
    for fan_in, stride in zip(stages, stage_strides(stages)):
        x = prim.shift_fold(x, fan_in, stride, fill=0.0, unroll=unroll)
    if multi_tile:
        x = prim.carry_fold_add(x, carry_ref)
    o_ref[...] = x.astype(o_ref.dtype)


def _scan_linrec_kernel(a_ref, b_ref, h_ref, carry_ref, *,
                        stages: Tuple[int, ...], multi_tile: bool,
                        gate: bool = False,
                        want_products: bool = False, p_ref=None):
    if multi_tile:
        prim.carry_init(carry_ref)
    aa = a_ref[...].astype(jnp.float32)
    bb = b_ref[...].astype(jnp.float32)
    if gate:
        # fused rglru chain: b_ref holds u; the elementwise gate runs as
        # the stage loop's prologue instead of a separate XLA HBM pass
        bb = prim.rglru_gate(aa, bb)
    for fan_in, stride in zip(stages, stage_strides(stages)):
        aa, bb = prim.linrec_level(aa, bb, fan_in, stride)
    # aa now holds prefix products of a; bb the zero-state response
    if want_products:
        p_ref[...] = aa.astype(p_ref.dtype)
    if multi_tile:
        h = prim.carry_fold_linrec(aa, bb, carry_ref)
    else:
        h = bb
    h_ref[...] = h.astype(h_ref.dtype)


def _linrec_prod_kernel(a_ref, b_ref, h_ref, p_ref, carry_ref, *,
                        stages: Tuple[int, ...], multi_tile: bool,
                        gate: bool = False):
    _scan_linrec_kernel(a_ref, b_ref, h_ref, carry_ref, stages=stages,
                        multi_tile=multi_tile, gate=gate, want_products=True,
                        p_ref=p_ref)


def _grid_and_specs(batch: int, n: int, rows: int, tile_n: int, n_in: int):
    grid = (batch // rows, n // tile_n)
    in_spec = pl.BlockSpec((rows, tile_n), lambda i, j: (i, j))
    out_spec = pl.BlockSpec((rows, tile_n), lambda i, j: (i, j))
    scratch = [pltpu.VMEM((rows, 1), jnp.float32)]
    return grid, [in_spec] * n_in, out_spec, scratch


def _resolve_stages(stages: Optional[Tuple[int, ...]], tile_n: int,
                    radix: int) -> Tuple[int, ...]:
    """Plans pass their stage sequence; direct callers fall back to the
    same decomposition the planner would produce."""
    return prim.as_stages(stages) if stages else stage_radices(tile_n, radix)


@functools.partial(jax.jit, static_argnames=("rows_per_program", "tile_n",
                                             "radix", "unroll", "stages",
                                             "interpret"))
def scan_add_pallas(x: jax.Array, *, rows_per_program: int = 8,
                    tile_n: int = 0, radix: int = 2, unroll: int = 1,
                    stages: Optional[Tuple[int, ...]] = None,
                    interpret: bool = False) -> jax.Array:
    """Inclusive prefix sum over the last axis of (batch, n)."""
    batch, n = x.shape
    tile_n = tile_n or n
    grid, in_specs, out_spec, scratch = _grid_and_specs(
        batch, n, rows_per_program, tile_n, 1)
    kernel = functools.partial(
        _scan_add_kernel, stages=_resolve_stages(stages, tile_n, radix),
        unroll=unroll, multi_tile=True)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("rows_per_program", "tile_n",
                                             "radix", "unroll", "stages",
                                             "gate", "interpret"))
def scan_linrec_pallas(a: jax.Array, b: jax.Array, *, rows_per_program: int = 8,
                       tile_n: int = 0, radix: int = 2, unroll: int = 1,
                       stages: Optional[Tuple[int, ...]] = None,
                       gate: bool = False,
                       interpret: bool = False) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along the last axis of (batch, n) pairs.

    ``gate=True`` is the fused rglru chain link: ``b`` carries the raw
    input ``u`` and the kernel applies the RG-LRU gate in-tile before the
    stage loop (one launch for the whole gate→linrec chain).
    """
    del unroll  # fold order fixed by composition order for linrec
    batch, n = a.shape
    tile_n = tile_n or n
    grid, in_specs, out_spec, scratch = _grid_and_specs(
        batch, n, rows_per_program, tile_n, 2)
    kernel = functools.partial(
        _scan_linrec_kernel, stages=_resolve_stages(stages, tile_n, radix),
        multi_tile=True, gate=gate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("rows_per_program", "radix",
                                             "stages", "gate", "interpret"))
def scan_linrec_prod_pallas(a: jax.Array, b: jax.Array, *,
                            rows_per_program: int = 8, radix: int = 2,
                            stages: Optional[Tuple[int, ...]] = None,
                            gate: bool = False,
                            interpret: bool = False):
    """Single-tile linrec returning (h, prefix products of a).

    The multi-pass driver's chunk kernel: each program holds whole rows
    (tile_n == n), so no carry chain — the products output is exactly the
    per-chunk transfer operator the carry scan then composes.  ``gate``
    fuses the RG-LRU input gate exactly as in ``scan_linrec_pallas``.
    """
    batch, n = a.shape
    rows = rows_per_program
    grid = (batch // rows, 1)
    spec = pl.BlockSpec((rows, n), lambda i, j: (i, j))
    kernel = functools.partial(
        _linrec_prod_kernel, stages=_resolve_stages(stages, n, radix),
        multi_tile=False, gate=gate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(a.shape, a.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((rows, 1), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
