"""Batched serving example: continuous batching over mixed-length requests,
with an OnlineTuner trialing kernel configs against the live decode steps.

    PYTHONPATH=src python examples/serve_lm.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.space import Workload
from repro.models.model import build_model
from repro.serve.engine import ServeEngine
from repro.tuning import OnlineTuner, TunerSession, attach

cfg = get_arch("qwen1.5-0.5b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServeEngine(model, params, max_batch=4, max_len=128)

# online tuning: wall-clock-time every decode step, shadow-trial candidate
# attention configs under a strict measurement budget, roll back slowdowns
import os
session = TunerSession(
    db_path=os.path.join(tempfile.mkdtemp(prefix="serve_lm_"), "db.json"))
tuner = OnlineTuner(Workload(op="attention", n=128, batch=4,
                             variant="flash"),
                    session, budget=16, guard_band=0.25)
attach(engine, tuner)

rng = np.random.default_rng(0)
for i in range(10):
    engine.submit(rng.integers(0, cfg.vocab, size=int(rng.integers(4, 20))),
                  max_new_tokens=8)
t0 = time.perf_counter()
done = engine.run()
dt = time.perf_counter() - t0
tokens = sum(len(r.output) for r in done)
print(f"[serve_lm] {len(done)} requests / {tokens} tokens in {dt:.2f}s "
      f"({tokens/dt:.1f} tok/s, continuous batching over 4 slots)")
for r in done[:3]:
    print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> {r.output}")

s = tuner.summary()
print(f"[serve_lm] online tuner: {s['state']} after {s['steps']} steps, "
      f"{s['measured']}/{s['budget']} trial measurements, "
      f"{s['promotions']} promotion(s)")
