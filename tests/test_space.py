"""Search-space construction, validity, and encoding."""
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import Workload, build_space
from repro.core.space import pow2_range


def test_pow2_range():
    assert pow2_range(1, 8) == (1, 2, 4, 8)
    assert pow2_range(128, 128) == (128,)


@pytest.mark.parametrize("op,variant", [
    ("scan", "lf"), ("scan", "ks"), ("tridiag", "wm"), ("tridiag", "pcr"),
    ("tridiag", "cr"), ("tridiag", "lf"), ("fft", "stockham"),
    ("attention", "flash"), ("matmul", "tiled"),
])
def test_spaces_nonempty_and_valid(op, variant):
    wl = Workload(op=op, n=1024, batch=4096, variant=variant)
    space = build_space(wl)
    cfgs = space.enumerate_valid()
    assert cfgs, f"{op} space empty"
    for cfg in cfgs[:50]:
        assert space.is_valid(cfg)


def test_constraints_reject_oversized_vmem():
    wl = Workload(op="scan", n=4096, batch=2**20)
    space = build_space(wl)
    huge = {"tile_n": 4096, "rows_per_program": 512, "radix": 2,
            "unroll": 1, "in_register": 0}
    # 512*4096*4*2 = 16 MiB <= budget so this one is fine; push rows
    assert space.is_valid(huge) == (512 * 4096 * 4 * 2 <= space.spec.vmem_budget)


def test_in_register_rule():
    wl = Workload(op="scan", n=2048, batch=4096)
    space = build_space(wl)
    cfg = {"tile_n": 2048, "rows_per_program": 1, "radix": 2,
           "unroll": 1, "in_register": 1}
    assert not space.is_valid(cfg)   # 2048 > lane*sublane budget


def test_wm_only_tridiag_radix():
    for variant, radices in [("wm", {2, 4, 8}), ("pcr", {2})]:
        wl = Workload(op="tridiag", n=256, batch=1024, variant=variant)
        space = build_space(wl)
        seen = {c["radix"] for c in space.enumerate_valid()}
        assert seen <= radices


def test_encode_in_unit_cube():
    wl = Workload(op="fft", n=1024, batch=8192, variant="stockham")
    space = build_space(wl)
    for cfg in space.enumerate_valid():
        for c in space.encode(cfg):
            assert -1e-9 <= c <= 1 + 1e-9


@given(n=st.sampled_from([128, 256, 512, 1024, 2048]),
       batch=st.sampled_from([256, 4096, 65536]))
@settings(max_examples=10, deadline=None)
def test_scan_space_valid_configs_satisfy_constraints(n, batch):
    wl = Workload(op="scan", n=n, batch=batch)
    space = build_space(wl)
    for cfg in space.enumerate_valid():
        assert cfg["tile_n"] <= n and n % cfg["tile_n"] == 0
        assert batch % cfg["rows_per_program"] == 0
