"""Shared in-kernel building blocks (the BPLG CTA-primitive layer).

Every staged kernel in the repo is a composition of four primitives, all
operating on the trailing (lane) dimension of VMEM-resident tiles:

  * ``shift_fold``   — one radix-r Kogge-Stone level for an associative
                       monoid (prefix sum), with balanced-tree unrolling;
  * ``linrec_level`` — the same level for the (a, b) linear-recurrence
                       monoid (composition order fixed by the algebra);
  * ``butterfly``    — the radix-rr complex DFT fold + twiddles of one
                       Stockham stage, including the ``stage_view``
                       reshape-repack (the index-digit layout transform);
  * ``carry chain``  — init/fold/store of the cross-tile VMEM carry that
                       turns a column-tiled grid into one streaming pass.

Extracted from the historical per-kernel copies in scan/fft/tridiag so a
new kernel family composes them instead of re-rolling its own stage loop
(docs/kernels.md walks through a port).  Stage sequences come from
``repro.kernels.blocks.plan.stage_radices`` — never recompute them here.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Lane shifts
# ---------------------------------------------------------------------------

def shift_lanes(x: jax.Array, off: int, fill: float) -> jax.Array:
    """Shift the trailing dim by ``off`` lanes, filling with the monoid
    identity.  off > 0 shifts right (element i sees neighbour i - off),
    off < 0 shifts left.  Mosaic lowers the concatenate to lane shifts."""
    if off == 0:
        return x
    pad = jnp.full(x.shape[:-1] + (abs(off),), fill, dtype=x.dtype)
    if off > 0:
        return jnp.concatenate([pad, x[..., :-off]], axis=-1)
    return jnp.concatenate([x[..., -off:], pad], axis=-1)


# ---------------------------------------------------------------------------
# Radix-r Kogge-Stone fold (associative monoid)
# ---------------------------------------------------------------------------

def _tree_fold(parts: List[jax.Array]) -> jax.Array:
    """Balanced pairwise reduction — associativity buys ILP (rule 3)."""
    while len(parts) > 1:
        nxt = []
        for i in range(0, len(parts) - 1, 2):
            nxt.append(parts[i] + parts[i + 1])
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    return parts[0]


def shift_fold(x: jax.Array, fan_in: int, stride: int, *, fill: float = 0.0,
               unroll: int = 1) -> jax.Array:
    """One stage of a radix-``fan_in`` prefix circuit: fold the fan_in - 1
    shifted neighbours at multiples of ``stride`` into every element."""
    tile_n = x.shape[-1]
    shifted = [shift_lanes(x, k * stride, fill) for k in range(1, fan_in)
               if k * stride < tile_n]
    if not shifted:
        return x
    if unroll > 1:
        return x + _tree_fold(shifted)
    acc = x
    for sh in shifted:
        acc = acc + sh
    return acc


def linrec_level(aa: jax.Array, bb: jax.Array, fan_in: int, stride: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """One stage for the linear-recurrence pair monoid.

    Composition (a, b)_new after (a, b)_old is (a_o * a_n, a_n * b_o + b_n);
    the fold order is fixed by the algebra, so there is no unroll knob —
    the search spaces prune it for linrec variants.
    """
    tile_n = aa.shape[-1]
    acc_a, acc_b = aa, bb
    for k in range(1, fan_in):
        off = k * stride
        if off >= tile_n:
            break
        sa = shift_lanes(aa, off, 1.0)    # identity transform: a = 1
        sb = shift_lanes(bb, off, 0.0)    # identity transform: b = 0
        acc_b = acc_a * sb + acc_b
        acc_a = acc_a * sa
    return acc_a, acc_b


# ---------------------------------------------------------------------------
# Stockham butterfly stage (complex fold on split re/im planes)
# ---------------------------------------------------------------------------

def cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def butterfly(re: jax.Array, im: jax.Array, *, n: int, n_cur: int, s: int,
              rr: int, sign: float) -> Tuple[jax.Array, jax.Array]:
    """One radix-``rr`` Stockham stage on (rows, n) split planes.

    ``stage_view``: the planes are viewed as (rows, n_cur, s), split into
    rr parts of m = n_cur // rr, folded through the rr-point DFT matrix
    with per-part twiddles, and repacked with the radix digit innermost —
    the self-sorting index-digit layout transform.  ``rr`` must divide
    ``n_cur``; plans built from ``stage_radices`` guarantee it (the ragged
    mixed-radix final stage simply arrives with a smaller rr).
    """
    rows = re.shape[0]
    assert n_cur % rr == 0, (n_cur, rr)
    m = n_cur // rr
    vr = re.reshape(rows, n_cur, s)
    vi = im.reshape(rows, n_cur, s)
    parts = [(vr[:, k * m:(k + 1) * m, :], vi[:, k * m:(k + 1) * m, :])
             for k in range(rr)]
    p = jax.lax.broadcasted_iota(jnp.float32, (1, m, 1), 1)
    outs = []
    for j in range(rr):
        tr = jnp.zeros((rows, m, s), jnp.float32)
        ti = jnp.zeros((rows, m, s), jnp.float32)
        for k in range(rr):
            ang = sign * 2.0 * math.pi * ((j * k) % rr) / rr
            wr, wi = math.cos(ang), math.sin(ang)
            pr, pi_ = parts[k]
            tr += pr * wr - pi_ * wi
            ti += pr * wi + pi_ * wr
        theta = sign * 2.0 * math.pi * j / n_cur
        twr = jnp.cos(theta * p)
        twi = jnp.sin(theta * p)
        tr, ti = cmul(tr, ti, twr, twi)
        outs.append((tr, ti))
    re = jnp.stack([o[0] for o in outs], axis=2).reshape(rows, n)
    im = jnp.stack([o[1] for o in outs], axis=2).reshape(rows, n)
    return re, im


# ---------------------------------------------------------------------------
# PCR reduction step (the tridiagonal fold)
# ---------------------------------------------------------------------------

def pcr_step(a, b, c, d, stride: int):
    """One full-width cyclic-reduction level at ``stride``: every equation
    eliminates its +-stride neighbours (identity fill keeps pivots finite)."""
    bm = shift_lanes(b, stride, 1.0)
    bp = shift_lanes(b, -stride, 1.0)
    am, ap = shift_lanes(a, stride, 0.0), shift_lanes(a, -stride, 0.0)
    cm, cp = shift_lanes(c, stride, 0.0), shift_lanes(c, -stride, 0.0)
    dm, dp = shift_lanes(d, stride, 0.0), shift_lanes(d, -stride, 0.0)
    alpha = -a / bm
    gamma = -c / bp
    return (alpha * am,
            b + alpha * cm + gamma * ap,
            gamma * cp,
            d + alpha * dm + gamma * dp)


# ---------------------------------------------------------------------------
# Cross-tile carry chain
# ---------------------------------------------------------------------------

def carry_init(carry_ref, axis: int = 1) -> None:
    """Zero the VMEM carry on the first sequential tile of ``axis``."""
    @pl.when(pl.program_id(axis) == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)


def carry_fold_add(x: jax.Array, carry_ref) -> jax.Array:
    """Fold the running prefix into this tile; store the new carry."""
    x = x + carry_ref[...]
    carry_ref[...] = x[:, -1:]
    return x


def carry_fold_linrec(aa: jax.Array, bb: jax.Array, carry_ref) -> jax.Array:
    """h = b + a * carry for the tile; store the tile's exit state."""
    h = bb + aa * carry_ref[...]
    carry_ref[...] = h[:, -1:]
    return h


# ---------------------------------------------------------------------------
# Chain-fusion links (elementwise ops folded into a stage loop's prologue)
# ---------------------------------------------------------------------------

def rglru_gate(aa: jax.Array, uu: jax.Array) -> jax.Array:
    """RG-LRU input gate b = sqrt(max(1 - a^2, 0)) * u, in-tile.

    The fused rglru chain runs this as the scan kernel's first stage
    (``gate=True``) instead of a separate XLA pass — the ``fuse=1`` arm of
    the chain planner, saving one full HBM roundtrip over the rows.
    """
    return jnp.sqrt(jnp.maximum(1.0 - aa * aa, 0.0)) * uu


# ---------------------------------------------------------------------------
# Stage-sequence helpers shared by the kernel wrappers
# ---------------------------------------------------------------------------

def as_stages(stages: Sequence[int]) -> Tuple[int, ...]:
    """Normalize a plan's stage sequence into a hashable static argument."""
    return tuple(int(r) for r in stages)
