"""Offline tuning CLI — populates the TuningDB and trains the ML predictor.

Search (legacy flag style, unchanged):

  PYTHONPATH=src python -m repro.launch.tune --op scan --variant lf \
      --sizes 128,256,512 --method bayesian
  PYTHONPATH=src python -m repro.launch.tune --paper-suite   # all paper ops

ML-based methodology (paper's offline-train / online-predict flow):

  PYTHONPATH=src python -m repro.launch.tune train-model \
      --out artifacts/ml_model.npz --db artifacts/ci_tuning_db.json --seed 0
  PYTHONPATH=src python -m repro.launch.tune eval-model \
      --model artifacts/ml_model.npz --min-top1 0.70 --max-slowdown 1.15

``train-model`` sweeps the training suite exhaustively on the TPU cost
model (and, with ``--db``, persists each sweep's winner into that TuningDB
— the synthetic fixture CI trains against — and folds any pre-existing
records in as extra training rows).  ``eval-model`` reports top-1 config
match rate and predicted-vs-best slowdown against the exhaustive optimum
on held-out problem sizes, exiting non-zero when the pinned floors are
violated (the CI regression gate for the learned strategy).

Methodology comparison (the paper's Table II as a CI artifact):

  PYTHONPATH=src python -m repro.launch.tune compare-methods \
      --json BENCH_methods.json [--model artifacts/ml_model.npz]

runs analytical/ml/online/bayesian/random against the exhaustive optimum
on the holdout suite and exits non-zero if exhaustive is ever beaten
(Phi > 1 is a sweep/objective bug, not a better methodology).
``--policies latency,energy,edp`` re-scores every method per tuning
policy (see docs/tuning.md, "Multi-objective tuning & policies"); Phi > 1
in ANY (method, policy) cell fails the same way.  With
``--device-matrix`` the comparison runs once per hardware profile
(default tpu_v5e,gpu_sm,cpu_interpret — see docs/hardware.md) sharing one
journal directory, so ``strategy="transfer"`` on later devices warm-starts
from earlier devices' sweeps; Phi > 1 in ANY (device, method) cell fails.

Online tuning replay (the deployment mode's deterministic test bench):

  PYTHONPATH=src python -m repro.launch.tune online-replay \
      --trace artifacts/serve_trace.jsonl [--db tuning_db.json] [--budget 32]

replays a recorded (config, step latency) trace — e.g. from
``repro.launch.serve --record-trace`` — through the OnlineTuner state
machine: same trace + same knobs -> same trials, same rollbacks, same
winner. With ``--db`` the promoted winner persists exactly as it would in
production.

Static analysis (zero-execution; the CI ``lint-analysis`` gate):

  PYTHONPATH=src python -m repro.launch.tune lint [--json REPORT] \
      [--baseline tests/fixtures/analysis_baseline.json] [--no-invariants]

runs the full :mod:`repro.analysis` pass — repo-convention AST lint,
version-drift fingerprints, and plan/space invariants for every op x
profile (see docs/analysis.md) — and exits non-zero on any finding not
suppressed by the baseline. ``--write-fingerprints`` refreshes the pinned
contract fixture after a deliberate, version-bumped schema change.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.configs.paper_ops import PREFIX_OPS, TOTAL_ELEMS
from repro.core import CostModelObjective, Workload
from repro.tuning import TunerSession, default_session, strategies


def tune_suite(method: str, noise: float = 0.02, verbose: bool = True,
               session: Optional[TunerSession] = None) -> None:
    session = session or default_session()
    for op, spec in PREFIX_OPS.items():
        for variant in spec["variants"]:
            for n in spec["sizes"]:
                wl = Workload(op=op, n=n, batch=max(TOTAL_ELEMS // n, 1),
                              variant=variant)
                res = session.tune(wl, method=method,
                                   objective=CostModelObjective(noise=noise))
                if verbose:
                    print(f"[tune] {wl.key}: {res.best_config} "
                          f"t={res.best_time*1e6:.1f}us "
                          f"evals={res.evaluations}", flush=True)


# ---------------------------------------------------------------------------
# ML model subcommands
# ---------------------------------------------------------------------------

def _parse_ops(arg: Optional[str]) -> Optional[List[str]]:
    return [s for s in arg.split(",") if s] if arg else None


def train_model_main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(prog="tune train-model",
                                 description="Train the ML config predictor")
    ap.add_argument("--out", required=True, help="model artifact (.npz) path")
    ap.add_argument("--ops", default=None,
                    help="comma list of ops (default: the full suite)")
    ap.add_argument("--db", default=None,
                    help="TuningDB fixture: sweep winners are stored here and "
                         "existing records join the training set")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trees", type=int, default=48)
    ap.add_argument("--depth", type=int, default=12)
    ap.add_argument("--noise", type=float, default=0.0,
                    help="cost-model jitter while sweeping (default off)")
    ap.add_argument("--journal-dir", default=None,
                    help="checkpoint the exhaustive sweeps as JSONL journals "
                         "here; an interrupted train-model rerun resumes "
                         "instead of re-evaluating (see docs/tuning.md)")
    args = ap.parse_args(argv)

    import numpy as np

    from repro.tuning.db import TuningDB
    from repro.tuning.ml import (build_dataset, dataset_from_db, merge,
                                 suite_workloads, train_bundle)
    from repro.tuning.ml.dataset import POOLED_OPS

    objective = CostModelObjective(noise=args.noise)
    try:
        workloads = suite_workloads("train", ops=_parse_ops(args.ops))
    except ValueError as e:
        ap.error(str(e))
    print(f"[train-model] sweeping {len(workloads)} workloads ...", flush=True)

    prior = None
    on_sweep = None
    if args.db:
        db = TuningDB(path=args.db)
        prior = dataset_from_db(db)

        def on_sweep(wl, cfgs, times):   # persist each winner: the fixture
            i = int(np.argmin(times))
            db.store(wl, cfgs[i], float(times[i]), "exhaustive", len(cfgs))

    ds = build_dataset(workloads, objective, on_sweep=on_sweep,
                       journal_dir=args.journal_dir)
    if prior is not None and len(prior):
        print(f"[train-model] +{len(prior)} rows from TuningDB {args.db}",
              flush=True)
        ds = merge(ds, prior)

    print(f"[train-model] {len(ds)} rows; training "
          f"(trees={args.trees}, depth={args.depth}, seed={args.seed})",
          flush=True)
    bundle = train_bundle(ds.by_op(), n_trees=args.trees,
                          max_depth=args.depth, seed=args.seed,
                          meta={"aliases": POOLED_OPS})
    path = bundle.save(args.out)
    for op, rows in sorted(bundle.meta["train_rows"].items()):
        print(f"[train-model]   {op}: {rows} rows")
    print(f"[train-model] saved {path}")
    return 0


def online_replay_main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(prog="tune online-replay",
                                 description="Replay a recorded serving "
                                             "trace through the OnlineTuner")
    ap.add_argument("--trace", required=True,
                    help="JSONL trace from launch.serve --record-trace")
    ap.add_argument("--db", default=None,
                    help="TuningDB to persist the promoted winner into "
                         "(default: replay only, nothing stored)")
    ap.add_argument("--journal-dir", default=None,
                    help="journal trial EWMAs here (sweep-journal format)")
    ap.add_argument("--budget", type=int, default=32)
    ap.add_argument("--guard-band", type=float, default=0.25)
    ap.add_argument("--min-samples", type=int, default=3)
    ap.add_argument("--samples-per-trial", type=int, default=8)
    ap.add_argument("--json", default=None, help="write the summary here")
    args = ap.parse_args(argv)

    from repro.core.analytical import AnalyticalTuner
    from repro.core.space import build_space
    from repro.tuning import OnlineTuner, ReplayTrace, TunerSession, replay
    from repro.tuning.online import replay_candidates
    from repro.tuning.sweep import config_key

    trace = ReplayTrace.load(args.trace)
    wl = trace.workload.canonical()
    session = TunerSession(db_path=args.db) if args.db else None
    store = session is not None

    prior = session.resolve_raw(wl) if session is not None \
        else AnalyticalTuner().suggest(build_space(wl))
    if config_key(prior) not in trace.times:
        # the trace never measured the configured prior (e.g. a DB-less
        # replay of someone else's traffic): start from the config the
        # traffic actually ran, so the baseline is a real measurement
        first = next(iter(trace.configs))
        print(f"[online-replay] prior not in trace; using recorded config "
              f"{trace.configs[first]} as incumbent")
        prior = trace.configs[first]
    # trial only configs the trace can answer for — every recorded config
    # stays in the queue (expert-ranked, never truncated: the trace's
    # measured winner may rank poorly analytically and must still run)
    space = build_space(wl)
    candidates = replay_candidates(space, trace, prior)

    tuner = OnlineTuner(wl, session, prior=prior, candidates=candidates,
                        budget=args.budget, guard_band=args.guard_band,
                        min_samples=args.min_samples,
                        samples_per_trial=args.samples_per_trial,
                        journal_dir=args.journal_dir, store=store,
                        source=trace.source)
    res = replay(tuner, trace)
    s = tuner.summary()
    print(f"[online-replay] {wl.key}: {trace.steps()} recorded steps, "
          f"{len(candidates)} candidates")
    print(f"[online-replay] stopped_by={res.stopped_by} "
          f"measured={s['measured']}/{s['budget']} "
          f"promotions={s['promotions']}")
    for t in s["trials"]:
        ewma = f"{t['ewma_s']*1e3:.3f}ms" if t["ewma_s"] else "-"
        print(f"[online-replay]   {t['config']} -> {t['state']} "
              f"(samples={t['samples']}, ewma={ewma})")
    print(f"[online-replay] winner {res.best_config} "
          f"ewma={res.best_time*1e3:.3f}ms"
          + (f" (persisted to {args.db})" if store and s["promotions"]
             else ""))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(s, f, indent=1, sort_keys=True)
        print(f"[online-replay] summary written to {args.json}")
    return 0


def compare_methods_main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(prog="tune compare-methods",
                                 description="Score every methodology "
                                             "against the exhaustive optimum")
    ap.add_argument("--json", default="BENCH_methods.json",
                    help="report artifact path (default BENCH_methods.json)")
    ap.add_argument("--ops", default=None,
                    help="comma list of ops (default: the full suite)")
    ap.add_argument("--split", default="holdout", choices=("train", "holdout"),
                    help="which suite split to score (default holdout)")
    ap.add_argument("--methods", default=",".join(
                        ("analytical", "ml", "online", "bayesian", "random")),
                    help="comma list of strategies to compare")
    ap.add_argument("--model", default=None,
                    help="ML model artifact for strategy='ml' (sets "
                         "$REPRO_ML_MODEL; default: the session default)")
    ap.add_argument("--max-evals", type=int, default=20,
                    help="per-workload budget for the search strategies")
    ap.add_argument("--noise", type=float, default=0.0,
                    help="cost-model jitter (deterministic, hash-seeded)")
    ap.add_argument("--journal-dir", default=None,
                    help="checkpoint/resume the exhaustive sweeps here")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--device-matrix", action="store_true",
                    help="run the comparison once per hardware profile and "
                         "gate every (device, method) cell on Phi <= 1; "
                         "overrides --methods with the matrix defaults "
                         "unless --methods is given explicitly")
    ap.add_argument("--profiles", default=None,
                    help="comma list of hardware profiles for --device-matrix "
                         "(default: tpu_v5e,gpu_sm,cpu_interpret; order "
                         "matters — earlier devices' journals seed "
                         "strategy='transfer' on later ones)")
    ap.add_argument("--policies", default="latency",
                    help="comma list of tuning policies to score per method "
                         "(latency, energy, edp, memory_cap[:bytes]); any "
                         "(method, policy) Phi > 1 fails")
    args = ap.parse_args(argv)

    import os
    import tempfile

    from repro.evaluation import (check_matrix, check_report, compare_methods,
                                  compare_methods_matrix, format_matrix,
                                  format_report)
    from repro.tuning.ml import suite_workloads

    if args.model:
        os.environ["REPRO_ML_MODEL"] = args.model
    try:
        workloads = suite_workloads(args.split, ops=_parse_ops(args.ops))
    except ValueError as e:
        ap.error(str(e))

    if args.device_matrix:
        from repro.evaluation.compare import (DEFAULT_MATRIX_METHODS,
                                              DEFAULT_MATRIX_PROFILES)
        explicit_methods = any(a == "--methods" or a.startswith("--methods=")
                               for a in argv)
        methods = tuple(m for m in args.methods.split(",") if m) \
            if explicit_methods else DEFAULT_MATRIX_METHODS
        profiles = tuple(p for p in args.profiles.split(",") if p) \
            if args.profiles else DEFAULT_MATRIX_PROFILES
        # transfer needs cross-device journals: default to a scratch dir so
        # a bare invocation still exercises the warm-start path
        journal_dir = args.journal_dir or tempfile.mkdtemp(
            prefix="repro_matrix_journals_")
        print(f"[compare-methods] device matrix: {len(workloads)} "
              f"{args.split} workloads x {len(methods)} methodologies x "
              f"{len(profiles)} profiles ...", flush=True)
        matrix = compare_methods_matrix(
            workloads, methods, profiles, seed=args.seed,
            max_evals=args.max_evals, journal_dir=journal_dir,
            policies=tuple(p for p in args.policies.split(",") if p))
        matrix["suite"] = {"split": args.split, "seed": args.seed,
                           "noise": args.noise, "max_evals": args.max_evals}
        print(format_matrix(matrix))
        with open(args.json, "w") as f:
            json.dump(matrix, f, indent=1, sort_keys=True)
        print(f"[compare-methods] matrix report written to {args.json}")
        failures = check_matrix(matrix)
        for failure in failures:
            print(f"[compare-methods] FAIL: {failure}", file=sys.stderr)
        return 1 if failures else 0

    methods = tuple(m for m in args.methods.split(",") if m)
    print(f"[compare-methods] {len(workloads)} {args.split} workloads x "
          f"{len(methods)} methodologies ...", flush=True)
    report = compare_methods(
        workloads, methods,
        objective_factory=lambda: CostModelObjective(noise=args.noise),
        seed=args.seed, max_evals=args.max_evals,
        journal_dir=args.journal_dir,
        policies=tuple(p for p in args.policies.split(",") if p))
    report["suite"] = {"split": args.split, "seed": args.seed,
                       "noise": args.noise, "max_evals": args.max_evals}
    print(format_report(report))
    with open(args.json, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"[compare-methods] report written to {args.json}")

    failures = check_report(report)
    for failure in failures:
        print(f"[compare-methods] FAIL: {failure}", file=sys.stderr)
    return 1 if failures else 0


def eval_model_main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(prog="tune eval-model",
                                 description="Evaluate the ML config "
                                             "predictor on held-out sizes")
    ap.add_argument("--model", required=True, help="model artifact (.npz)")
    ap.add_argument("--ops", default=None,
                    help="comma list of ops (default: the full holdout suite)")
    ap.add_argument("--min-top1", type=float, default=None,
                    help="fail when top-1 match rate drops below this floor")
    ap.add_argument("--max-slowdown", type=float, default=None,
                    help="fail when mean slowdown exceeds this ceiling")
    ap.add_argument("--min-ml-rate", type=float, default=None,
                    help="fail when the fraction of workloads answered by "
                         "the learned rungs (vs fallbacks) drops below this")
    ap.add_argument("--min-rank-corr", type=float, default=None,
                    help="fail when the forest's mean predicted-vs-true "
                         "rank correlation drops below this (catches a "
                         "degenerate model hiding behind analytical defers)")
    ap.add_argument("--json", default=None, help="write the full report here")
    ap.add_argument("--seed", type=int, default=0,
                    help="accepted for CLI uniformity; evaluation is "
                         "deterministic")
    args = ap.parse_args(argv)

    from repro.tuning.ml import (ModelBundle, check_floors, evaluate_model,
                                 suite_workloads)

    bundle = ModelBundle.load(args.model)
    try:
        workloads = suite_workloads("holdout", ops=_parse_ops(args.ops))
    except ValueError as e:
        ap.error(str(e))
    report = evaluate_model(bundle, workloads)

    print(f"[eval-model] {report['n_scored']} holdout workloads scored; "
          f"rungs: {report.get('rungs', {})}")
    for op, r in sorted(report.get("per_op", {}).items()):
        print(f"[eval-model]   {op:<10} top1={r['top1_rate']:5.1%}  "
              f"mean={r['mean_slowdown']:.3f}x  max={r['max_slowdown']:.3f}x  "
              f"(n={r['n']})")
    if report["n_scored"]:
        print(f"[eval-model] overall    top1={report['top1_rate']:5.1%}  "
              f"mean={report['mean_slowdown']:.3f}x  "
              f"max={report['max_slowdown']:.3f}x  "
              f"ml_rate={report['ml_rate']:5.1%}  "
              f"rank_corr={report['mean_rank_corr']:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"[eval-model] report written to {args.json}")

    failures = check_floors(report, min_top1=args.min_top1,
                            max_mean_slowdown=args.max_slowdown,
                            min_ml_rate=args.min_ml_rate,
                            min_rank_corr=args.min_rank_corr)
    for failure in failures:
        print(f"[eval-model] FAIL: {failure}", file=sys.stderr)
    return 1 if failures else 0


def lint_main(argv: List[str]) -> int:
    import os

    from repro.analysis import (apply_baseline, default_fixture_path,
                                load_baseline, report_dict, run_lint,
                                write_fingerprints)
    ap = argparse.ArgumentParser(
        prog="tune lint",
        description="zero-execution static analysis: AST conventions, "
                    "contract fingerprints, plan/space invariants "
                    "(docs/analysis.md)")
    ap.add_argument("--json", default=None,
                    help="write the full machine-readable report here")
    ap.add_argument("--baseline", default=None,
                    help="suppression file (default: "
                         "tests/fixtures/analysis_baseline.json when "
                         "present)")
    ap.add_argument("--write-fingerprints", action="store_true",
                    help="refresh the pinned contract fixture from the "
                         "live tree (after a deliberate, version-bumped "
                         "schema change)")
    ap.add_argument("--no-invariants", action="store_true",
                    help="skip the op x profile semantic sweep (fast "
                         "pre-commit mode; CI runs everything)")
    ap.add_argument("--root", default=None,
                    help="package root to AST-lint (default: the "
                         "installed repro package)")
    args = ap.parse_args(argv)

    fixture = default_fixture_path()
    if args.write_fingerprints:
        write_fingerprints(fixture)
        print(f"[lint] fingerprints written to {fixture}")

    findings = run_lint(pkg_root=args.root, fingerprint_path=fixture,
                        invariants=not args.no_invariants)
    baseline = args.baseline
    if baseline is None:
        cand = os.path.join(os.path.dirname(fixture),
                            "analysis_baseline.json")
        baseline = cand if os.path.exists(cand) else None
    fresh, suppressed = apply_baseline(findings, load_baseline(baseline))
    for f in fresh:
        print(f.render())
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report_dict(fresh, suppressed), fh, indent=1,
                      sort_keys=True)
        print(f"[lint] report written to {args.json}")
    print(f"[lint] {len(fresh)} finding(s), {len(suppressed)} baselined")
    return 1 if fresh else 0


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:]) if argv is None else list(argv)
    if argv and argv[0] == "lint":
        return lint_main(argv[1:])
    if argv and argv[0] == "train-model":
        return train_model_main(argv[1:])
    if argv and argv[0] == "eval-model":
        return eval_model_main(argv[1:])
    if argv and argv[0] == "compare-methods":
        return compare_methods_main(argv[1:])
    if argv and argv[0] == "online-replay":
        return online_replay_main(argv[1:])

    ap = argparse.ArgumentParser()
    ap.add_argument("--op", default=None)
    ap.add_argument("--variant", default="")
    ap.add_argument("--sizes", default="")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--method", default="bayesian", choices=list(strategies()))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="latency",
                    help="tuning policy: latency (default), energy, edp, or "
                         "memory_cap[:bytes] — see docs/tuning.md")
    ap.add_argument("--db", default=None,
                    help="path to the tuning DB (default: the session DB)")
    ap.add_argument("--paper-suite", action="store_true")
    args = ap.parse_args(argv)

    session = TunerSession(db_path=args.db, policy=args.policy) if args.db \
        else default_session()
    if args.paper_suite:
        tune_suite(args.method, session=session)
        return 0
    assert args.op and args.sizes
    for n in [int(s) for s in args.sizes.split(",")]:
        wl = Workload(op=args.op, n=n,
                      batch=args.batch or max(TOTAL_ELEMS // n, 1),
                      variant=args.variant)
        res = session.tune(wl, method=args.method, seed=args.seed,
                           policy=args.policy)
        if args.policy == "latency":
            score = f"t={res.best_time*1e6:.1f}us"
        else:   # best_time is the policy scalar, not seconds
            score = f"{args.policy}={res.best_time:.6g}"
        print(f"[tune] {wl.key}: {res.best_config} "
              f"{score} evals={res.evaluations}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
