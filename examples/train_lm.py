"""End-to-end training driver: a ~100M-parameter decoder LM for a few
hundred steps with checkpointing + fault tolerance.

    PYTHONPATH=src python examples/train_lm.py                 # host preset
    PYTHONPATH=src python examples/train_lm.py --preset 100m   # full 100M
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.data.pipeline import Batcher, DataConfig
from repro.models.model import build_model
from repro.train.loop import LoopConfig, run_training
from repro.train.step import TrainHParams


def preset_100m() -> ModelConfig:
    """~100M params: 12L x 512 x 8H, d_ff 2048, 32k vocab."""
    return ModelConfig(arch="lm-100m", family="dense", n_layers=12,
                       d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
                       d_ff=2048, vocab=32768, activation="swiglu",
                       param_dtype="float32", compute_dtype="float32",
                       remat="none")


def preset_host() -> ModelConfig:
    """~14M params: runs a few hundred steps in minutes on a CPU host."""
    return dataclasses.replace(preset_100m(), n_layers=4, d_model=256,
                               n_heads=4, head_dim=64, n_kv_heads=4,
                               d_ff=1024, vocab=8192, arch="lm-14m")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="host", choices=["host", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    cfg = preset_host() if args.preset == "host" else preset_100m()
    model = build_model(cfg)
    hp = TrainHParams(peak_lr=1e-3, warmup_steps=20, total_steps=args.steps,
                      z_weight=0.0)
    data = iter(Batcher(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                   global_batch=args.batch)))
    loop = LoopConfig(total_steps=args.steps, log_every=10,
                      checkpoint_every=50, checkpoint_dir=args.ckpt)
    out = run_training(model, hp, loop, data)
    h0, h1 = out["history"][0], out["history"][-1]
    print(f"[train_lm] loss {h0['loss']:.3f} -> {h1['loss']:.3f} over "
          f"{args.steps} steps (resumed_from={out['resumed_from']})")


if __name__ == "__main__":
    main()
