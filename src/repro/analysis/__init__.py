"""Static analysis for the tuning stack (``tune.py lint``, docs/analysis.md).

Two halves, one report:

  * **semantic invariants** (:mod:`repro.analysis.invariants`) — plan
    soundness, model agreement, feasibility, and dead knobs for every
    ``known_ops()`` op under every registered hardware profile, plus
    version-drift fingerprints (:mod:`repro.analysis.fingerprints`) for
    the persisted contracts;
  * **repo-convention AST lint** (:mod:`repro.analysis.astlint`) — pure
    stdlib ``ast`` rules over ``src/repro`` enforcing the conventions the
    stack's tests rely on (injectable clocks, the O_APPEND journal
    helper, no retired shims or deprecated aliases, vector-objective
    overrides, no mutable defaults).

Everything is pure inspection: no kernel executes, no file is written
(except ``--write-fingerprints``), and a full run stays under the
``bench_analysis`` wall-clock gate.
"""
from __future__ import annotations

from typing import List, Optional

from repro.analysis.astlint import RULES, LintContext, lint_source, lint_tree
from repro.analysis.findings import (Finding, apply_baseline, load_baseline,
                                     report_dict)
from repro.analysis.fingerprints import (CONTRACTS, check_fingerprints,
                                         current_fingerprints,
                                         default_fixture_path,
                                         write_fingerprints)
from repro.analysis.invariants import (check_dead_knobs, check_invariants,
                                       check_space, find_dead_knobs,
                                       suite_grid)

__all__ = [
    "Finding", "RULES", "LintContext", "CONTRACTS",
    "lint_source", "lint_tree",
    "apply_baseline", "load_baseline", "report_dict",
    "check_fingerprints", "current_fingerprints", "default_fixture_path",
    "write_fingerprints",
    "check_dead_knobs", "check_invariants", "check_space", "find_dead_knobs",
    "suite_grid",
    "run_lint",
]


def run_lint(pkg_root: Optional[str] = None,
             fingerprint_path: Optional[str] = None,
             invariants: bool = True) -> List[Finding]:
    """The full pass: AST lint + fingerprints + semantic invariants.

    ``invariants=False`` skips the (comparatively slow) op x profile
    sweep — the mode pre-commit hooks want; CI and the bench gate run
    everything.
    """
    findings = lint_tree(pkg_root)
    findings += check_fingerprints(fingerprint_path
                                   or default_fixture_path())
    if invariants:
        findings += check_invariants()
    return findings
