"""Removed. ``repro.hw.tpu`` was a compatibility shim; it is gone.

The machine model is data in :mod:`repro.hw.profiles`:

* ``V5E`` / ``TpuSpec``  -> ``TPU_V5E`` / ``HardwareProfile``
* model functions (``lane_utilization``, ``dma_efficiency``, ...) live in
  ``repro.hw.profiles`` under the same names.
"""
raise ImportError(
    "repro.hw.tpu was removed: use repro.hw.profiles "
    "(TPU_V5E / HardwareProfile / get_profile('tpu_v5e')) — "
    "see docs/hardware.md")
