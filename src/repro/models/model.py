"""Model builder: config -> init / forward / prefill / decode functions.

Layer stacks are `lax.scan`ned over parameter groups so HLO size is O(1) in
depth (critical for 88–100-layer archs in the 512-device dry-run). A "group"
is the architecture's repeating pattern:
  dense/moe: 1 block;  hybrid: (rec, rec, local-attn);  vlm: 4 standard +
  1 cross-attn block;  ssm: 1 SSD block;  audio: enc stack + dec stack.

Caches are pytrees with a leading group dimension threaded through the same
scan. Modality frontends (whisper conv, vision patching) are STUBS per the
assignment: forward takes precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import recurrent as rec_mod
from repro.models import ssm as ssm_mod
from repro.models.moe import init_moe, moe_block

PyTree = Any


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _constrain_batch(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Pin the batch sharding of the residual stream. The embedding gather
    (vocab-sharded table) otherwise replicates its output batch dim, and
    the whole stack inherits the replication (measured 40x memory)."""
    if not cfg.batch_axes or (cfg.batch_shards
                              and x.shape[0] % cfg.batch_shards):
        return x
    b = cfg.batch_axes if len(cfg.batch_axes) > 1 else cfg.batch_axes[0]
    U = P.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(x, P(b, *([U] * (x.ndim - 1))))


def _constrain_residual(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Sequence-parallel (SP) sharding of the residual stream at layer
    boundaries: (B, L, D) -> P(batch, "model", None).

    The tensor saved per scanned layer for the backward pass is the block
    input; without SP it is only batch-sharded, and deep/wide archs blow
    past HBM (granite-34b: 88 x (16, 4096, 6144) bf16 = 66 GiB/device).
    With SP the saves shrink by the model-axis size; GSPMD inserts the
    all-gather at attention entry / reduce-scatter after (Korthikanti et
    al.-style SP, GSPMD-native)."""
    if (cfg.activation_strategy != "sp" or not cfg.batch_axes
            or not cfg.model_axis_size or x.ndim != 3
            or x.shape[1] % cfg.model_axis_size
            or (cfg.batch_shards and x.shape[0] % cfg.batch_shards)):
        return x
    b = cfg.batch_axes if len(cfg.batch_axes) > 1 else cfg.batch_axes[0]
    return jax.lax.with_sharding_constraint(
        x, P(b, "model", P.UNCONSTRAINED))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _init_std_block(key, cfg: ModelConfig, dtype, cross: bool = False) -> Dict:
    ka, km, kc = jax.random.split(key, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_mod.init_attention(ka, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(km, cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    if cross:
        p["lnx"] = jnp.zeros((cfg.d_model,), dtype)
        p["xattn"] = attn_mod.init_attention(kc, cfg, dtype, cross=True)
    return p


def _std_block(p: Dict, x, cfg: ModelConfig, *, positions, cache=None,
               window=None, memory=None, compute_dtype=None):
    cd = compute_dtype or _cdtype(cfg)
    aux = jnp.zeros((), jnp.float32)
    h, new_cache = attn_mod.self_attention(
        p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
        positions=positions, cache=cache, window=window, compute_dtype=cd)
    x = x + h
    if "xattn" in p and memory is not None:
        x = x + attn_mod.cross_attention(
            p["xattn"], L.rms_norm(x, p["lnx"], cfg.norm_eps), memory, cfg, cd)
    y = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        h, aux = moe_block(p["moe"], y, cfg, cd)
    else:
        h = L.mlp(p["mlp"], y, cfg.activation, cd)
    return x + h, new_cache, aux


def _init_ssd_group(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln": jnp.zeros((cfg.d_model,), dtype),
            "ssd": ssm_mod.init_ssd_block(k1, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff or 2 * cfg.d_model,
                              cfg.activation, dtype)
            if cfg.d_ff else None}


def _ssd_group(p, x, cfg, *, cache=None, compute_dtype=None):
    cd = compute_dtype or _cdtype(cfg)
    h, new_cache = ssm_mod.ssd_block(
        p["ssd"], L.rms_norm(x, p["ln"], cfg.norm_eps), cfg, cache=cache,
        compute_dtype=cd)
    x = x + h
    if p.get("mlp") is not None:
        x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps),
                      cfg.activation, cd)
    return x, new_cache, jnp.zeros((), jnp.float32)


def _init_hybrid_group(key, cfg, dtype):
    ks = jax.random.split(key, len(cfg.block_pattern))
    group = []
    for k, kind in zip(ks, cfg.block_pattern):
        if kind == "rec":
            k1, k2 = jax.random.split(k)
            group.append({"ln1": jnp.zeros((cfg.d_model,), dtype),
                          "rec": rec_mod.init_recurrent_block(k1, cfg, dtype),
                          "ln2": jnp.zeros((cfg.d_model,), dtype),
                          "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff,
                                            cfg.activation, dtype)})
        else:
            group.append(_init_std_block(k, cfg, dtype))
    return {"blocks": group}


def _hybrid_group(p, x, cfg, *, positions, cache=None, compute_dtype=None):
    cd = compute_dtype or _cdtype(cfg)
    new_caches = []
    for i, blk in enumerate(p["blocks"]):
        sub_cache = None if cache is None else cache[i]
        if "rec" in blk:
            def run_rec(xx, blk=blk):
                h, nc = rec_mod.recurrent_block(
                    blk["rec"], L.rms_norm(xx, blk["ln1"], cfg.norm_eps), cfg,
                    cache=sub_cache, compute_dtype=cd)
                xx = xx + h
                xx = xx + L.mlp(blk["mlp"],
                                L.rms_norm(xx, blk["ln2"], cfg.norm_eps),
                                cfg.activation, cd)
                return xx, nc
            if cache is None and cfg.remat == "full":
                # per-layer remat: without it the whole group's forward
                # stays live during the group's backward replay
                run_rec = jax.checkpoint(run_rec)
            x, nc = run_rec(x)
        else:
            def run_att(xx, blk=blk):
                return _std_block(blk, xx, cfg, positions=positions,
                                  cache=sub_cache, window=cfg.attn_window,
                                  compute_dtype=cd)
            if cache is None and cfg.remat == "full":
                run_att = jax.checkpoint(run_att)
            x, nc, _ = run_att(x)
        new_caches.append(nc)
    return x, (new_caches if cache is not None else None), \
        jnp.zeros((), jnp.float32)


def _init_vlm_group(key, cfg, dtype):
    period = cfg.cross_attn_every
    ks = jax.random.split(key, period)
    group = [_init_std_block(k, cfg, dtype, cross=(i == period - 1))
             for i, k in enumerate(ks)]
    return {"blocks": group}


def _vlm_group(p, x, cfg, *, positions, memory, cache=None,
               compute_dtype=None):
    new_caches = []
    for i, blk in enumerate(p["blocks"]):
        sub_cache = None if cache is None else cache[i]

        def run(xx, blk=blk, sub_cache=sub_cache):
            return _std_block(blk, xx, cfg, positions=positions,
                              cache=sub_cache, memory=memory,
                              compute_dtype=compute_dtype)
        if cache is None and cfg.remat == "full":
            # per-layer remat inside the 5-layer group (see _hybrid_group)
            run = jax.checkpoint(run)
        x, nc, _ = run(x)
        new_caches.append(nc)
    return x, (new_caches if cache is not None else None), \
        jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # --- structure ---
    @property
    def group_period(self) -> int:
        if self.cfg.family == "hybrid":
            return len(self.cfg.block_pattern)
        if self.cfg.family == "vlm":
            return self.cfg.cross_attn_every
        return 1

    @property
    def n_groups(self) -> int:
        return self.cfg.n_layers // self.group_period

    # --- init ---
    def init(self, key) -> PyTree:
        cfg = self.cfg
        dtype = _pdtype(cfg)
        k_embed, k_blocks, k_enc = jax.random.split(key, 3)

        def init_group(k):
            if cfg.family == "ssm":
                return _init_ssd_group(k, cfg, dtype)
            if cfg.family == "hybrid":
                return _init_hybrid_group(k, cfg, dtype)
            if cfg.family == "vlm":
                return _init_vlm_group(k, cfg, dtype)
            return _init_std_block(k, cfg, dtype)

        params = {
            "embed": L.init_embedding(k_embed, cfg.vocab, cfg.d_model, dtype),
            "blocks": jax.vmap(init_group)(
                jax.random.split(k_blocks, self.n_groups)),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
        if cfg.is_enc_dec:
            n_enc = cfg.n_enc_layers or cfg.n_layers
            enc_cfg = dataclasses.replace(cfg, family="dense")

            def init_enc(k):
                return _init_std_block(k, enc_cfg, dtype)

            params["enc_blocks"] = jax.vmap(init_enc)(
                jax.random.split(k_enc, n_enc))
            params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
        return params

    # --- encoder (whisper stub frontend) ---
    def encode(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        cd = _cdtype(cfg)
        enc_cfg = dataclasses.replace(cfg, family="dense")
        x = _constrain_batch(frames.astype(cd), cfg)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

        def body(h, blk):
            def run(h):
                y, _, _ = _std_block(blk, h, enc_cfg, positions=positions,
                                     compute_dtype=cd)
                return y
            if cfg.remat == "full":
                run = jax.checkpoint(run)
            return run(h), None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # --- training / prefill-style forward (no cache) ---
    def forward(self, params, tokens: jax.Array,
                memory: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
        """tokens: (B, L) -> (logits (B, L, V) fp32, aux loss scalar)."""
        cfg = self.cfg
        cd = _cdtype(cfg)
        b, l = tokens.shape
        x = L.embed(params["embed"], tokens, cd,
                    one_hot=bool(cfg.batch_axes)) * jnp.sqrt(
            jnp.asarray(cfg.d_model, cd))
        x = _constrain_batch(x, cfg)
        positions = jnp.broadcast_to(jnp.arange(l)[None], (b, l))
        if cfg.is_enc_dec and memory is not None:
            memory = memory.astype(cd)

        def body(carry, blk):
            h, aux = carry
            h = _constrain_residual(h, cfg)

            def run(h):
                if cfg.family == "ssm":
                    y, _, a = _ssd_group(blk, h, cfg, compute_dtype=cd)
                elif cfg.family == "hybrid":
                    y, _, a = _hybrid_group(blk, h, cfg, positions=positions,
                                            compute_dtype=cd)
                elif cfg.family == "vlm":
                    y, _, a = _vlm_group(blk, h, cfg, positions=positions,
                                         memory=memory, compute_dtype=cd)
                else:
                    mem = memory if cfg.is_enc_dec else None
                    ed_cfg = (dataclasses.replace(cfg, family="dense")
                              if cfg.is_enc_dec else cfg)
                    blk2 = dict(blk)
                    y, _, a = _std_block(blk2, h, ed_cfg, positions=positions,
                                         memory=mem, window=cfg.attn_window,
                                         compute_dtype=cd)
                return y, a

            if cfg.remat == "full":
                run = jax.checkpoint(run)
            y, a = run(h)
            return (y, aux + a), None

        n_dec = self.n_groups
        dec_blocks = params["blocks"]
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   dec_blocks)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x, cfg.logits_softcap)
        return logits, aux / max(n_dec, 1)

    # --- KV / state caches ---
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16
                   ) -> PyTree:
        cfg = self.cfg

        def kv_cache(length, ring=False):
            out = {"k": jnp.zeros((batch, length, cfg.n_kv_heads,
                                   cfg.head_dim), dtype),
                   "v": jnp.zeros((batch, length, cfg.n_kv_heads,
                                   cfg.head_dim), dtype)}
            if ring:
                out["pos"] = jnp.full((batch, length), -2**30, jnp.int32)
            return out

        def one_group():
            if cfg.family == "ssm":
                return ssm_mod.init_ssd_cache(cfg, batch, dtype)
            if cfg.family == "hybrid":
                out = []
                ring = cfg.attn_window is not None and cfg.attn_window < max_len
                for kind in cfg.block_pattern:
                    if kind == "rec":
                        out.append(rec_mod.init_recurrent_cache(cfg, batch,
                                                                dtype))
                    else:
                        out.append(kv_cache(min(max_len,
                                                cfg.attn_window or max_len),
                                            ring=ring))
                return out
            if cfg.family == "vlm":
                return [kv_cache(max_len) for _ in range(cfg.cross_attn_every)]
            return kv_cache(max_len)

        proto = one_group()
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n_groups,) + x.shape),
            proto)

    # --- single-token decode step ---
    def decode_step(self, params, token: jax.Array, cache: PyTree,
                    pos: jax.Array, memory: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, PyTree]:
        """token: (B, 1); pos: (B, 1) absolute positions."""
        cfg = self.cfg
        cd = _cdtype(cfg)
        x = L.embed(params["embed"], token, cd) * jnp.sqrt(
            jnp.asarray(cfg.d_model, cd))
        x = _constrain_batch(x, cfg)
        if memory is not None:
            memory = memory.astype(cd)

        def body(h, inp):
            blk, cache_g = inp
            if cfg.family == "ssm":
                y, nc, _ = _ssd_group(blk, h, cfg, cache=cache_g,
                                      compute_dtype=cd)
            elif cfg.family == "hybrid":
                y, nc, _ = _hybrid_group(blk, h, cfg, positions=pos,
                                         cache=cache_g, compute_dtype=cd)
            elif cfg.family == "vlm":
                y, nc, _ = _vlm_group(blk, h, cfg, positions=pos,
                                      memory=memory, cache=cache_g,
                                      compute_dtype=cd)
            else:
                mem = memory if cfg.is_enc_dec else None
                ed_cfg = (dataclasses.replace(cfg, family="dense")
                          if cfg.is_enc_dec else cfg)
                y, nc, _ = _std_block(blk, h, ed_cfg, positions=pos,
                                      cache=cache_g, memory=mem,
                                      window=cfg.attn_window,
                                      compute_dtype=cd)
            return y, nc

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x, cfg.logits_softcap)
        return logits, new_cache

    # --- bulk prompt ingestion (single-dispatch prefill) ---
    def prefill(self, params, tokens: jax.Array, cache: PyTree,
                positions: jax.Array, write_mask: jax.Array) -> PyTree:
        """Bulk-write a block of prompt tokens into the decode cache.

        ``tokens``/``positions``/``write_mask``: (steps, batch) time-major.
        Scans :meth:`decode_step` over the leading axis inside one traced
        computation, so a whole prompt chunk lands in the cache in a single
        device dispatch.  ``write_mask[t, b]`` selects, per step and lane,
        whether lane ``b``'s cache advances at step ``t``; masked-off lanes
        keep their cache/state **bit-exactly** (their decode_step output is
        discarded), which is what lets lanes with different prompt lengths
        — and lanes that are mid-decode or empty — ride along as padding
        work without cross-request state pollution.  Per-lane results are
        bit-identical to replaying the same (token, position) sequence
        through :meth:`decode_step` one step at a time.  Logits are never
        materialized.
        """
        def body(c, inp):
            tok, pos, write = inp
            _, c_new = self.decode_step(params, tok[:, None], c,
                                        pos[:, None])
            merged = jax.tree.map(
                lambda n, o: jnp.where(
                    write.reshape((1, -1) + (1,) * (n.ndim - 2)), n, o),
                c_new, c)
            return merged, None
        cache, _ = jax.lax.scan(body, cache,
                                (tokens, positions, write_mask))
        return cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
