"""whisper-large-v3: enc-dec 32L+32L d_model=1280 20H d_ff=5120 vocab=51866 —
conv frontend STUB (precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch="whisper-large-v3", family="audio",
    n_layers=32, n_enc_layers=32, n_dec_layers=32,
    d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866, activation="gelu", enc_len=1500,
    activation_strategy="sp",
    rope_theta=10000.0,
))
