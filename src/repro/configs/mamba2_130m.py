"""mamba2-130m: 24L d_model=768 attn-free, ssm_state=128 — SSD
[arXiv:2405.21060]. Sub-quadratic: runs long_500k."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280, activation="swiglu",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    sub_quadratic=True,
))
