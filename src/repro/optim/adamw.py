"""AdamW with fp32 state over bf16 params (pytree-functional, shardable).

State tensors inherit the parameter PartitionSpecs, so the 2D FSDP x TP
layout automatically shards first/second moments (ZeRO-style).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jax.Array


def adamw(lr: Callable[[jax.Array], jax.Array] | float, b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1):
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params),
                          count=jnp.zeros((), jnp.int32))

    def update(grads: PyTree, state: AdamWState, params: PyTree
               ) -> Tuple[PyTree, AdamWState]:
        count = state.count + 1
        t = count.astype(jnp.float32)
        step_lr = lr_fn(count)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** t)
            vhat = v / (1 - b2 ** t)
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and p.ndim >= 2:   # decay matrices only
                delta = delta + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - step_lr * delta).astype(p.dtype)
            return new_p, m, v

        flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda x: x[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda x: x[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda x: x[2], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(new_mu, new_nu, count)

    return init, update
