"""Beyond-paper distributed-config tuning: space + objective plumbing."""
from repro.core.distributed_tuning import distributed_space


def test_space_enumerable():
    sp = distributed_space("granite-34b", "train_4k", is_moe=False,
                           is_train=True)
    cfgs = sp.enumerate_valid()
    assert len(cfgs) == 2 * 4 * 2 * 1
    sp2 = distributed_space("qwen3-moe-30b-a3b", "train_4k", is_moe=True)
    assert len(sp2.enumerate_valid()) == 2 * 4 * 2 * 3


def test_serving_space_has_no_train_knobs():
    sp = distributed_space("gemma-2b", "decode_32k", is_train=False)
    for cfg in sp.enumerate_valid():
        assert cfg["micro_steps"] == 1 and cfg["remat"] == 1
