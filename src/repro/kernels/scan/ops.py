"""Tuned scan entry points (prefix sum + linear recurrence).

Every call resolves its configuration through the default
:class:`repro.tuning.TunerSession` — DB hit (offline-tuned), else the
memoized analytical model (online, zero evaluations) — the paper's
deployment flow. Shapes are normalized to (batch, n) rows; callers with
higher-rank arrays flatten leading dims.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.space import Workload, fit_block, scan_space
from repro.kernels.scan.kernel import scan_add_pallas, scan_linrec_pallas
from repro.kernels.scan.ref import scan_add_ref, scan_linrec_assoc_ref
from repro.tuning import default_session, plan_execution, tuned_kernel


def _normalize(cfg, wl, dims=None):
    """Fit tuned knobs to the (batch, n) launch geometry; project to the
    kwargs the scan kernels accept (``in_register`` is a space-only knob)."""
    return {
        "rows_per_program": fit_block(cfg.get("rows_per_program", 8),
                                      max(wl.batch, 1)),
        "tile_n": fit_block(cfg.get("tile_n", wl.n), wl.n),
        "radix": cfg.get("radix", 2),
        "unroll": cfg.get("unroll", 1),
    }


@tuned_kernel("scan", space=scan_space, pallas=scan_add_pallas,
              reference=scan_add_ref, normalize=_normalize,
              variants=("ks", "lf"))
def prefix_sum(x: jax.Array, variant: str = "ks",
               config: Optional[dict] = None,
               interpret: Optional[bool] = None,
               use_pallas: Optional[bool] = None) -> jax.Array:
    """Inclusive row-wise prefix sum with tuned blocking."""
    batch, n = x.shape
    use_pallas, interpret = plan_execution(use_pallas, interpret)
    if not use_pallas:
        return scan_add_ref(x)
    cfg = default_session().resolve(
        Workload(op="scan", n=n, batch=batch, variant=variant), config=config)
    return scan_add_pallas(x, interpret=interpret, **cfg)


@tuned_kernel("scan", space=scan_space, pallas=scan_linrec_pallas,
              reference=scan_linrec_assoc_ref, normalize=_normalize,
              variants=("ks", "lf"))
def linear_recurrence(a: jax.Array, b: jax.Array, variant: str = "ks",
                      config: Optional[dict] = None,
                      interpret: Optional[bool] = None,
                      use_pallas: Optional[bool] = None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t row-wise with tuned blocking.

    The workhorse behind RG-LRU layers and SSD inter-chunk state scans.
    """
    batch, n = a.shape
    use_pallas, interpret = plan_execution(use_pallas, interpret)
    if not use_pallas:
        return scan_linrec_assoc_ref(a, b)
    cfg = default_session().resolve(
        Workload(op="scan", n=n, batch=batch, variant=variant), config=config)
    return scan_linrec_pallas(a, b, interpret=interpret, **cfg)
