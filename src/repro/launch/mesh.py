"""Production mesh construction.

Kept as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any device query).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ("data","model") single pod; (2,16,16) ("pod","data","model")
    for the 2-pod = 512-chip deployment."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over the host's real devices (tests / examples)."""
    n = len(jax.devices())
    data = max(n // model_axis, 1)
    return jax.make_mesh((data, model_axis), ("data", "model"))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
