"""Serving engine: batched prefill + continuous-batching decode.

A slot-based scheduler: the engine owns `max_batch` slots, each slot a
request's KV/state cache lane. New requests prefill into a free slot (the
prefill forward recomputes the prompt; for cache-full archs the prompt K/V
are inserted by replaying tokens through decode for simplicity at host
scale — production TPU path would bulk-write prefill K/V); decode steps run
all active slots in lockstep (one jitted decode_step per token).

Online-tuning hooks (see ``repro.tuning.online``): the engine accepts an
injectable ``step_timer`` (any zero-arg callable returning monotonic
seconds — a fake clock in tests), reports every timed decode step to
registered listeners as a :class:`StepRecord`, and applies an optional
override-provider's config fragments around each step so an
:class:`~repro.tuning.online.OnlineTuner` can run shadowed trials against
live traffic. With no listeners registered the loop takes the exact
pre-hook path — an untimed engine pays nothing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.tuning.overrides import overrides as _tuning_overrides

PyTree = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """One timed decode step, as reported to step listeners."""

    index: int          # monotonically increasing decode-step counter
    duration_s: float   # wall-clock (or fake-clock) duration of the step
    active: int         # slots that were decoding during the step


class ServeEngine:
    def __init__(self, model: Model, params: PyTree, max_batch: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0,
                 step_timer: Optional[Callable[[], float]] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        self.cache = model.init_cache(max_batch, max_len, dtype=jnp.float32)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(model.decode_step)
        # decode is jitted, so kernel configs resolved at TRACE time are
        # baked into the compiled executable — an overrides() frame around
        # later calls cannot reach it. Each distinct override fragment
        # therefore gets its own jitted variant, re-traced (and its config
        # re-resolved) under that frame; revisits are cache hits.
        self._decode_variants: Dict[object, Callable] = {None: self._decode}
        self._active_overrides: Optional[Dict] = None
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        # -- step hooks (timing is only paid when a listener is registered)
        self.step_timer: Callable[[], float] = step_timer or time.perf_counter
        self._step_listeners: List[Callable[[StepRecord], None]] = []
        self._override_provider: Optional[
            Callable[[], Optional[Mapping[str, Mapping[str, int]]]]] = None
        self._step_index = 0

    def add_step_listener(self, fn: Callable[[StepRecord], None]) -> None:
        """Register a callback invoked after every timed decode step."""
        self._step_listeners.append(fn)

    def set_override_provider(
            self, fn: Optional[
                Callable[[], Optional[Mapping[str, Mapping[str, int]]]]],
    ) -> None:
        """Install a provider of per-op config overrides, consulted before
        each step and applied (via the thread-local override stack) around
        it — how an online tuner's active trial reaches the kernels."""
        self._override_provider = fn

    # -- public API --
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            # an empty prompt has no last token to decode from: _admit would
            # set slot_pos = -1 and _decode_step would IndexError on
            # prompt[-1]; reject at the door instead of crashing the batch
            raise ValueError("empty prompt: need at least one token")
        rid = len(self.queue) + len(self.completed) + sum(
            r is not None for r in self.slot_req)
        self.queue.append(Request(rid, prompt, max_new_tokens))
        return rid

    def run(self, max_steps: int = 1000) -> List[Request]:
        """Serve until the queue drains (or ``max_steps``).

        Returns completed requests in **submission order** (ascending
        ``rid``) — a stable contract that deterministic consumers (trace
        replay, batched clients zipping prompts with results) rely on.
        ``self.completed`` retains completion order for schedulers that
        care about finishing sequence.
        """
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            ov = self._override_provider() if self._override_provider else None
            if ov != self._active_overrides:
                self._select_decode_variant(ov)
            ctx = _tuning_overrides(**ov) if ov else contextlib.nullcontext()
            with ctx:
                self._admit()
                active = sum(r is not None for r in self.slot_req)
                if self._step_listeners and active:
                    t0 = self.step_timer()
                    self._decode_step()
                    record = StepRecord(self._step_index,
                                        self.step_timer() - t0, active)
                    for listener in self._step_listeners:
                        listener(record)
                else:
                    self._decode_step()
            self._step_index += 1
            steps += 1
        return sorted(self.completed, key=lambda r: r.rid)

    # -- internals --
    def _select_decode_variant(self, ov: Optional[Dict]) -> None:
        """Switch to (or build) the jitted decode traced under ``ov``.

        First use of a config pays one re-trace/compile — landing inside
        that trial's first timed step, which the online tuner's
        first-sample baseline discard absorbs; returning to a previously
        seen config (the incumbent after a rollback) is a dict hit.
        """
        self._active_overrides = None if ov is None \
            else {op: dict(frag) for op, frag in ov.items()}
        key = None if ov is None else tuple(
            (op, tuple(sorted(frag.items())))
            for op, frag in sorted(ov.items()))
        fn = self._decode_variants.get(key)
        if fn is None:
            fn = jax.jit(self.model.decode_step)
            self._decode_variants[key] = fn
        self._decode = fn

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None:
                continue
            while self.queue:
                req = self.queue.pop(0)
                if np.asarray(req.prompt).size == 0:
                    # hand-built Request bypassing submit(): complete it
                    # empty rather than poisoning the whole batch with
                    # slot_pos = -1 and an IndexError on prompt[-1]
                    req.done = True
                    self.completed.append(req)
                    continue
                self.slot_req[slot] = req
                # replay prompt through decode to build this slot's cache
                for t, tok in enumerate(req.prompt[:-1]):
                    self._step_slot(slot, int(tok), t)
                self.slot_pos[slot] = len(req.prompt) - 1
                break

    def _step_slot(self, slot: int, token: int, pos: int) -> int:
        """Single-slot step executed via the batched decode fn (other slots
        run their current token as padding work — lockstep batching)."""
        tokens = np.zeros((self.max_batch, 1), np.int32)
        poss = np.maximum(self.slot_pos[:, None], 0).astype(np.int32)
        tokens[slot, 0] = token
        poss[slot, 0] = pos
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.asarray(poss))
        return int(np.argmax(np.asarray(logits)[slot]))

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits_row))
        z = logits_row / self.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self.rng.choice(len(p), p=p))

    def _decode_step(self) -> None:
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tokens = np.zeros((self.max_batch, 1), np.int32)
        poss = np.maximum(self.slot_pos[:, None], 0).astype(np.int32)
        for s in active:
            req = self.slot_req[s]
            last = (req.output[-1] if req.output
                    else int(req.prompt[-1]))
            tokens[s, 0] = last
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.asarray(poss))
        logits = np.asarray(logits)
        for s in active:
            req = self.slot_req[s]
            nxt = self._sample(logits[s])
            req.output.append(nxt)
            self.slot_pos[s] += 1
            if (len(req.output) >= req.max_new_tokens
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                self.completed.append(req)
                self.slot_req[s] = None
