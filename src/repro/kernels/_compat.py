"""Pallas API compatibility across jax versions.

Newer jax exposes ``pltpu.CompilerParams``; older releases call the same
dataclass ``pltpu.TPUCompilerParams``. Kernels import the name from here so
they compile on both.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
