from repro.optim.adamw import adamw, AdamWState
from repro.optim.adafactor import adafactor, AdafactorState
from repro.optim.schedule import warmup_cosine, constant
from repro.optim.clip import clip_by_global_norm, global_norm
