"""Mamba-2 (SSD) block — the flagship consumer of the tuned scan/SSD kernels."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd.ops import ssd as ssd_op
from repro.models.layers import causal_conv1d, dense, init_dense, rms_norm


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def init_ssd_block(key, cfg: ModelConfig, dtype) -> Dict:
    d_inner, n_heads, s = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    # fused input projection: [x (d_inner), z (d_inner), B (s), C (s), dt (H)]
    d_proj = 2 * d_inner + 2 * s + n_heads
    return {
        "in_proj": init_dense(ks[0], d, d_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width,
                                             d_inner + 2 * s), jnp.float32)
                   * (1.0 / math.sqrt(cfg.conv_width))).astype(dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "out_proj": init_dense(ks[2], d_inner, d, dtype),
    }


def ssd_block(p: Dict, x: jax.Array, cfg: ModelConfig, *,
              cache: Optional[Dict] = None, compute_dtype=jnp.bfloat16
              ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, L, D). cache (decode): {"conv": (B,K-1,chan), "state": (B,H,S,P)}."""
    bsz, L, _ = x.shape
    d_inner, n_heads, s = _dims(cfg)
    P = cfg.ssm_head_dim

    proj = dense(p["in_proj"], x, compute_dtype)
    xz, z, bc, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + 2 * s], axis=-1)
    conv_in = jnp.concatenate([xz, bc], axis=-1)
    conv_out, conv_cache = causal_conv1d(
        conv_in, p["conv_w"].astype(compute_dtype),
        cache=None if cache is None else cache["conv"])
    conv_out = jax.nn.silu(conv_out)
    xs, b_in, c_in = jnp.split(conv_out, [d_inner, d_inner + s], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])          # (B, L, H)
    a = jnp.exp(-jnp.exp(p["a_log"])[None, None, :] * dt)        # decay in (0,1)
    xh = xs.reshape(bsz, L, n_heads, P)

    if cache is None or L > 1:
        y = ssd_op(xh.astype(jnp.float32), a, b_in.astype(jnp.float32),
                   c_in.astype(jnp.float32),
                   use_pallas=cfg.use_pallas or None)
        new_state = None  # prefill state capture handled by decode-from-scratch
    else:
        # O(1) decode step: h = a h + b x^T ; y = c . h
        h = cache["state"]
        x_t = xh[:, 0]                                           # (B, H, P)
        a_t = a[:, 0]                                            # (B, H)
        b_t = b_in[:, 0].astype(jnp.float32)                     # (B, S)
        c_t = c_in[:, 0].astype(jnp.float32)
        h = (a_t[..., None, None] * h
             + jnp.einsum("bs,bhp->bhsp", b_t, x_t.astype(jnp.float32)))
        y = jnp.einsum("bs,bhsp->bhp", c_t, h)[:, None]          # (B,1,H,P)
        new_state = h

    y = y.reshape(bsz, L, d_inner).astype(compute_dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = dense(p["out_proj"], y, compute_dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": conv_cache.astype(cache["conv"].dtype),
                     "state": new_state if new_state is not None
                     else cache["state"]}
    return out, new_cache


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_inner, n_heads, s = _dims(cfg)
    chan = d_inner + 2 * s
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, chan), dtype),
        "state": jnp.zeros((batch, n_heads, s, cfg.ssm_head_dim), jnp.float32),
    }
