"""RG-LRU via the tuned linear-recurrence scan kernel.

The gate computation lives in the model layer; this op runs the recurrence
h_t = a_t h_{t-1} + sqrt(1-a_t^2) u_t by flattening (B, L, D) into
(B*D, L) rows for the scan kernel — the direct integration of the paper's
tuned scan into RecurrentGemma. The rglru workload resolves through the
TunerSession under its own op name (the space is the linrec-pruned scan
space), builds its StagePlan, and dispatches fused or multi-pass through
the shared blocks driver, so per-op DB entries and ``overrides(rglru=...)``
apply.

rglru is a gate→linrec *chain*: the tuned ``fuse`` knob decides whether
the elementwise gate runs inside the scan kernel's first stage
(``fuse=1`` — one launch, one fewer HBM roundtrip; the plan's
``xla_passes`` drops to 0) or as a separate XLA pass at the historical
op boundary (``fuse=0``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.space import Workload, linrec_space
from repro.kernels.blocks import driver
from repro.kernels.blocks.plan import plan_for
from repro.kernels.scan.kernel import scan_linrec_pallas
from repro.kernels.scan.ops import _normalize as _normalize_scan
from repro.kernels.scan.ops import linear_recurrence
from repro.kernels.scan.ref import scan_linrec_assoc_ref
from repro.tuning import default_session, plan_execution, tuned_kernel


@tuned_kernel("rglru", space=linrec_space, pallas=scan_linrec_pallas,
              reference=scan_linrec_assoc_ref, normalize=_normalize_scan)
def rglru(a: jax.Array, u: jax.Array, config: Optional[dict] = None,
          interpret: Optional[bool] = None,
          use_pallas: Optional[bool] = None) -> jax.Array:
    B, L, D = a.shape
    run_pallas, interpret_eff = plan_execution(use_pallas, interpret)
    if not run_pallas:
        b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * u
        a_rows = jnp.transpose(a, (0, 2, 1)).reshape(B * D, L)
        b_rows = jnp.transpose(b, (0, 2, 1)).reshape(B * D, L)
        h = linear_recurrence(a_rows, b_rows, use_pallas=False)
        return jnp.transpose(h.reshape(B, D, L), (0, 2, 1))

    wl = Workload(op="rglru", n=L, batch=B * D)
    cfg = default_session().resolve(wl, config=config)
    plan = plan_for(wl, cfg)
    fused = bool(cfg.get("fuse", 0))
    a_rows = jnp.transpose(a, (0, 2, 1)).reshape(B * D, L)
    if fused:
        # fused chain: the second operand is the raw input u; the kernel
        # computes the gate in-tile (gate=True), saving the XLA gate pass
        b_rows = jnp.transpose(u, (0, 2, 1)).reshape(B * D, L)
    else:
        b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * u
        b_rows = jnp.transpose(b, (0, 2, 1)).reshape(B * D, L)
    if plan.kind == "multipass":
        h = driver.multipass_linrec(a_rows, b_rows, plan, gate=fused,
                                    interpret=interpret_eff)
    else:
        h = driver.launch(scan_linrec_pallas, plan.launches[0],
                          a_rows, b_rows, rows_per_program=plan.rows,
                          tile_n=plan.tile_n, stages=plan.stages,
                          gate=fused, interpret=interpret_eff)
    return jnp.transpose(h.reshape(B, D, L), (0, 2, 1))
