"""Oracles for the complex FFT kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fft_ref(x: jax.Array) -> jax.Array:
    """Row-wise complex DFT via jnp.fft (x: (..., n) complex)."""
    return jnp.fft.fft(x, axis=-1)


def stockham_jnp(x: jax.Array, radix: int = 2) -> jax.Array:
    """Self-sorting Stockham DIF in pure jnp complex arithmetic.

    Validates the staged formulation the Pallas kernel mirrors on split
    re/im planes.
    """
    rows, n = x.shape
    buf = x
    n_cur, s = n, 1
    while n_cur > 1:
        rr = min(radix, n_cur)
        m = n_cur // rr
        v = buf.reshape(rows, n_cur, s)
        parts = [v[:, k * m:(k + 1) * m, :] for k in range(rr)]
        p = jnp.arange(m).reshape(1, m, 1)
        outs = []
        for j in range(rr):
            t = sum(parts[k] * jnp.exp(-2j * jnp.pi * j * k / rr)
                    for k in range(rr))
            t = t * jnp.exp(-2j * jnp.pi * j * p / n_cur)
            outs.append(t)
        buf = jnp.stack(outs, axis=2).reshape(rows, n)
        n_cur, s = m, s * rr
    return buf


def four_step_ref(x: jax.Array, n1: int) -> jax.Array:
    """Four-step (Bailey) large FFT oracle: N = n1 * n2.

    1. view as (n2, n1) in row-major (index = i2*n1 + i1... we use the
       transpose convention below), FFT columns, twiddle, FFT rows,
       transpose.
    """
    rows, n = x.shape
    n2 = n // n1
    v = x.reshape(rows, n2, n1)
    v = jnp.fft.fft(v, axis=1)                       # length-n2 FFTs
    k2 = jnp.arange(n2).reshape(1, n2, 1)
    k1 = jnp.arange(n1).reshape(1, 1, n1)
    v = v * jnp.exp(-2j * jnp.pi * k1 * k2 / n)      # twiddle
    v = jnp.fft.fft(v, axis=2)                       # length-n1 FFTs
    v = jnp.transpose(v, (0, 2, 1))                  # self-sort
    return v.reshape(rows, n)
