"""Pure-jnp oracles for the scan kernel family.

The scan primitive operates row-wise on (batch, n) arrays. Two monoids:
  - "add": ordinary prefix sum (the paper's scan primitive);
  - "linrec": first-order linear recurrence h_t = a_t * h_{t-1} + b_t over
    element pairs (a, b) — the building block for RG-LRU and SSD inter-chunk
    state propagation. Monoid: (a2, b2) o (a1, b1) = (a1*a2, a2*b1 + b2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_add_ref(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along the last axis."""
    return jnp.cumsum(x, axis=-1)


def scan_add_exclusive_ref(x: jax.Array) -> jax.Array:
    inc = jnp.cumsum(x, axis=-1)
    return jnp.concatenate([jnp.zeros_like(inc[..., :1]), inc[..., :-1]], axis=-1)


def scan_linrec_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t, h_0 = b_0 (i.e. h_{-1} = 0), along last axis.

    Sequential lax.scan ground truth (exact order of operations).
    """

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    aT = jnp.moveaxis(a, -1, 0)
    bT = jnp.moveaxis(b, -1, 0)
    _, hT = jax.lax.scan(step, jnp.zeros_like(aT[0]), (aT, bT))
    return jnp.moveaxis(hT, 0, -1)


def scan_linrec_assoc_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Same recurrence via jax.lax.associative_scan (parallel ground truth)."""

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    a_out, b_out = jax.lax.associative_scan(combine, (a, b), axis=-1)
    return b_out


def scan_max_ref(x: jax.Array) -> jax.Array:
    return jax.lax.associative_scan(jnp.maximum, x, axis=-1)
