# Pallas TPU kernels for the compute hot-spots the paper tunes (scan,
# tridiagonal solvers, FFT) plus the framework's own hot kernels (SSD,
# RG-LRU, flash attention, matmul). Each subpackage: kernel.py
# (pl.pallas_call + BlockSpec), ops.py (the public entry point, declared
# with @repro.tuning.tuned_kernel and resolving its config through the
# TunerSession), ref.py (pure-jnp oracle).
