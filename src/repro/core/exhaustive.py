"""Exhaustive and random searches (the paper's ground truth + sanity baseline)."""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.bayesian import TuneResult
from repro.core.objective import Objective, PENALTY_TIME
from repro.core.space import Config, SearchSpace


class ExhaustiveSearch:
    """Evaluates every valid configuration. Guarantees the optimum; used to
    compute the paper's Phi metric denominators."""

    name = "exhaustive"

    def tune(self, space: SearchSpace, objective: Objective) -> TuneResult:
        history: List[Tuple[Config, float]] = []
        best_cfg: Optional[Config] = None
        best_t = float("inf")
        for cfg in space.enumerate_valid():
            m = objective(space, cfg)
            t = m.time_s if m.valid else PENALTY_TIME
            history.append((cfg, t))
            if t < best_t:
                best_cfg, best_t = cfg, t
        if best_cfg is None:
            raise ValueError(f"empty search space for {space.workload.key}")
        return TuneResult(best_cfg, best_t, len(history), history, "exhausted")


class RandomSearch:
    """Uniform random sampling without replacement — the bar any smarter
    search must beat (cf. the paper's citation of [35])."""

    name = "random"

    def __init__(self, max_evals: int = 16, seed: int = 0):
        self.max_evals = max_evals
        self.seed = seed

    def tune(self, space: SearchSpace, objective: Objective) -> TuneResult:
        rng = np.random.default_rng(self.seed)
        candidates = space.enumerate_valid()
        if not candidates:
            raise ValueError(f"empty search space for {space.workload.key}")
        order = rng.permutation(len(candidates))[: self.max_evals]
        history: List[Tuple[Config, float]] = []
        best_cfg, best_t = None, float("inf")
        for idx in order:
            cfg = candidates[int(idx)]
            m = objective(space, cfg)
            t = m.time_s if m.valid else PENALTY_TIME
            history.append((cfg, t))
            if t < best_t:
                best_cfg, best_t = cfg, t
        return TuneResult(best_cfg, best_t, len(history), history, "max_evals")
