"""Benchmark harness — one section per paper table/figure.

Prints ``name,...`` CSV rows:
  fig5/fig6/fig7/fig8 — tridiag / scan / FFT / large-FFT throughput per
      tuning methodology (+ `-host` rows: genuine wall-clock on this host);
  table2              — average performance + Phi per (op, methodology);
  fig4 / fig4d        — BO candidate-evaluation counts (+ control vs random);
  roofline            — per (arch x shape) three-term roofline summary;
  resolve             — TunerSession online hot-path vs seed miss path.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: prefix_ops,convergence,roofline,resolve")
    ap.add_argument("--no-host-wallclock", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def emit(row: str) -> None:
        print(row, flush=True)

    t0 = time.time()
    emit("table,op,variant,N,method,metric,value,extra")
    if only is None or "prefix_ops" in only:
        from benchmarks.bench_prefix_ops import run as run_ops
        run_ops(emit, host_wallclock=not args.no_host_wallclock)
    if only is None or "convergence" in only:
        from benchmarks.bench_convergence import run as run_conv
        run_conv(emit)
    if only is None or "roofline" in only:
        from benchmarks.bench_roofline import run as run_roof
        run_roof(emit)
    if only is None or "resolve" in only:
        from benchmarks.bench_resolve import run as run_resolve
        run_resolve(emit)
    print(f"# benchmarks done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
