"""Large-problem multi-pass tuning (paper §IV-C)."""

from repro.core import Workload, build_space, BayesianTuner, CachedObjective
from repro.core.multikernel import (MultiPassObjective, analytical_multipass,
                                    max_resident_tile, num_passes)


def test_num_passes():
    assert num_passes(2**20, 2**10) == 2
    assert num_passes(2**23, 2**10) == 3     # paper: N >= 2^19 -> 3 kernels
    assert num_passes(2**10, 2**10) == 1


def test_analytical_multipass_minimizes_m():
    wl = Workload(op="large_fft", n=2**20, batch=64, variant="stockham")
    plan = analytical_multipass(wl)
    assert plan.m == num_passes(wl.n, max_resident_tile(wl))
    assert len(plan.passes) == plan.m
    assert all(p["tile_n"] == plan.tile_n for p in plan.passes)


def test_multipass_objective_valid():
    wl = Workload(op="large_fft", n=2**20, batch=64, variant="stockham")
    space = build_space(wl)
    obj = MultiPassObjective()
    cfg = space.enumerate_valid()[0]
    m = obj(space, cfg)
    assert m.valid and m.time_s > 0
    assert m.meta["m"] >= 1


def test_bo_on_multipass_space():
    wl = Workload(op="large_fft", n=2**20, batch=64, variant="stockham")
    space = build_space(wl)
    res = BayesianTuner(seed=0, max_evals=24).tune(
        space, CachedObjective(MultiPassObjective()))
    assert space.is_valid(res.best_config)
