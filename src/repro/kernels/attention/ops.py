"""Tuned attention entry point with GQA + decode handling."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import Workload, get_config
from repro.kernels.attention.kernel import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              config: Optional[dict] = None,
              interpret: Optional[bool] = None,
              use_pallas: Optional[bool] = None) -> jax.Array:
    """Multi-head attention core on flattened (B*H, L, D) tensors.

    GQA callers repeat KV heads before the call. Decode (Lq == 1) always
    takes the XLA path — it is a GEMV-shaped, memory-bound op where flash
    tiling has nothing to add.
    """
    BH, lq, d = q.shape
    lk = k.shape[1]
    if use_pallas is None:
        use_pallas = ((not _on_cpu()) or bool(interpret)) and lq > 1
    if not use_pallas or lq == 1:
        return attention_ref(q, k, v, causal=causal, window=window)
    interpret = _on_cpu() if interpret is None else interpret
    cfg = config or get_config(Workload(op="attention", n=lk, batch=BH,
                                        variant="flash"))
    bq = min(cfg.get("block_q", 256), lq)
    while lq % bq:
        bq //= 2
    bk = min(cfg.get("block_k", 256), lk)
    while lk % bk:
        bk //= 2
    return flash_attention_pallas(q, k, v, block_q=max(bq, 1),
                                  block_k=max(bk, 1), causal=causal,
                                  window=window, interpret=interpret)
