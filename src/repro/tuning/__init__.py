"""repro.tuning — the public API for every tuned kernel.

Offline -> online lifecycle (see docs/tuning.md):

    session = TunerSession(db_path="artifacts/tuning_db.json")
    session.tune(wl, method="bayesian")       # offline: populate the DB
    cfg = session.resolve(wl)                 # online: cached, normalized

    with overrides(scan={"radix": 4}):        # scoped experiments
        prefix_sum(x)

Kernel families declare themselves once via ``@tuned_kernel`` (space
builder, pallas impl, reference impl, config normalizer); the session is
the only component that turns a Workload into launch kwargs.

Module-level ``resolve``/``tune``/``suggest`` delegate to the process-wide
default session.
"""
from __future__ import annotations

from typing import Mapping, Optional

from repro.core.bayesian import TuneResult
from repro.core.policy import (Policy, PolicyObjective, get_policy,
                               pareto_front, policies, policy_scalar_cols)
from repro.core.space import (Config, Workload, build_space, fit_block,
                              normalize_config)
from repro.tuning.db import DEFAULT_DB_PATH, SCHEMA_VERSION, TuningDB
from repro.tuning.dispatch import on_cpu, plan_execution
from repro.tuning.overrides import active_overrides, overrides, overrides_active
from repro.tuning.registry import (KernelSpec, get_kernel, normalizer_for,
                                   registered_kernels, tuned_kernel)
from repro.tuning.session import (TunerSession, default_session, get_strategy,
                                  register_strategy, set_default_session,
                                  strategies)
from repro.tuning.sweep import (SweepJournal, SweepResult, config_key,
                                journal_path, prune_candidates, run_sweep)


# The online-tuning stack (repro.tuning.online) stays a lazy import, like
# the ml stack: it pulls in the sweep journal + analytical ranking, and
# the serve engine imports this package on every startup. PEP 562 keeps
# `from repro.tuning import OnlineTuner` working without the eager cost.
_ONLINE_EXPORTS = frozenset((
    "OnlineTuner", "OnlineWallClockObjective", "ReplayTrace", "StepTimer",
    "TraceRecorder", "aggregate_fleet", "attach", "fleet_prior",
    "measurements_to_incumbent", "online_search", "promote_fleet_winner",
    "replay", "replay_candidates", "warm_tuner"))


def __getattr__(name: str):
    if name in _ONLINE_EXPORTS:
        from repro.tuning import online
        return getattr(online, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def resolve(wl: Workload, *, config: Optional[Mapping[str, int]] = None,
            dims: Optional[Mapping[str, int]] = None) -> Config:
    """Resolve a launch-ready config through the default session."""
    return default_session().resolve(wl, config=config, dims=dims)


def tune(wl: Workload, method: str = "bayesian", **kw) -> TuneResult:
    """Offline-tune through the default session (persists the winner)."""
    return default_session().tune(wl, method=method, **kw)


def suggest(wl: Workload) -> Config:
    """Zero-evaluation analytical suggestion via the default session."""
    return default_session().suggest(wl)


__all__ = [
    "Config", "DEFAULT_DB_PATH", "KernelSpec", "OnlineTuner", "Policy",
    "PolicyObjective",
    "OnlineWallClockObjective", "ReplayTrace", "SCHEMA_VERSION", "StepTimer",
    "SweepJournal", "SweepResult", "TraceRecorder", "TuneResult",
    "TunerSession", "TuningDB", "Workload", "active_overrides", "attach",
    "build_space", "config_key", "default_session", "fit_block", "get_kernel",
    "get_policy", "get_strategy", "journal_path", "normalize_config",
    "normalizer_for", "on_cpu", "online_search", "overrides",
    "overrides_active", "pareto_front", "plan_execution", "policies",
    "policy_scalar_cols", "prune_candidates",
    "register_strategy", "registered_kernels", "replay",
    "replay_candidates", "resolve", "run_sweep", "set_default_session",
    "strategies", "suggest", "tune", "tuned_kernel",
]
