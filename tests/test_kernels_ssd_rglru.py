"""SSD (Mamba-2) and RG-LRU kernels vs sequential oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rglru.ops import rglru
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_ref

KEY = jax.random.PRNGKey(0)


def _ssd_inputs(B=2, L=256, H=2, P=16, S=8):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, L, H, P))
    a = jax.random.uniform(ks[1], (B, L, H), minval=0.85, maxval=0.999)
    b = jax.random.normal(ks[2], (B, L, S)) * 0.3
    c = jax.random.normal(ks[3], (B, L, S)) * 0.3
    return x, a, b, c


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_ssd_chunked_ref_matches_sequential(chunk):
    x, a, b, c = _ssd_inputs()
    ref = ssd_ref(x, a, b, c)
    got = ssd_chunked_ref(x, a, b, c, chunk=chunk)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [64, 128])
def test_ssd_pallas_pipeline(chunk):
    x, a, b, c = _ssd_inputs()
    ref = ssd_ref(x, a, b, c)
    got = ssd(x, a, b, c, config={"tile_n": chunk}, interpret=True)
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)


def test_ssd_small_decay_no_nan_grads():
    x, a, b, c = _ssd_inputs()
    a = a * 0.01      # strong decay: exercises the masked-exp stability fix
    def loss(x):
        return jnp.sum(ssd_chunked_ref(x, a, b, c, chunk=64) ** 2)
    g = jax.grad(loss)(x)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_rglru_matches_ref():
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (2, 128, 16), minval=0.8, maxval=0.99)
    u = jax.random.normal(ks[1], (2, 128, 16))
    ref = rglru_ref(a, u)
    got = rglru(a, u, config={"rows_per_program": 8, "tile_n": 128,
                              "radix": 4, "unroll": 1}, interpret=True)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
