"""Flash-attention Pallas kernel + XLA chunked path vs reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
from repro.kernels.attention.ops import attention
from repro.kernels.attention.ref import attention_ref

KEY = jax.random.PRNGKey(0)


def _qkv(BH, Lq, Lk, D):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (BH, Lq, D), jnp.float32),
            jax.random.normal(ks[1], (BH, Lk, D), jnp.float32),
            jax.random.normal(ks[2], (BH, Lk, D), jnp.float32))


@pytest.mark.parametrize("bq,bk", [(128, 128), (64, 256), (256, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_blocks_sweep(bq, bk, causal):
    q, k, v = _qkv(2, 256, 256, 64)
    ref = attention_ref(q, k, v, causal=causal)
    got = attention(q, k, v, causal=causal,
                    config={"block_q": bq, "block_k": bk}, interpret=True)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)


def test_flash_local_window():
    q, k, v = _qkv(2, 512, 512, 64)
    ref = attention_ref(q, k, v, causal=True, window=128)
    got = attention(q, k, v, causal=True, window=128,
                    config={"block_q": 128, "block_k": 128}, interpret=True)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)


def test_decode_uses_ref_path():
    q, k, v = _qkv(4, 1, 300, 64)
    ref = attention_ref(q, k, v, causal=True)
    got = attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_chunked_xla_attention_exact(monkeypatch):
    B, L, H, D = 2, 2048, 4, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, L, H, D))
    k = jax.random.normal(ks[1], (B, L, H, D))
    v = jax.random.normal(ks[2], (B, L, H, D))
    ref = A._attention_core(q, k, v, causal=True, window=None,
                            compute_dtype=jnp.float32, model_axis=0,
                            q_offset=0)
    monkeypatch.setattr(A, "_SCORE_ELEMS_LIMIT", 1024 * 1024)
    got = A._attention_4d(q, k, v, causal=True, window=None,
                          compute_dtype=jnp.float32)
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-6)
