"""ML-based tuning via Bayesian optimization (paper §IV-B).

Workflow (identical to the paper's GPTune-driven loop):
  1. bootstrap: randomly sample `n_init` configurations, evaluate them;
  2. fit the surrogate model on (encoded config -> log time);
  3. maximize the Expected Improvement acquisition over the *remaining*
     valid configs (spaces are enumerable, so acquisition optimization is
     exact — the paper's spaces are likewise small/discrete);
  4. evaluate the winner, append to the dataset, repeat;
  5. stop on the sliding-window criterion: no improvement within the last
     `patience` evaluations (paper: 5), or when the space is exhausted, or
     at `max_evals`.

Surrogate: a Gaussian process with an RBF kernel over the log2-normalized
parameter encoding ("LCM-lite" — GPTune's Linear Coregionalization Model
reduces to a single-task GP when tuning one task at a time, which is how the
paper uses it per (algorithm, N)). Pure numpy; no external deps.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.objective import Objective, PENALTY_TIME
from repro.core.space import Config, SearchSpace


@dataclasses.dataclass
class GP:
    """RBF-kernel Gaussian process regression (zero mean on standardized y)."""

    lengthscale: float = 0.35
    signal: float = 1.0
    noise: float = 1e-4

    x: Optional[np.ndarray] = None
    y_mean: float = 0.0
    y_std: float = 1.0
    alpha: Optional[np.ndarray] = None
    chol: Optional[np.ndarray] = None

    def _k(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return self.signal * np.exp(-0.5 * d2 / self.lengthscale**2)

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GP":
        self.x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self.y_mean = float(y.mean())
        self.y_std = float(y.std()) or 1.0
        yn = (y - self.y_mean) / self.y_std
        k = self._k(self.x, self.x) + self.noise * np.eye(len(y))
        self.chol = np.linalg.cholesky(k)
        self.alpha = np.linalg.solve(self.chol.T, np.linalg.solve(self.chol, yn))
        return self

    def predict(self, xq: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        xq = np.asarray(xq, dtype=np.float64)
        ks = self._k(xq, self.x)
        mu = ks @ self.alpha
        v = np.linalg.solve(self.chol, ks.T)
        var = np.clip(self.signal - (v**2).sum(0), 1e-12, None)
        return mu * self.y_std + self.y_mean, np.sqrt(var) * self.y_std


# elementwise math.erf: exact to double precision and keeps this module's
# "pure numpy; no external deps" contract (scipy is only a transitive
# extra of jax and absent from requirements-ci.txt)
_erf = np.vectorize(math.erf, otypes=[np.float64])


def expected_improvement(mu: np.ndarray, sigma: np.ndarray, best: float) -> np.ndarray:
    """EI for minimization (Mockus 1975, the paper's acquisition)."""
    sigma = np.maximum(sigma, 1e-12)
    z = (best - mu) / sigma
    phi = np.exp(-0.5 * z**2) / math.sqrt(2 * math.pi)
    cdf = 0.5 * (1.0 + _erf(z / math.sqrt(2)))
    return (best - mu) * cdf + sigma * phi


@dataclasses.dataclass
class TuneResult:
    best_config: Config
    best_time: float
    evaluations: int          # unique objective evaluations (paper Fig 4)
    history: List[Tuple[Config, float]]
    stopped_by: str


class BayesianTuner:
    name = "bayesian"

    def __init__(self, n_init: Optional[int] = None, patience: int = 5,
                 max_evals: int = 64, seed: int = 0, xi: float = 0.01):
        self.n_init = n_init           # None -> adaptive to |space|
        self.patience = patience       # paper: stop if no progress in last 5
        self.max_evals = max_evals
        self.seed = seed
        self.xi = xi                   # exploration bonus on `best`

    def tune(self, space: SearchSpace, objective: Objective) -> TuneResult:
        rng = np.random.default_rng(self.seed)
        candidates = space.enumerate_valid()
        if not candidates:
            raise ValueError(f"empty search space for {space.workload.key}")
        enc = np.array([space.encode(c) for c in candidates], dtype=np.float64)

        order = rng.permutation(len(candidates))
        history: List[Tuple[Config, float]] = []
        evaluated: Dict[int, float] = {}

        def measure(idx: int) -> float:
            m = objective(space, candidates[idx])
            t = m.time_s if m.valid else PENALTY_TIME
            evaluated[idx] = t
            history.append((candidates[idx], t))
            return t

        # --- bootstrap (adaptive: bigger spaces warrant a broader prior,
        # matching the paper's higher evaluation counts on large spaces) ---
        n_init = self.n_init if self.n_init is not None else min(
            max(4, len(candidates) // 24), 12)
        for idx in order[: min(n_init, len(candidates))]:
            measure(int(idx))

        best_idx = min(evaluated, key=evaluated.get)
        best_t = evaluated[best_idx]
        since_improve = 0
        stopped_by = "exhausted"

        while len(evaluated) < min(self.max_evals, len(candidates)):
            if since_improve >= self.patience:
                stopped_by = "sliding_window"
                break
            xs = enc[list(evaluated.keys())]
            ys = np.log(np.array(list(evaluated.values())))
            gp = GP().fit(xs, ys)
            remaining = [i for i in range(len(candidates)) if i not in evaluated]
            mu, sigma = gp.predict(enc[remaining])
            ei = expected_improvement(mu, sigma, math.log(best_t) - self.xi)
            pick = remaining[int(np.argmax(ei))]
            t = measure(pick)
            if t < best_t * (1 - 1e-9):
                best_t, best_idx = t, pick
                since_improve = 0
            else:
                since_improve += 1
        else:
            stopped_by = "max_evals" if len(evaluated) >= self.max_evals else "exhausted"

        return TuneResult(
            best_config=candidates[best_idx],
            best_time=best_t,
            evaluations=len(evaluated),
            history=history,
            stopped_by=stopped_by,
        )
