"""Synthetic data pipeline: determinism, packing, masks."""
import numpy as np

from repro.data.pipeline import Batcher, DataConfig, SyntheticCorpus, \
    pack_documents


def _cfg(**kw):
    base = dict(vocab=512, seq_len=64, global_batch=4, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_across_instances():
    b1 = next(iter(Batcher(_cfg())))
    b2 = next(iter(Batcher(_cfg())))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_different_hosts_differ():
    b1 = next(iter(Batcher(_cfg(), host_id=0, n_hosts=2)))
    b2 = next(iter(Batcher(_cfg(), host_id=1, n_hosts=2)))
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_shapes_and_mask():
    cfg = _cfg()
    batch = next(iter(Batcher(cfg)))
    assert batch["tokens"].shape == (4, 64)
    assert batch["targets"].shape == (4, 64)
    assert set(np.unique(batch["mask"])) <= {0.0, 1.0}
    # targets are tokens shifted by one
    rows = np.concatenate([batch["tokens"], batch["targets"][:, -1:]], axis=1)
    np.testing.assert_array_equal(rows[:, 1:-1], batch["targets"][:, :-1])


def test_packing_offsets_are_prefix_sums():
    cfg = _cfg()
    corpus = SyntheticCorpus(cfg)
    docs = corpus.documents()
    _, _, offsets = pack_documents(docs, cfg.seq_len, 2)
    diffs = np.diff(np.concatenate([[0.0], offsets]))
    assert (diffs > 0).all()          # doc lengths positive
    assert offsets[-1] >= 2 * (cfg.seq_len + 1)
