"""Per-arch smoke tests (reduced configs): forward shapes/finiteness +
decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, all_archs, get_arch, shape_applicable
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


def _memory_for(cfg, model, params, batch):
    if cfg.family == "audio":
        frames = jax.random.normal(KEY, (batch, cfg.enc_len, cfg.d_model))
        return model.encode(params, frames)
    if cfg.family == "vlm":
        return jax.random.normal(KEY, (batch, cfg.vision_len, cfg.d_model)) * 0.1
    return None


@pytest.mark.parametrize("arch", all_archs())
def test_forward_smoke(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    memory = _memory_for(cfg, model, params, 2)
    logits, aux = model.forward(params, tokens, memory=memory)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-130m",
                                  "recurrentgemma-9b", "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    B, L = 2, 12
    tokens = jax.random.randint(KEY, (B, L), 0, cfg.vocab)
    memory = _memory_for(cfg, model, params, B)
    full, _ = model.forward(params, tokens, memory=memory)
    cache = model.init_cache(B, max_len=L, dtype=jnp.float32)
    outs = []
    for t in range(L):
        pos = jnp.full((B, 1), t, jnp.int32)
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache, pos,
                                      memory=memory)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(full - dec))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 2e-2, rel


def test_windowed_ring_buffer_cache():
    """Hybrid arch with window smaller than sequence: ring buffer correct."""
    import dataclasses
    cfg = dataclasses.replace(get_arch("recurrentgemma-9b").reduced(),
                              attn_window=8)
    model = build_model(cfg)
    params = model.init(KEY)
    B, L = 1, 24
    tokens = jax.random.randint(KEY, (B, L), 0, cfg.vocab)
    full, _ = model.forward(params, tokens)
    cache = model.init_cache(B, max_len=L, dtype=jnp.float32)
    outs = []
    for t in range(L):
        pos = jnp.full((B, 1), t, jnp.int32)
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache, pos)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(full - dec))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 2e-2, rel


def test_shape_applicability_rules():
    n_skip = 0
    for arch in all_archs():
        cfg = get_arch(arch)
        ok, why = shape_applicable(cfg, SHAPES["long_500k"])
        if not ok:
            n_skip += 1
        else:
            assert cfg.sub_quadratic
    assert n_skip == 8      # exactly the 8 full-attention archs skip
