"""Oracle for the Mamba-2 SSD (state-space dual) core.

Per (batch, head): state h in R^{d_state x d_head}; for t = 1..L:
    h_t = a_t * h_{t-1} + b_t x_t^T        (a_t scalar decay per head-step)
    y_t = c_t^T h_t
with b_t, c_t in R^{d_state}, x_t in R^{d_head}.

Shapes (grouped layout, n_groups=1 for simplicity):
    x: (B, L, H, P)   a: (B, L, H)   b: (B, L, S)   c: (B, L, S)
    y: (B, L, H, P)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """Sequential lax.scan ground truth."""
    B, L, H, P = x.shape
    S = b.shape[-1]

    def step(h, inp):
        x_t, a_t, b_t, c_t = inp          # (B,H,P), (B,H), (B,S), (B,S)
        h = a_t[..., None, None] * h + jnp.einsum("bs,bhp->bhsp", b_t, x_t)
        y = jnp.einsum("bs,bhsp->bhp", c_t, h)
        return h, y

    h0 = jnp.zeros((B, H, S, P), x.dtype)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(a, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)


def ssd_chunked_ref(x, a, b, c, chunk: int = 64):
    """Chunked (quadratic-intra + scanned-inter) formulation in pure jnp.

    The parallel algorithm the Pallas kernel implements:
      intra: y_intra[t] = sum_{s<=t, same chunk} (prod_{u in (s,t]} a_u)
                          * (c_t . b_s) * x_s
      inter: chunk states scanned with the linear-recurrence monoid, then
             broadcast into each chunk through the decay prefix.
    """
    B, L, H, P = x.shape
    S = b.shape[-1]
    Q = chunk
    nc = L // Q
    xc = x.reshape(B, nc, Q, H, P)
    ac = a.reshape(B, nc, Q, H)
    bc = b.reshape(B, nc, Q, S)
    cc = c.reshape(B, nc, Q, S)

    # cumulative log-decay within chunk: A[t] = prod_{u<=t} a_u
    la = jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-30)), axis=2)   # (B,nc,Q,H)
    A = jnp.exp(la)
    # decay from s+1..t: A[t]/A[s]; the mask is applied INSIDE the exp —
    # exp of a masked-out positive difference overflows to inf and the
    # backward pass hits 0 * inf = NaN otherwise
    diff = la[:, :, :, None, :] - la[:, :, None, :, :]            # (B,nc,t,s,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    ratio = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -1e30))
    cb = jnp.einsum("bnts,bnqs->bntq", cc, bc)                  # (B,nc,t,s)
    y_intra = jnp.einsum("bntq,bntqh,bnqhp->bnthp", cb, ratio, xc)

    # chunk-exit states: sum_s (prod_{u>s} a) b_s x_s^T
    decay_to_end = jnp.exp(la[:, :, -1:, :] - la)               # (B,nc,Q,H)
    state = jnp.einsum("bnqs,bnqh,bnqhp->bnhsp", bc, decay_to_end, xc)
    a_chunk = A[:, :, -1, :]                                    # (B,nc,H)

    # inter-chunk linear recurrence over chunk index
    def combine(l, r):
        al, sl = l
        ar, sr = r
        return al * ar, ar[..., None, None] * sl + sr

    a_scan, s_scan = jax.lax.associative_scan(
        combine, (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(state, 1, 0)),
        axis=0)
    entry = jnp.concatenate(
        [jnp.zeros_like(s_scan[:1]), s_scan[:-1]], axis=0)      # state entering chunk
    entry = jnp.moveaxis(entry, 0, 1)                           # (B,nc,H,S,P)

    # inter contribution: y[t] += c_t . (A[t] * entry)
    y_inter = jnp.einsum("bnqs,bnqh,bnhsp->bnqhp", cc, A, entry)
    return (y_intra + y_inter).reshape(B, L, H, P)
