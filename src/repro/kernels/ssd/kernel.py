"""Pallas TPU kernels for the Mamba-2 SSD chunked algorithm.

Three-phase parallel form (Dao & Gu 2024, adapted to TPU tiling):
  phase A (kernel): per (batch*head, chunk) block, compute the intra-chunk
    output via the quadratic dual form — Q x Q attention-like matmuls that
    map straight onto the MXU — plus the chunk's state-space transition
    (a_chunk scalar, (S, P) state injection);
  phase B (tuned scan): linear-recurrence scan over chunk transitions
    (reuses the paper-tuned scan kernel / monoid);
  phase C (kernel): broadcast scanned entry states back into each chunk.

The chain planner's ``fuse=1`` arm collapses phases B + C into
``ssd_state_apply_pallas``: one launch whose chunk axis is sequential and
whose (S, P) VMEM carry *is* the inter-chunk recurrence state — phase A's
chunk states feed phase B without the HBM roundtrip, and the apply is
folded into the same launch.

Tunables: chunk length Q (the VMEM tile; tile_n in the tuning space),
rows via the grid, and the chain-fusion boundary (``fuse``). Q is
hardware-aligned to the 128-lane MXU edge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _intra_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, ac_ref, st_ref):
    x = x_ref[0].astype(jnp.float32)      # (Q, P)
    a = a_ref[0].astype(jnp.float32)      # (Q,)
    b = b_ref[0].astype(jnp.float32)      # (Q, S)
    c = c_ref[0].astype(jnp.float32)      # (Q, S)
    q = x.shape[0]

    la = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-30)))            # (Q,)
    diff = la[:, None] - la[None, :]                           # (Q, Q) t,s
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    ratio = jnp.exp(jnp.where(mask, diff, -1e30))  # mask inside exp (no inf)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    scores = cb * ratio
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    decay_end = jnp.exp(la[-1] - la)                           # (Q,)
    bw = b * decay_end[:, None]                                # (Q, S)
    state = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (S, P)
    y_ref[0] = y.astype(y_ref.dtype)
    ac_ref[0, 0] = jnp.exp(la[-1]).astype(ac_ref.dtype)
    st_ref[0, 0] = state.astype(st_ref.dtype)


def _inter_kernel(y_ref, a_ref, c_ref, ent_ref, o_ref):
    y = y_ref[0].astype(jnp.float32)      # (Q, P)
    a = a_ref[0].astype(jnp.float32)      # (Q,)
    c = c_ref[0].astype(jnp.float32)      # (Q, S)
    ent = ent_ref[0, 0].astype(jnp.float32)  # (S, P)
    la = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-30)))
    amul = jnp.exp(la)                    # (Q,)
    y_in = jax.lax.dot_general(c, ent, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (Q, P)
    o_ref[0] = (y + y_in * amul[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_pallas(x, a, b, c, *, chunk: int = 128, interpret: bool = False):
    """x: (BH, L, P); a: (BH, L); b, c: (BH, L, S) — b/c pre-broadcast.

    Returns (y_intra (BH, L, P), a_chunk (BH, nc), state (BH, nc, S, P)).
    """
    BH, L, P = x.shape
    S = b.shape[-1]
    nc = L // chunk
    grid = (BH, nc)
    kernel = _intra_kernel
    y, ac, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, S), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, S), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1, S, P), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, L, P), x.dtype),
            jax.ShapeDtypeStruct((BH, nc), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, S, P), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, a, b, c)
    return y, ac, st


def _state_apply_kernel(y_ref, a_ref, c_ref, ac_ref, st_ref, o_ref,
                        carry_ref):
    """Fused phases B + C: the (S, P) VMEM carry is the recurrence state.

    The chunk axis is the grid's sequential dimension, so the carry
    entering program (i, j) is exactly h_{j-1} = the scanned entry state
    for chunk j; the kernel applies it to the chunk's output and advances
    the recurrence h_j = a_chunk_j * h_{j-1} + state_j in VMEM.
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)
    ent = carry_ref[...]                     # (S, P) entry state, f32
    y = y_ref[0].astype(jnp.float32)         # (Q, P)
    a = a_ref[0].astype(jnp.float32)         # (Q,)
    c = c_ref[0].astype(jnp.float32)         # (Q, S)
    la = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-30)))
    y_in = jax.lax.dot_general(c, ent, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (Q, P)
    o_ref[0] = (y + y_in * jnp.exp(la)[:, None]).astype(o_ref.dtype)
    ac = ac_ref[0, 0].astype(jnp.float32)
    st = st_ref[0, 0].astype(jnp.float32)
    carry_ref[...] = ac * ent + st


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_state_apply_pallas(y_intra, a, c, a_chunk, state, *,
                           chunk: int = 128, interpret: bool = False):
    """Fused inter-chunk recurrence + apply (chain ``fuse=1``): one launch.

    y_intra: (BH, L, P); a: (BH, L); c: (BH, L, S);
    a_chunk: (BH, nc) chunk transition scalars; state: (BH, nc, S, P)
    chunk state injections — both straight out of ``ssd_intra_pallas``.
    Unlike the unfused phase B, odd chunk counts need no radix-space
    fallback: the sequential carry walks any nc.
    """
    BH, L, P = y_intra.shape
    S = c.shape[-1]
    nc = L // chunk
    return pl.pallas_call(
        _state_apply_kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, S), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1, S, P), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L, P), y_intra.dtype),
        scratch_shapes=[pltpu.VMEM((S, P), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(y_intra, a, c, a_chunk, state)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_apply_entry_pallas(y_intra, a, c, entry, *, chunk: int = 128,
                           interpret: bool = False):
    """Adds the inter-chunk contribution. entry: (BH, nc, S, P)."""
    BH, L, P = y_intra.shape
    S = c.shape[-1]
    nc = L // chunk
    return pl.pallas_call(
        _inter_kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, S), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, S, P), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L, P), y_intra.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(y_intra, a, c, entry)
