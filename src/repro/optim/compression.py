"""int8 gradient compression with error feedback (DP/DCI all-reduce path).

At 512+ chips the inter-pod gradient all-reduce crosses DCI; int8 EF
compression cuts those bytes 4x (bf16) with bounded noise: the residual of
each quantization is carried into the next step (error feedback), which
keeps SGD convergence (Karimireddy et al. 2019).

`compress/decompress` are the numerics (unit-tested); `psum_compressed`
is the shard_map collective for an explicit pod-axis reduction.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q int8, scale f32 scalar, new_err)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_roundtrip(grads: PyTree, err: PyTree) -> Tuple[PyTree, PyTree]:
    """Compress->decompress every leaf with error feedback (numerics of the
    wire format; the actual reduction happens over the quantized payload)."""
    def one(g, e):
        q, s, ne = compress(g, e)
        return decompress(q, s, g.dtype), ne

    pairs = jax.tree.map(one, grads, err)
    is_t = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda x: x[0], pairs, is_leaf=is_t),
            jax.tree.map(lambda x: x[1], pairs, is_leaf=is_t))


def init_error(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_compressed(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantized psum (call inside shard_map over the pod axis)."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    # max-reduce scales so every participant uses a common grid
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total.astype(jnp.float32) * scale / n).astype(x.dtype)
