"""Tuned SSD op: three-phase chunked state-space dual.

`ssd(x, a, b, c)` with shapes (B, L, H, P), (B, L, H), (B, L, S), (B, L, S).
The chunk length comes from the TunerSession (op="ssd" shares the scan
space; tile_n -> chunk). On CPU hosts the pure-jnp chunked formulation runs
(same math, XLA-fused); the Pallas path is exercised in interpret mode by
tests and compiled on real TPUs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.space import Workload, fit_block, scan_space
from repro.kernels.blocks import driver
from repro.kernels.ssd.kernel import ssd_apply_entry_pallas, ssd_intra_pallas
from repro.kernels.ssd.ref import ssd_chunked_ref
from repro.tuning import default_session, plan_execution, tuned_kernel


def _normalize(cfg, wl, dims=None):
    """The only launch knob is the chunk length (tuned tile_n fit to L)."""
    return {"chunk": fit_block(cfg.get("tile_n", 128), wl.n)}


@tuned_kernel("ssd", space=scan_space, pallas=ssd_intra_pallas,
              reference=ssd_chunked_ref, normalize=_normalize,
              variants=("chunked",))
def ssd(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
        config: Optional[dict] = None, interpret: Optional[bool] = None,
        use_pallas: Optional[bool] = None) -> jax.Array:
    B, L, H, P = x.shape
    S = b.shape[-1]
    cfg = default_session().resolve(
        Workload(op="ssd", n=L, batch=B * H, variant="chunked"),
        config=config)
    chunk = cfg["chunk"]
    use_pallas, interpret = plan_execution(use_pallas, interpret)
    if not use_pallas:
        return ssd_chunked_ref(x, a, b, c, chunk=chunk)

    # reshape to (BH, L, ...) rows; broadcast b/c over heads (n_groups=1)
    xbh = jnp.transpose(x, (0, 2, 1, 3)).reshape(B * H, L, P)
    abh = jnp.transpose(a, (0, 2, 1)).reshape(B * H, L)
    bbh = jnp.broadcast_to(b[:, None], (B, H, L, S)).reshape(B * H, L, S)
    cbh = jnp.broadcast_to(c[:, None], (B, H, L, S)).reshape(B * H, L, S)

    y_intra, a_chunk, state = ssd_intra_pallas(
        xbh, abh, bbh, cbh, chunk=chunk, interpret=interpret)
    nc = L // chunk

    # phase B: inter-chunk linear recurrence (rows = BH*S*P, length nc) on
    # the shared carry-chain building block — the tuned scan kernel where
    # the (op="scan", variant="linrec") space has a valid config for nc,
    # the XLA reference otherwise (odd nc)
    a_rows = jnp.broadcast_to(a_chunk[:, None, None, :], (B * H, S, P, nc))
    s_rows = jnp.transpose(state, (0, 2, 3, 1))          # (BH, S, P, nc)
    h = driver.linrec_rows(a_rows.reshape(-1, nc), s_rows.reshape(-1, nc),
                           use_pallas=True, interpret=interpret)
    h = h.reshape(B * H, S, P, nc)
    entry = jnp.concatenate(
        [jnp.zeros_like(h[..., :1]), h[..., :-1]], axis=-1)
    entry = jnp.transpose(entry, (0, 3, 1, 2))           # (BH, nc, S, P)

    y = ssd_apply_entry_pallas(y_intra, abh, cbh, entry, chunk=chunk,
                               interpret=interpret)
    return jnp.transpose(y.reshape(B, H, L, P), (0, 2, 1, 3))
