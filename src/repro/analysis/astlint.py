"""Repo-convention lint: pure ``ast`` rules over ``src/repro``.

Each rule encodes a convention the test suite or a past PR established
but nothing previously enforced:

  * ``ast.retired-shim-import`` — ``repro.core.tuner`` and
    ``repro.hw.tpu`` raise ImportError at import time; importing them is
    always a bug.
  * ``ast.deprecated-alias`` — ``TPUCostModelObjective`` is a
    backwards-compat alias of ``CostModelObjective``; only its definition
    site (core/objective.py) and the compat re-export (core/__init__.py)
    may reference it.
  * ``ast.deprecated-spec-kwarg`` — ``spec=`` is a deprecated alias of
    ``profile=`` on the space/plan/objective entry points; call sites
    must pass ``profile=``.
  * ``ast.raw-clock`` — measurement paths (``serve/``, ``tuning/``,
    ``launch/serve.py``) must use the injectable clock
    (``ServeEngine.step_timer`` / the online tuner's ``StepTimer``) so
    tests can fake time; calling ``time.time()`` / ``time.perf_counter()``
    directly makes the path untestable.  The ``serve/`` scope covers the
    whole serving package — the optimized engine, the replay
    :mod:`~repro.serve.reference` baseline, and the
    :mod:`~repro.serve.trace` generator — where the only blessed clock
    use is the bare ``time.perf_counter`` *reference* as the
    ``step_timer`` default (a call would be flagged).
  * ``ast.objective-batch-eval`` — vector objectives override
    ``batch_eval_metrics`` (``batch_eval`` derives from it); overriding
    only ``batch_eval`` silently drops the energy/VMEM columns.
  * ``ast.mutable-default`` — classic Python footgun; ruff's B006
    equivalent, enforced here so the rule also runs where ruff is not
    installed.
  * ``ast.journal-open-append`` — journal/trace appends must go through
    ``repro.tuning.sweep.append_journal_lines`` (single ``os.write`` on an
    ``O_APPEND`` descriptor, torn-tail termination); a buffered
    ``open(path, "a")`` can interleave with concurrent writers and leaves
    multi-line tears.

Adding a rule: write a generator taking a :class:`LintContext` and
yielding :class:`~repro.analysis.findings.Finding`, decorate it with
``@rule("name")``.  A source line containing ``lint: allow[<name>]``
suppresses that rule on that line (use sparingly; prefer fixing).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Callable, Dict, Iterable, Iterator, List, Optional

from repro.analysis.findings import Finding

# -- registry ---------------------------------------------------------------

RULES: Dict[str, Callable[["LintContext"], Iterable[Finding]]] = {}


def rule(name: str):
    """Register an AST lint rule under ``ast.<name>``."""
    def deco(fn):
        RULES[name] = fn
        return fn
    return deco


@dataclasses.dataclass
class LintContext:
    """Everything a rule may inspect for one file."""

    relpath: str          # path relative to the repro package root
    tree: ast.AST
    lines: List[str]      # raw source lines (1-indexed via line numbers)

    def allowed(self, rule_name: str, lineno: int) -> bool:
        """True when the line opts out via ``lint: allow[<rule>]``."""
        if 1 <= lineno <= len(self.lines):
            return f"lint: allow[{rule_name}]" in self.lines[lineno - 1]
        return False

    def finding(self, rule_name: str, node: ast.AST, message: str
                ) -> Iterator[Finding]:
        lineno = getattr(node, "lineno", 0)
        if not self.allowed(rule_name, lineno):
            yield Finding(rule=f"ast.{rule_name}", path=self.relpath,
                          line=lineno, message=message)


# -- shared AST helpers -----------------------------------------------------

def _call_name(node: ast.Call) -> str:
    """Trailing name of the called expression (``a.b.c()`` -> ``c``)."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _dotted(node: ast.AST) -> str:
    """``a.b.c`` attribute chain as a string ('' for anything else)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# -- rules ------------------------------------------------------------------

RETIRED_MODULES = ("repro.core.tuner", "repro.hw.tpu")


@rule("retired-shim-import")
def _retired_shim_import(ctx: LintContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        names: List[str] = []
        if isinstance(node, ast.Import):
            names = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            names = [node.module]
            names += [f"{node.module}.{a.name}" for a in node.names]
        for name in names:
            for retired in RETIRED_MODULES:
                if name == retired or name.startswith(retired + "."):
                    yield from ctx.finding(
                        "retired-shim-import", node,
                        f"import of retired shim {retired!r} (it raises "
                        f"ImportError; see its module docstring for the "
                        f"replacement)")


DEPRECATED_ALIAS = "TPUCostModelObjective"
# definition site + the compat re-export keep the alias importable
_ALIAS_ALLOWED_FILES = ("core/objective.py", "core/__init__.py")


@rule("deprecated-alias")
def _deprecated_alias(ctx: LintContext) -> Iterator[Finding]:
    if ctx.relpath in _ALIAS_ALLOWED_FILES:
        return
    for node in ast.walk(ctx.tree):
        hit = None
        if isinstance(node, ast.ImportFrom):
            if any(a.name == DEPRECATED_ALIAS for a in node.names):
                hit = node
        elif isinstance(node, ast.Name) and node.id == DEPRECATED_ALIAS:
            hit = node
        elif isinstance(node, ast.Attribute) and node.attr == DEPRECATED_ALIAS:
            hit = node
        if hit is not None:
            yield from ctx.finding(
                "deprecated-alias", hit,
                f"{DEPRECATED_ALIAS} is a deprecated alias; use "
                f"CostModelObjective (profile-parameterized)")


# entry points whose ``spec=`` kwarg is the deprecated profile alias; other
# functions (e.g. distributed_tuning.micro_step_overhead_s) use ``spec`` as
# their canonical parameter name and are not targeted
SPEC_KWARG_TARGETS = ("build_space", "plan_for", "build_plan",
                      "CostModelObjective", "TPUCostModelObjective")


@rule("deprecated-spec-kwarg")
def _deprecated_spec_kwarg(ctx: LintContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _call_name(node)
        if callee not in SPEC_KWARG_TARGETS:
            continue
        for kw in node.keywords:
            if kw.arg == "spec":
                yield from ctx.finding(
                    "deprecated-spec-kwarg", node,
                    f"{callee}(spec=...) is deprecated; pass profile=...")


RAW_CLOCKS = ("time.time", "time.perf_counter", "perf_counter")
# measurement paths that must use the injectable clock
_CLOCK_SCOPED = re.compile(r"^(serve|tuning)/|^launch/serve\.py$")


@rule("raw-clock")
def _raw_clock(ctx: LintContext) -> Iterator[Finding]:
    if not _CLOCK_SCOPED.search(ctx.relpath):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name in RAW_CLOCKS:
            yield from ctx.finding(
                "raw-clock", node,
                f"direct {name}() call on a measurement path; use the "
                f"injectable clock (ServeEngine.step_timer / StepTimer) so "
                f"tests can fake time")


@rule("objective-batch-eval")
def _objective_batch_eval(ctx: LintContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        bases = {b.attr if isinstance(b, ast.Attribute) else
                 getattr(b, "id", "") for b in node.bases}
        if not any(b.endswith("Objective") for b in bases):
            continue
        methods = {n.name for n in node.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        if "batch_eval" in methods and "batch_eval_metrics" not in methods:
            yield from ctx.finding(
                "objective-batch-eval", node,
                f"{node.name} overrides batch_eval without "
                f"batch_eval_metrics: the vector path (energy/VMEM columns) "
                f"silently falls back to the base loop — override "
                f"batch_eval_metrics instead (batch_eval derives from it)")


_MUTABLE_CALLS = ("list", "dict", "set")


@rule("mutable-default")
def _mutable_default(ctx: LintContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            bad = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call) and not d.args and not d.keywords
                and isinstance(d.func, ast.Name)
                and d.func.id in _MUTABLE_CALLS)
            if bad:
                yield from ctx.finding(
                    "mutable-default", d,
                    f"mutable default argument in {node.name}(); defaults "
                    f"are evaluated once and shared across calls — default "
                    f"to None and construct inside the body")


@rule("journal-open-append")
def _journal_open_append(ctx: LintContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call) or _call_name(node) != "open":
            continue
        if isinstance(node.func, ast.Attribute):
            continue   # os.open etc. — the helper itself
        mode = None
        if len(node.args) >= 2:
            mode = node.args[1]
        for kw in node.keywords:
            if kw.arg == "mode":
                mode = kw.value
        if isinstance(mode, ast.Constant) and isinstance(mode.value, str) \
                and "a" in mode.value:
            yield from ctx.finding(
                "journal-open-append", node,
                'buffered open(..., "a") append; use '
                "repro.tuning.sweep.append_journal_lines (O_APPEND + single "
                "os.write + torn-tail termination) so concurrent writers "
                "never interleave mid-line")


# -- runner -----------------------------------------------------------------

def lint_source(relpath: str, source: str,
                rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint one file's source text; ``relpath`` is repro-package-relative."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(rule="ast.syntax-error", path=relpath,
                        line=e.lineno or 0, message=str(e.msg))]
    ctx = LintContext(relpath=relpath.replace(os.sep, "/"), tree=tree,
                      lines=source.splitlines())
    out: List[Finding] = []
    for name, fn in sorted(RULES.items()):
        if rules is not None and name not in rules:
            continue
        out.extend(fn(ctx))
    return out


def lint_tree(pkg_root: Optional[str] = None,
              rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint every ``*.py`` under the repro package root."""
    if pkg_root is None:
        import repro
        pkg_root = os.path.dirname(os.path.abspath(repro.__file__))
    findings: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(pkg_root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            full = os.path.join(dirpath, fname)
            rel = os.path.relpath(full, pkg_root)
            with open(full, encoding="utf-8") as f:
                findings.extend(lint_source(rel, f.read(), rules=rules))
    return findings
