"""Findings, reports, and the baseline suppression file.

Every check in ``repro.analysis`` — AST lint rules, plan-invariant
verification, version-drift fingerprints — reports problems as
:class:`Finding` records.  A finding's :meth:`~Finding.key` is stable
across unrelated edits (it hashes the rule, the location, and the message
but **not** the line number), so a baseline file keeps suppressing the
same finding while surrounding code moves.

The baseline workflow (docs/analysis.md):

  * ``tune.py lint`` exits non-zero on any finding not listed in the
    baseline;
  * an intentionally accepted finding is added to the baseline JSON
    (``{"version": 1, "suppress": ["<key>", ...]}``) with a review;
  * the shipped tree keeps an **empty** baseline — the self-clean test
    pins that invariant.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence

BASELINE_VERSION = 1
REPORT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    """One problem surfaced by a static check.

    ``rule`` is namespaced (``ast.raw-clock``, ``invariant.stage-product``,
    ``fingerprint.feature_columns``); ``path`` is repo-relative for AST
    findings and a logical location (``op/profile``) for semantic ones;
    ``line`` is 0 when no source line applies.
    """

    rule: str
    path: str
    message: str
    line: int = 0

    def key(self) -> str:
        """Stable identity for baselining: rule + path + message digest.

        The line number is deliberately excluded — suppressions must
        survive unrelated edits shifting code up or down.
        """
        digest = hashlib.sha256(self.message.encode()).hexdigest()[:12]
        return f"{self.rule}:{self.path}:{digest}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"

    def to_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "key": self.key()}


def report_dict(findings: Sequence[Finding],
                suppressed: Sequence[Finding] = ()) -> Dict:
    """The ``--json`` report: every finding plus baseline accounting."""
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "version": REPORT_VERSION,
        "findings": [f.to_dict() for f in findings],
        "suppressed": [f.to_dict() for f in suppressed],
        "counts": counts,
        "total": len(findings),
    }


def load_baseline(path: Optional[str]) -> List[str]:
    """Suppression keys from a baseline file; [] when absent."""
    if not path or not os.path.exists(path):
        return []
    with open(path) as f:
        raw = json.load(f)
    if not isinstance(raw, dict) or "suppress" not in raw:
        raise ValueError(f"baseline {path!r}: expected "
                         '{"version": 1, "suppress": [...]}')
    return [str(k) for k in raw["suppress"]]


def apply_baseline(findings: Iterable[Finding], suppress: Sequence[str]
                   ) -> tuple:
    """Split findings into (fresh, suppressed) against baseline keys."""
    keys = set(suppress)
    fresh: List[Finding] = []
    quiet: List[Finding] = []
    for f in findings:
        (quiet if f.key() in keys else fresh).append(f)
    return fresh, quiet
