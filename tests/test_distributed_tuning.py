"""Beyond-paper distributed-config tuning: space + objective plumbing."""
from repro.core.distributed_tuning import (CompiledRooflineObjective,
                                           distributed_space,
                                           micro_step_overhead_s,
                                           step_time_from_record,
                                           tune_distributed)


def test_space_enumerable():
    sp = distributed_space("granite-34b", "train_4k", is_moe=False,
                           is_train=True)
    cfgs = sp.enumerate_valid()
    assert len(cfgs) == 2 * 4 * 2 * 1
    sp2 = distributed_space("qwen3-moe-30b-a3b", "train_4k", is_moe=True)
    assert len(sp2.enumerate_valid()) == 2 * 4 * 2 * 3


def test_serving_space_has_no_train_knobs():
    sp = distributed_space("gemma-2b", "decode_32k", is_train=False)
    for cfg in sp.enumerate_valid():
        assert cfg["micro_steps"] == 1 and cfg["remat"] == 1


# ---------------------------------------------------------------------------
# micro_steps objective regression (the dead `if False` branch made the
# knob a no-op: the objective returned the same step time for every value)
# ---------------------------------------------------------------------------

GRAD_BYTES_DEV = 8 * 2**20     # ~0.5b params / 256 chips, f32 accumulator


def _fake_record(micro_steps: int) -> dict:
    # per-step bound mildly DECREASING in micro_steps (smaller activation
    # working set): exactly the shape that made the broken objective pick
    # the largest accumulation depth for free
    t = 1.0e-3 * (1.0 - 4.0e-3 * micro_steps)
    return {"status": "ok", "chips": 256,
            "per_device": {"peak_bytes": 10 * 2**30},
            "roofline": {"compute_s": t, "memory_s": t / 2,
                         "collective_s": t / 4},
            "dominant": "compute_s",
            "step_time_bound_s": t}


def test_micro_step_overhead_charges_accumulation():
    assert micro_step_overhead_s(1, GRAD_BYTES_DEV) == 0.0
    o2 = micro_step_overhead_s(2, GRAD_BYTES_DEV)
    o8 = micro_step_overhead_s(8, GRAD_BYTES_DEV)
    assert 0 < o2 < o8
    # each extra micro step pays at least the grad-shard read-modify-write
    assert o8 >= 7 * 2 * GRAD_BYTES_DEV / 819e9


def test_micro_steps_changes_objective_time():
    """Two micro_steps values must produce different objective times."""
    base = {"sp": 0, "remat": 1, "moe_group": 1024}
    rec2, rec8 = _fake_record(2), _fake_record(8)
    t2 = step_time_from_record(rec2, dict(base, micro_steps=2),
                               GRAD_BYTES_DEV)
    t8 = step_time_from_record(rec8, dict(base, micro_steps=8),
                               GRAD_BYTES_DEV)
    assert t2 != t8
    # and in the corrected direction: the accumulation overhead outweighs
    # the small activation-footprint gain the raw bound shows
    assert t8 > t2
    assert rec8["step_time_bound_s"] < rec2["step_time_bound_s"]


def test_fixed_objective_changes_tune_distributed_winner(monkeypatch):
    """With the dead branch, tune_distributed ranked configs by the raw
    per-step bound — argmin at micro_steps=8.  The fixed objective charges
    the accumulation cost and flips the winner."""
    import repro.launch.roofline as roofline

    def fake_analyze_cell(arch, shape, multi_pod=False, arch_cfg=None,
                          hp=None):
        return _fake_record(hp.micro_steps if hp is not None else 1)

    monkeypatch.setattr(roofline, "analyze_cell", fake_analyze_cell)
    res = tune_distributed("qwen1.5-0.5b", "train_4k", method="exhaustive")

    # what the broken objective optimized: raw step_time_bound_s
    broken_winner_micro = max(
        (1, 2, 4, 8), key=lambda m: -_fake_record(m)["step_time_bound_s"])
    assert broken_winner_micro == 8
    assert res.best_config["micro_steps"] != broken_winner_micro
    assert res.best_config["micro_steps"] == 1

    # the fixed objective really produced distinct times per micro_steps
    times_by_micro = {}
    for cfg, t in res.history:
        times_by_micro.setdefault(cfg["micro_steps"], set()).add(round(t, 12))
    assert len({min(v) for v in times_by_micro.values()}) == 4


def test_hbm_guard_still_penalizes(monkeypatch):
    import repro.launch.roofline as roofline

    def oom_analyze_cell(arch, shape, multi_pod=False, arch_cfg=None,
                         hp=None):
        rec = _fake_record(hp.micro_steps if hp is not None else 1)
        rec["per_device"]["peak_bytes"] = 32 * 2**30   # > 16 GiB HBM
        return rec

    monkeypatch.setattr(roofline, "analyze_cell", oom_analyze_cell)
    sp = distributed_space("qwen1.5-0.5b", "train_4k")
    m = CompiledRooflineObjective()(sp, sp.enumerate_valid()[0])
    assert not m.valid and m.time_s > 60.0
