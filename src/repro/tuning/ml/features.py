"""Deterministic featurization of (Workload, candidate Config) pairs.

The learned predictor (paper §IV-B's offline-ML methodology, re-targeted at
config *prediction* instead of config *search*) never sees raw dicts: every
candidate is encoded as a fixed-length float vector whose layout is frozen
by ``FEATURE_NAMES``. Two design rules:

  * log2-encode every power-of-two knob and dimension — sizes span four
    orders of magnitude and trees split far better on the exponent;
  * stack on the analytical model: the occupancy / lane-utilization /
    grid-depth / pass-count quantities from ``repro.core.analytical`` are
    features, so the forest learns *corrections to the expert model*
    rather than re-deriving TPU architecture from scratch.

The encoding is pure and deterministic (no RNG, no wall clock), so a row
computed at train time is bit-identical to the row computed online — the
model artifact stays valid as long as ``FEATURE_VERSION`` matches.
"""
from __future__ import annotations

import math
import zlib
from typing import Mapping, Sequence

import numpy as np

from repro.core.analytical import score
from repro.core.space import Config, SearchSpace
from repro.hw.profiles import dma_efficiency, dtype_bytes, ilp_factor
from repro.kernels.blocks.plan import plan_for

# Bump whenever FEATURE_NAMES or any encoding rule changes; artifacts carry
# the version and loading a stale one fails fast instead of mis-predicting.
# v4: device feature columns (hardware-profile geometry/limits), so one
# forest can pool rows measured on different profiles.
# v5: "fuse" column — the chain-fusion boundary knob (ssd/rglru chains);
# the plan columns (log2_passes) already see its effect, the raw knob lets
# the forest separate fusion from blocking at equal pass counts.
FEATURE_VERSION = 5

FEATURE_NAMES = (
    # workload (Input Parameters `A`)
    "log2_n", "log2_batch", "dtype_bytes", "variant_id",
    # raw knobs (Performance Parameters `B`); 0.0 when a knob is absent
    "log2_tile_n", "log2_rows", "log2_radix", "log2_unroll", "in_register",
    "fuse",
    "log2_block_q", "log2_block_k", "log2_block_m", "log2_block_n",
    # StagePlan stack (the exact staged execution the drivers launch:
    # launches/HBM passes, stage count, carry-chain depth, raggedness,
    # VMEM) + the guideline score computed on the same plan
    "log2_grid", "log2_vmem", "occupancy", "log2_ilp", "log2_passes",
    "log2_block_bytes", "steps_per_pass", "vmem_fits",
    "log2_seq_tiles", "ragged_tail",
    "tier", "radix_rank", "block_rank", "ilp_rank",
    # machine-model response curves (hw.profiles): the expert model's own
    # efficiency terms, so the forest corrects them instead of re-learning
    "dma_eff", "ilp_eff", "lane_util", "sublane_util",
    "log2_total_bytes", "log2_t_mem_proxy", "log2_steps_total",
    # scale-invariant knob ratios: log2(knob / the dim it divides).
    # Absolute tile_n=512 means "one pass" at N=512 but "half the problem"
    # at N=1024 — the ratio is what generalizes to unseen N (0.0 when the
    # knob is absent from the op's space).
    "rel_tile_n", "rel_rows", "rel_block_q", "rel_block_k",
    "rel_block_m", "rel_block_n",
    # space-context features (filled by featurize_batch): this candidate's
    # standing *relative to the alternatives in its own space*. A
    # per-candidate regressor cannot otherwise express "largest exact radix
    # AVAILABLE at this N" — the winning radix at an unseen size may never
    # have been the winner at any training size, but "radix_rank_rel == 0"
    # transfers exactly.
    "ana_rank_pct", "tier_rel", "radix_rank_rel", "block_rank_rel",
    "dma_eff_rel",
    # device columns (the space's hardware profile): constant within one
    # profile — a single-device forest never splits on them — but they let
    # one forest pool rows measured on different devices and learn
    # hardware-conditioned corrections (the paper's portability story).
    "dev_log2_vmem_budget", "dev_log2_lanes", "dev_log2_sublanes",
    "dev_log2_mxu", "dev_log2_bw", "dev_log2_flops_bytes",
    "dev_log2_launch_ns", "dev_log2_sync_ns",
)

N_FEATURES = len(FEATURE_NAMES)

_LOG2_KNOBS = (
    ("log2_tile_n", "tile_n"), ("log2_rows", "rows_per_program"),
    ("log2_radix", "radix"), ("log2_unroll", "unroll"),
    ("log2_block_q", "block_q"), ("log2_block_k", "block_k"),
    ("log2_block_m", "block_m"), ("log2_block_n", "block_n"),
)


def _log2(v: float) -> float:
    return math.log2(v) if v > 0 else 0.0


def variant_id(variant: str) -> float:
    """Stable small numeric id for the workload variant (categorical)."""
    if not variant:
        return 0.0
    return float(zlib.crc32(variant.encode()) % 97 + 1)


def _encode(space: SearchSpace, cfg: Mapping[str, int]):
    """(feature row, analytical score) — one StagePlan per candidate.

    Every architectural quantity is read off the plan (the same object the
    kernel drivers execute), so train-time rows, predict-time rows, and
    the launched kernels all agree; the only additions are the machine
    model's response curves evaluated AT the plan's operating point.
    """
    wl = space.workload
    plan = plan_for(wl, cfg, profile=space.spec)
    res = plan.resources()
    sc = score(space, dict(cfg), res=res)

    spec = space.spec
    block_bytes = max(float(plan.block_bytes), 1.0)
    dma_eff = dma_efficiency(int(block_bytes), spec)
    # bytes the whole problem moves per HBM pass (read+write), the
    # numerator of the machine model's memory term
    total_bytes = 2.0 * plan.batch * wl.n * plan.element_bytes * plan.passes
    t_mem_proxy = total_bytes / (spec.hbm_bandwidth * max(dma_eff, 1e-6))

    row = {
        "log2_n": _log2(wl.n),
        "log2_batch": _log2(max(wl.batch, 1)),
        "dtype_bytes": float(dtype_bytes(wl.dtype)),
        "variant_id": variant_id(wl.variant),
        "in_register": float(cfg.get("in_register", 0)),
        "fuse": float(cfg.get("fuse", 0)),
        "log2_grid": _log2(res["grid"]),
        "log2_vmem": _log2(res["vmem"]),
        "occupancy": float(res["occupancy"]),
        "log2_ilp": _log2(max(res["ilp"], 1)),
        "log2_passes": _log2(max(res["passes"], 1)),
        "log2_block_bytes": _log2(block_bytes),
        "steps_per_pass": float(res["steps_per_pass"]),
        "vmem_fits": 1.0 if res["vmem"] <= space.spec.vmem_budget else 0.0,
        "log2_seq_tiles": _log2(max(res["seq_tiles"], 1)),
        "ragged_tail": float(res["ragged"]),
        "tier": float(sc.tier),
        "radix_rank": float(sc.radix_rank),
        "block_rank": float(sc.block_rank),
        "ilp_rank": float(sc.ilp_rank),
        "dma_eff": float(dma_eff),
        "ilp_eff": float(ilp_factor(int(cfg.get("unroll", 1)), spec)),
        "lane_util": float(res["lane_eff"]),
        "sublane_util": float(res["sublane_eff"]),
        "log2_total_bytes": _log2(total_bytes),
        "log2_t_mem_proxy": _log2(max(t_mem_proxy, 1e-12)),
        "log2_steps_total": _log2(
            max(res["passes"] * max(res["steps_per_pass"], 1.0), 1.0)),
    }
    for feat, knob in _LOG2_KNOBS:
        row[feat] = _log2(cfg[knob]) if knob in cfg else 0.0
    batch = max(wl.batch, 1)
    for feat, knob, denom in (
            ("rel_tile_n", "tile_n", wl.n), ("rel_rows", "rows_per_program", batch),
            ("rel_block_q", "block_q", wl.n), ("rel_block_k", "block_k", wl.n),
            ("rel_block_m", "block_m", batch), ("rel_block_n", "block_n", wl.n)):
        row[feat] = _log2(cfg[knob]) - _log2(denom) if knob in cfg else 0.0
    # neutral context defaults; featurize_batch overwrites with real standing
    row["ana_rank_pct"] = 1.0
    row["tier_rel"] = 0.0
    row["radix_rank_rel"] = 0.0
    row["block_rank_rel"] = 0.0
    row["dma_eff_rel"] = 0.0
    # device columns: the profile this space (and therefore this row's
    # label) was bounded/measured by
    row["dev_log2_vmem_budget"] = _log2(spec.vmem_budget)
    row["dev_log2_lanes"] = _log2(spec.lane_count)
    row["dev_log2_sublanes"] = _log2(spec.sublane_count)
    row["dev_log2_mxu"] = _log2(spec.mxu_dim)
    row["dev_log2_bw"] = _log2(spec.hbm_bandwidth)
    # machine balance (vector flops per HBM byte): the roofline knee
    row["dev_log2_flops_bytes"] = _log2(spec.peak_vpu_flops
                                        / spec.hbm_bandwidth)
    row["dev_log2_launch_ns"] = _log2(spec.kernel_launch_s * 1e9)
    row["dev_log2_sync_ns"] = _log2(spec.pass_sync_s * 1e9)
    return (np.array([row[name] for name in FEATURE_NAMES],
                     dtype=np.float64), sc)


def featurize(space: SearchSpace, cfg: Mapping[str, int]) -> np.ndarray:
    """One candidate -> one float64 row in ``FEATURE_NAMES`` order.

    The trailing space-context features are neutral here (best-possible
    standing); use :func:`featurize_batch` over the full candidate set —
    as the dataset builder and the strategy both do — whenever relative
    standing should be real.
    """
    return _encode(space, cfg)[0]


_CONTEXT_COLS = {name: FEATURE_NAMES.index(name) for name in
                 ("ana_rank_pct", "tier_rel", "radix_rank_rel",
                  "block_rank_rel", "dma_eff_rel")}
_TIER_COL = FEATURE_NAMES.index("tier")
_RADIX_RANK_COL = FEATURE_NAMES.index("radix_rank")
_BLOCK_RANK_COL = FEATURE_NAMES.index("block_rank")
_DMA_EFF_COL = FEATURE_NAMES.index("dma_eff")


def featurize_batch(space: SearchSpace,
                    cfgs: Sequence[Config]) -> np.ndarray:
    """Encode the candidates of one space; shape (len(cfgs), N_FEATURES).

    Fills the space-context columns from the batch itself: the analytical
    ordering percentile and each candidate's tier/radix/block rank relative
    to the best value present among ``cfgs``.
    """
    if not cfgs:
        return np.empty((0, N_FEATURES), dtype=np.float64)
    encoded = [_encode(space, c) for c in cfgs]
    X = np.stack([row for row, _ in encoded])
    keys = [sc.key() for _, sc in encoded]
    order = sorted(range(len(keys)), key=keys.__getitem__, reverse=True)
    pct = np.empty(len(keys))
    denom = max(len(keys) - 1, 1)
    for rank, i in enumerate(order):
        pct[i] = 1.0 - rank / denom
    X[:, _CONTEXT_COLS["ana_rank_pct"]] = pct
    X[:, _CONTEXT_COLS["tier_rel"]] = X[:, _TIER_COL] - X[:, _TIER_COL].max()
    X[:, _CONTEXT_COLS["radix_rank_rel"]] = \
        X[:, _RADIX_RANK_COL] - X[:, _RADIX_RANK_COL].max()
    X[:, _CONTEXT_COLS["block_rank_rel"]] = \
        X[:, _BLOCK_RANK_COL] - X[:, _BLOCK_RANK_COL].max()
    X[:, _CONTEXT_COLS["dma_eff_rel"]] = \
        X[:, _DMA_EFF_COL] - X[:, _DMA_EFF_COL].max()
    return X
