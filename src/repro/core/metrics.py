"""Performance-portability metric Phi (paper §VI, after Pennycook et al.).

    Phi(a, C) = |C| / sum_i 1 / e_i(a, p_i)

where e_i is the efficiency of methodology `a` on problem size p_i, measured
as a fraction of the best empirically-observed performance (the exhaustive
optimum). Phi = 1 means every size matched the optimum; the harmonic mean
punishes any single bad size hard — exactly why the paper chose it.
"""
from __future__ import annotations

from typing import Mapping, Sequence


def efficiency(achieved_time: float, best_time: float) -> float:
    """Performance efficiency in (0, 1]; performance = 1/time."""
    if achieved_time <= 0 or best_time <= 0:
        raise ValueError("times must be positive")
    return min(best_time / achieved_time, 1.0)


def phi(efficiencies: Sequence[float]) -> float:
    if not len(efficiencies):
        raise ValueError("need at least one efficiency")
    for e in efficiencies:
        if not (0 < e <= 1.0 + 1e-9):
            raise ValueError(f"efficiency out of range: {e}")
    return len(efficiencies) / sum(1.0 / e for e in efficiencies)


def phi_from_times(method_times: Mapping[int, float], best_times: Mapping[int, float]) -> float:
    """Phi over a common set of problem sizes: {N: time}."""
    sizes = sorted(method_times)
    if sorted(best_times) != sizes:
        raise ValueError("method and best time tables cover different sizes")
    return phi([efficiency(method_times[n], best_times[n]) for n in sizes])
