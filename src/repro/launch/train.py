"""Training driver (host-scale run of the production stack).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --reduced \
      --steps 100 --batch 8 --seq 256 --ckpt /tmp/ckpt

On the real pod this module is launched per-host with jax.distributed;
here it runs the same code on the host device set (see examples/train_lm.py
for the ~100M-parameter end-to-end run).
"""
from __future__ import annotations

import argparse

from repro.configs.base import get_arch
from repro.data.pipeline import Batcher, DataConfig
from repro.models.model import build_model
from repro.train.loop import LoopConfig, run_training
from repro.train.step import TrainHParams


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (host runs)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro-steps", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    hp = TrainHParams(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps, micro_steps=args.micro_steps)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    loop = LoopConfig(total_steps=args.steps, checkpoint_dir=args.ckpt,
                      checkpoint_every=args.ckpt_every)
    out = run_training(model, hp, loop, iter(Batcher(data_cfg)))
    final = out["history"][-1] if out["history"] else {}
    print(f"[train] done: {final}")


if __name__ == "__main__":
    main()
