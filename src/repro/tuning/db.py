"""Versioned, thread-safe JSON config store (the offline -> online handoff).

Schema 2 wraps the entries in an envelope so future migrations are cheap:

    {"schema": 2,
     "entries": {"<platform>|<workload-key>": {"config": {...},
                                               "time_s": ..., "method": ...,
                                               "evaluations": ...}}}

Legacy (schema-1) files were a flat ``{key: entry}`` mapping; ``_load``
migrates them transparently and the next ``store`` persists the new
envelope. Unknown top-level envelope keys (annotations from other tools,
future-schema side-channels) are preserved across load/flush rather than
dropped. Writes are atomic (tmp file + ``os.replace``) and serialized by a
lock, so concurrent ``store`` calls from threads never corrupt the file.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Optional

SCHEMA_VERSION = 2

DEFAULT_DB_PATH = os.environ.get(
    "REPRO_TUNING_DB", os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                    "artifacts", "tuning_db.json"))


class TuningDB:
    """JSON-backed config store; thread-safe; content-addressed by workload key."""

    def __init__(self, path: Optional[str] = None, platform: str = "tpu_v5e"):
        self.path = os.path.abspath(path or DEFAULT_DB_PATH)
        self.platform = platform
        self._lock = threading.Lock()
        self._data: Dict[str, Dict] = {}
        self._extra: Dict[str, object] = {}   # unknown envelope keys, kept
        self._loaded = False

    # -- persistence ---------------------------------------------------------

    def _load(self) -> None:
        if self._loaded:
            return
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    raw = json.load(f)
            except (json.JSONDecodeError, OSError):
                raw = {}
            if isinstance(raw, dict) and "schema" in raw:
                self._data = dict(raw.get("entries") or {})
                # preserve unknown envelope keys (annotations written by
                # other tools, future-schema side-channels): they round-trip
                # through the next flush instead of being dropped
                self._extra = {k: v for k, v in raw.items()
                               if k not in ("schema", "entries")}
            else:
                # legacy flat {key: entry} file (schema 1)
                self._data = raw if isinstance(raw, dict) else {}
        self._loaded = True

    def _flush_locked(self) -> None:
        directory = os.path.dirname(self.path)
        if directory:
            os.makedirs(directory, exist_ok=True)
        payload = {**self._extra, "schema": SCHEMA_VERSION,
                   "entries": self._data}
        tmp = f"{self.path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    # -- access --------------------------------------------------------------

    def _key(self, wl) -> str:
        return f"{self.platform}|{wl.key}"

    def lookup(self, wl) -> Optional[Dict]:
        with self._lock:
            self._load()
            entry = self._data.get(self._key(wl))
            return dict(entry["config"]) if entry else None

    def store(self, wl, cfg: Dict, time_s: float, method: str,
              evaluations: int = 0) -> None:
        with self._lock:
            self._load()
            self._data[self._key(wl)] = {
                "config": dict(cfg), "time_s": time_s, "method": method,
                "evaluations": evaluations,
            }
            self._flush_locked()

    def entries(self) -> Dict[str, Dict]:
        with self._lock:
            self._load()
            return dict(self._data)

    def __len__(self) -> int:
        with self._lock:
            self._load()
            return len(self._data)
