"""Online tuning subsystem: EWMA measurement, trial/rollback state machine,
deterministic trace replay, TuningDB/journal persistence, strategy row."""
import numpy as np
import pytest

from repro.core import TPUCostModelObjective, Workload, build_space
from repro.core.objective import CachedObjective, PENALTY_TIME
from repro.tuning import (OnlineTuner, ReplayTrace, TunerSession,
                          aggregate_fleet, fleet_prior,
                          measurements_to_incumbent, online_search,
                          promote_fleet_winner, replay, warm_tuner)
from repro.tuning.online import (EwmaTracker, INCUMBENT, ROLLED_BACK,
                                 ranked_candidates)
from repro.tuning.sweep import SweepJournal, config_key

WL = Workload(op="scan", n=512, batch=2**17, variant="lf")


def _trace_with_best(session, *, prior_ms=2.0, best_ms=1.0, other_ms=2.4,
                     best_rank=3, top_k=8, jitter=0.0, seed=0):
    """Recorded trace where the prior is prior_ms/best_ms x slower than the
    best candidate (the acceptance premise); returns (trace, prior, best)."""
    space = build_space(WL)
    prior = session.resolve_raw(WL)
    cands = ranked_candidates(space, top_k, exclude=(config_key(prior),))
    best = cands[best_rank]
    rng = np.random.default_rng(seed)
    trace = ReplayTrace(WL, source="test")

    def times(ms):
        base = ms * 1e-3
        if not jitter:
            return [base] * 40
        return list(base * (1.0 + jitter * rng.uniform(-1, 1, size=40)))

    for t in times(prior_ms):
        trace.add(prior, t)
    for i, cfg in enumerate(cands):
        for t in times(best_ms if i == best_rank else other_ms):
            trace.add(cfg, t)
    return trace, prior, best


# ---------------------------------------------------------------------------
# EWMA measurement
# ---------------------------------------------------------------------------

def test_ewma_clips_outliers():
    tr = EwmaTracker(alpha=0.25, clip=4.0)
    tr.observe(1.0)
    tr.observe(1000.0)                    # GC pause / preemption spike
    assert tr.clipped == 1
    assert tr.value <= 1.0 * (1 - 0.25) + 4.0 * 0.25 + 1e-12
    for _ in range(20):
        tr.observe(1.0)
    assert abs(tr.value - 1.0) < 0.05     # recovers fast

    with pytest.raises(ValueError, match="alpha"):
        EwmaTracker(alpha=0.0)
    with pytest.raises(ValueError, match="clip"):
        EwmaTracker(clip=1.0)


def test_first_trial_sample_clipped_against_incumbent_hint(tmp_path):
    """A startup spike on a trial's FIRST step must not kill the config:
    the tracker clips it against the incumbent's EWMA baseline."""
    tr = EwmaTracker(alpha=0.25, clip=4.0, hint=1e-3)
    tr.observe(1.0)                       # 1000x preemption spike, step one
    assert tr.clipped == 1 and tr.value <= 4e-3

    session = TunerSession(db_path=str(tmp_path / "db.json"))
    prior = session.resolve_raw(WL)
    space = build_space(WL)
    best = ranked_candidates(space, 1, exclude=(config_key(prior),))[0]
    trace = ReplayTrace(WL, source="test")
    for _ in range(30):
        trace.add(prior, 2e-3)
    trace.add(best, 2.0)                  # spike exactly on the first sample
    for _ in range(30):
        trace.add(best, 1e-3)
    tuner = OnlineTuner(WL, session, prior=prior, candidates=[best],
                        budget=32, store=False)
    res = replay(tuner, trace)
    assert res.best_config == best        # survived its noisy first step


def test_history_includes_demoted_prior(tmp_path):
    """After a promotion the original prior's measured EWMA must still be
    reported — every config that informed a decision shows up."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    trace, prior, best = _trace_with_best(session)
    tuner = OnlineTuner(WL, session, budget=64, store=False)
    res = replay(tuner, trace)
    assert res.best_config == best
    keys = {config_key(c) for c, _ in res.history}
    assert config_key(prior) in keys and config_key(best) in keys


def test_ewma_constant_stream_is_exact():
    """Deterministic samples collapse to the sample exactly (alpha=0.25 is
    fp-exact), so the compare report scores online on measured numbers."""
    tr = EwmaTracker(alpha=0.25)
    for _ in range(10):
        tr.observe(3.14159e-3)
    assert tr.value == 3.14159e-3


# ---------------------------------------------------------------------------
# Replay: convergence, guard band, persistence (acceptance criteria)
# ---------------------------------------------------------------------------

def test_replay_converges_from_2x_slower_prior(tmp_path):
    """Prior 2x slower than the best recorded config: the tuner must find
    the best within its budget, persist it to the TuningDB, and journal
    the production EWMAs."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    trace, prior, best = _trace_with_best(session, jitter=0.05)
    tuner = OnlineTuner(WL, session, budget=64, guard_band=0.25,
                        journal_dir=str(tmp_path / "journals"),
                        source="test")
    res = replay(tuner, trace)
    assert res.best_config == best
    assert tuner.promotions >= 1
    assert res.evaluations <= 64                  # strict measurement budget
    assert res.stopped_by in ("budget", "exhausted")
    # winner persisted: the serve path resolves it from here on
    assert session.lookup(WL) == best
    entry = next(iter(session.db.entries().values()))
    assert entry["method"] == "online"
    # production EWMAs journaled under the online objective identity
    journals = list((tmp_path / "journals").glob("*.jsonl"))
    assert len(journals) == 1
    journal = SweepJournal(str(journals[0]))
    header = journal.read_header()
    assert header["objective"] == "online_wallclock:test"
    assert header["pruned"] > 0                   # partial: not trainable yet
    keys = {config_key(cfg) for cfg, _ in journal.entries()}
    assert config_key(best) in keys and config_key(prior) in keys


def test_replay_never_exceeds_guard_band_mid_run(tmp_path):
    """A believed trial (>= min_samples) may never sit beyond the guard
    band: the violation step is the rollback step."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    trace, prior, best = _trace_with_best(session, other_ms=5.0,
                                          jitter=0.05)
    tuner = OnlineTuner(WL, session, budget=64, guard_band=0.25,
                        min_samples=3, store=False)
    cursors = {}
    while not tuner.finished and tuner.steps < 10_000:
        key = config_key(tuner.config())
        ts = trace.times.get(key, [PENALTY_TIME])
        t = ts[cursors.get(key, 0) % len(ts)]
        cursors[key] = cursors.get(key, 0) + 1
        tuner.observe(t)
        if tuner.trial is not None and tuner.trial.samples >= 3:
            guard = tuner.incumbent.tracker.value * 1.25
            assert tuner.trial.ewma <= guard + 1e-12, \
                "a trial beyond the guard band survived its decision step"
    # the 5x-slower candidates must have died early, at min_samples
    for rec in tuner.trials:
        if rec.state == ROLLED_BACK and rec.ewma > rec.baseline * 1.25:
            assert rec.samples <= 3


def test_replay_is_deterministic(tmp_path):
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    trace, _, _ = _trace_with_best(session, jitter=0.1)

    def run():
        tuner = OnlineTuner(WL, session, budget=48, store=False)
        res = replay(tuner, trace)
        return (res.best_config, res.best_time, res.stopped_by,
                [(t.key, t.state, t.samples) for t in tuner.trials])

    assert run() == run()


def test_unrecorded_candidate_rolls_back_on_penalty(tmp_path):
    """A config the trace never measured answers with the penalty clamp
    and must die at min_samples, not poison the incumbent."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    space = build_space(WL)
    prior = session.resolve_raw(WL)
    ghost = ranked_candidates(space, 1, exclude=(config_key(prior),))[0]
    trace = ReplayTrace(WL, source="test")
    for _ in range(20):
        trace.add(prior, 1e-3)
    tuner = OnlineTuner(WL, session, prior=prior, candidates=[ghost],
                        budget=16, min_samples=2, store=False)
    res = replay(tuner, trace)
    assert res.best_config == prior               # incumbent survived
    assert tuner.trials[0].state == ROLLED_BACK
    assert tuner.trials[0].samples == 2


def test_stopped_by_budget_vs_exhausted(tmp_path):
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    trace, _, _ = _trace_with_best(session, top_k=4)
    tight = OnlineTuner(WL, session, budget=5, samples_per_trial=4,
                        min_samples=2, store=False)
    assert replay(tight, trace).stopped_by == "budget"
    assert tight.measured <= 5
    roomy = OnlineTuner(WL, session, budget=500, top_k=4, store=False)
    assert replay(roomy, trace).stopped_by == "exhausted"
    assert len(roomy.trials) >= 4                 # every candidate trialed


def test_promotion_requires_strict_win(tmp_path):
    """Identical latencies must not churn the incumbent."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    prior = session.resolve_raw(WL)
    space = build_space(WL)
    cands = ranked_candidates(space, 3, exclude=(config_key(prior),))
    trace = ReplayTrace(WL, source="test")
    for cfg in [prior] + cands:
        for _ in range(30):
            trace.add(cfg, 1e-3)
    tuner = OnlineTuner(WL, session, budget=200, top_k=3, store=False)
    res = replay(tuner, trace)
    assert tuner.promotions == 0
    assert res.best_config == prior


def test_tuner_parameter_validation(tmp_path):
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    with pytest.raises(ValueError, match="budget"):
        OnlineTuner(WL, session, budget=0)
    with pytest.raises(ValueError, match="guard_band"):
        OnlineTuner(WL, session, guard_band=0.0)
    with pytest.raises(ValueError, match="samples_per_trial"):
        OnlineTuner(WL, session, min_samples=5, samples_per_trial=2)


def test_ranked_candidates_exclude_and_order():
    space = build_space(WL)
    all_ranked = ranked_candidates(space, 10)
    assert len(all_ranked) == 10
    head = config_key(all_ranked[0])
    without = ranked_candidates(space, 10, exclude=(head,))
    assert all(config_key(c) != head for c in without)
    assert [config_key(c) for c in without[:9]] \
        == [config_key(c) for c in all_ranked[1:10]]


# ---------------------------------------------------------------------------
# Traces
# ---------------------------------------------------------------------------

def test_replay_candidates_keep_low_ranked_recorded_configs():
    """The trace's measured winner may rank poorly analytically; replay
    candidate selection must rank the recorded set, never filter it."""
    from repro.tuning.online import replay_candidates

    space = build_space(WL)
    ranked = ranked_candidates(space, top_k=space.size())
    prior, low = ranked[0], ranked[-1]            # worst-ranked valid config
    trace = ReplayTrace(WL, source="test")
    trace.add(prior, 2e-3)
    trace.add(ranked[1], 1.8e-3)
    trace.add(low, 1e-3)                          # ...and it's the fastest
    cands = replay_candidates(space, trace, prior)
    keys = [config_key(c) for c in cands]
    assert config_key(low) in keys                # not truncated away
    assert config_key(prior) not in keys
    assert keys[0] == config_key(ranked[1])       # still expert-ordered

    # end to end: replay converges to the low-ranked recorded winner
    for _ in range(30):
        trace.add(prior, 2e-3)
        trace.add(ranked[1], 1.8e-3)
        trace.add(low, 1e-3)
    tuner = OnlineTuner(WL, session=None, prior=prior, candidates=cands,
                        budget=64, store=False)
    assert replay(tuner, trace).best_config == low


def test_trace_roundtrip_and_torn_tail(tmp_path):
    trace = ReplayTrace(WL, source="roundtrip")
    space = build_space(WL)
    cfgs = space.enumerate_valid()[:3]
    for i, cfg in enumerate(cfgs):
        for j in range(4):
            trace.add(cfg, 1e-3 * (i + 1) + 1e-6 * j)
    path = str(tmp_path / "trace.jsonl")
    trace.save(path)
    with open(path, "a") as f:
        f.write('{"k": "torn')                    # recorder killed mid-write
    loaded = ReplayTrace.load(path)
    assert loaded.workload == WL and loaded.source == "roundtrip"
    assert loaded.times == trace.times
    assert loaded.configs == trace.configs
    with pytest.raises(ValueError, match="header"):
        bad = str(tmp_path / "headerless.jsonl")
        with open(bad, "w") as f:
            f.write('{"k": "a", "cfg": {}, "t": 1.0}\n')
        ReplayTrace.load(bad)

    # two recording sessions cat'ed together must fail loudly, not
    # silently replay only the second half
    clean = str(tmp_path / "clean.jsonl")
    trace.save(clean)
    merged = str(tmp_path / "merged.jsonl")
    with open(merged, "w") as f:
        f.write(open(clean).read() + open(clean).read())
    with pytest.raises(ValueError, match="multiple headers"):
        ReplayTrace.load(merged)


# ---------------------------------------------------------------------------
# strategy="online" (the compare-report row)
# ---------------------------------------------------------------------------

def test_online_strategy_never_beats_exhaustive_and_reports_budget():
    from repro.core.exhaustive import ExhaustiveSearch

    wl = Workload(op="fft", n=256, batch=2**14, variant="stockham")
    space = build_space(wl)
    obj = CachedObjective(TPUCostModelObjective(noise=0.02))
    ex = ExhaustiveSearch().tune(space, obj)
    res = online_search(space, obj, budget=16)
    assert res.best_time >= ex.best_time - 1e-18
    assert res.evaluations <= 16
    assert res.stopped_by in ("budget", "exhausted")
    assert space.is_valid(res.best_config)


def test_online_strategy_through_session(tmp_path):
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    wl = Workload(op="tridiag", n=128, batch=2**13, variant="pcr")
    res = session.tune(wl, method="online", max_evals=12)
    assert res.stopped_by in ("budget", "exhausted")
    assert session.lookup(wl) == res.best_config
    entry = next(iter(session.db.entries().values()))
    assert entry["method"] == "online"
    # online winners are NOT exhaustive optima: the ML label exporter
    # must skip them (same contract as "exhaustive-pruned")
    from repro.tuning.ml.dataset import dataset_from_db
    assert len(dataset_from_db(session.db)) == 0


def test_online_in_compare_report():
    from repro.evaluation import check_report, compare_methods

    wls = [Workload(op="tridiag", n=128, batch=2**13, variant="pcr")]
    report = compare_methods(
        wls, methods=("analytical", "online"),
        objective_factory=lambda: TPUCostModelObjective(noise=0.02),
        seed=0, max_evals=10)
    assert check_report(report) == []
    row = report["workloads"][0]["methods"]["online"]
    assert row["slowdown"] >= 1.0 - 1e-9
    assert row["stopped_by"] in ("budget", "exhausted")


def test_incumbent_state_transitions(tmp_path):
    """Promoted trial becomes the incumbent; the demoted incumbent is
    recorded as rolled back — states stay consistent mid-flight."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    trace, prior, best = _trace_with_best(session)
    tuner = OnlineTuner(WL, session, budget=64, store=False)
    assert tuner.state() == INCUMBENT
    replay(tuner, trace)
    assert tuner.incumbent.state == INCUMBENT
    assert tuner.incumbent.config == best
    promoted = [t for t in tuner.trials if t.state == INCUMBENT]
    assert promoted and promoted[-1] is tuner.incumbent


# ---------------------------------------------------------------------------
# Fleet priors: replica journal aggregation + warm start
# ---------------------------------------------------------------------------

def _run_replica(session, journal_dir, *, seed, candidates=None):
    """One fleet replica: replay live traffic, streaming EWMAs to its own
    journal directory."""
    trace, prior, best = _trace_with_best(session, jitter=0.05, seed=seed)
    tuner = OnlineTuner(WL, session, budget=64, store=False,
                        candidates=candidates,
                        journal_dir=journal_dir, source="test")
    replay(tuner, trace)
    return tuner, prior, best


def test_fleet_aggregation_merges_replica_journals(tmp_path):
    """Three replicas with jittered traffic: the fleet estimate for each
    config is the mean of the replicas' final EWMAs, tagged with how many
    replicas measured it."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    dirs = [str(tmp_path / f"replica{i}") for i in range(3)]
    best = None
    for i, d in enumerate(dirs):
        _, _, best = _run_replica(session, d, seed=i)
    agg = aggregate_fleet(dirs, WL, source="test")
    assert agg
    bk = config_key(best)
    assert bk in agg
    cfg, mean_s, replicas = agg[bk]
    assert cfg == best and replicas == 3
    assert mean_s == pytest.approx(1e-3, rel=0.2)    # best_ms with jitter
    # the winner by fleet mean is the trace's known-best config
    assert min(agg.values(), key=lambda it: it[1])[0] == best


def test_fleet_min_replicas_filters_single_replica_flukes(tmp_path):
    """A config only one replica ever measured is dropped when the caller
    demands fleet-wide evidence."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    space = build_space(WL)
    prior = session.resolve_raw(WL)
    cands = ranked_candidates(space, 8, exclude=(config_key(prior),))
    best, extra = cands[3], cands[5]
    dirs = [str(tmp_path / "a"), str(tmp_path / "b")]
    _run_replica(session, dirs[0], seed=0, candidates=[best])
    _run_replica(session, dirs[1], seed=1, candidates=[best, extra])

    loose = aggregate_fleet(dirs, WL, source="test", min_replicas=1)
    strict = aggregate_fleet(dirs, WL, source="test", min_replicas=2)
    assert config_key(extra) in loose
    assert config_key(extra) not in strict           # one-replica fluke
    assert config_key(best) in strict                # both replicas agree
    winner, ranked = fleet_prior(dirs, WL, source="test", min_replicas=2)
    assert winner == best
    assert all(config_key(c) != config_key(extra) for c in ranked)


def test_fleet_warm_tuner_beats_cold_start(tmp_path):
    """The acceptance gate: a fresh replica warm-started from the fleet
    journals reaches its final incumbent with strictly fewer trial
    measurements than a cold replica on the same traffic."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    dirs = [str(tmp_path / f"replica{i}") for i in range(2)]
    for i, d in enumerate(dirs):
        _run_replica(session, d, seed=i)

    trace, prior, best = _trace_with_best(session, jitter=0.05, seed=9)
    cold = OnlineTuner(WL, session, budget=64, store=False, source="test")
    replay(cold, trace)
    warm = warm_tuner(WL, dirs, session, source="test", budget=64,
                      store=False)
    # the warm replica serves the fleet consensus from its first step
    assert warm.config() == best
    replay(warm, trace)

    assert cold.result().best_config == best
    assert warm.result().best_config == best
    cold_cost = measurements_to_incumbent(cold)
    warm_cost = measurements_to_incumbent(warm)
    assert cold_cost > 0                  # cold paid trials to find it
    assert warm_cost < cold_cost          # warm started on it (usually 0)


def test_promote_fleet_winner_seeds_session(tmp_path):
    """Promotion stores the fleet winner under method="fleet" and the
    session resolves it for every future engine — while the exhaustive
    dataset allowlist keeps ignoring traffic-derived entries."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    dirs = [str(tmp_path / f"replica{i}") for i in range(2)]
    for i, d in enumerate(dirs):
        _, _, best = _run_replica(session, d, seed=i)

    promoted = promote_fleet_winner(session, WL, dirs, source="test")
    assert promoted is not None
    cfg, mean_s, replicas = promoted
    assert cfg == best and replicas == 2 and mean_s > 0
    assert session.resolve_raw(WL) == best           # DB hit, not analytical
    entry = next(e for e in session.db.entries().values()
                 if e["config"] == best)
    assert entry["method"] == "fleet"
    # a fresh OnlineTuner on this session now cold-starts on the winner
    fresh = OnlineTuner(WL, session, budget=8, store=False, source="test")
    assert fresh.config() == best


def test_fleet_empty_journals_fall_back_to_cold_start(tmp_path):
    """No fleet data (empty/missing journal dirs): warm_tuner degrades to
    the normal session prior + analytical queue, so callers can pass the
    fleet directories unconditionally."""
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    dirs = [str(tmp_path / "nothing-here")]
    assert aggregate_fleet(dirs, WL, source="test") == {}
    assert fleet_prior(dirs, WL, source="test") == (None, [])
    assert promote_fleet_winner(session, WL, dirs, source="test") is None
    tuner = warm_tuner(WL, dirs, session, source="test", budget=8,
                       store=False)
    assert tuner.config() == session.resolve_raw(WL)
