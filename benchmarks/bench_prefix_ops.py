"""Paper Figs 5/6/7/8 + Table II: per-op throughput under each tuning
methodology, with Phi vs the exhaustive optimum.

Emits CSV rows: table,op,variant,N,method,metric,value,evals
  * device-model throughput for the full paper batch (2^26/N problems);
  * host wall-clock throughput for the tuned kernels at host-sized batches
    (the empirical cross-check this container can actually measure);
  * Table II rows: average throughput + Phi per (op, methodology).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (HOST_ELEMS, gflops_fft, mdata_per_s,
                               median_time, mrows_per_s, tune_all_methods)
from repro.configs.paper_ops import PREFIX_OPS, TOTAL_ELEMS
from repro.core import Workload
from repro.core.metrics import phi

METRIC = {"tridiag": ("MRows/s", mrows_per_s),
          "scan": ("MData/s", mdata_per_s),
          "fft": ("GFlops/s", gflops_fft),
          "large_fft": ("GFlops/s", gflops_fft)}


def _host_thunk(op: str, variant: str, n: int, batch: int, cfg: Dict):
    """Build a jitted host executable for the tuned op (XLA paths)."""
    rng = np.random.default_rng(0)
    if op == "scan":
        from repro.kernels.scan.ref import scan_add_ref
        x = jnp.asarray(rng.normal(size=(batch, n)), jnp.float32)
        f = jax.jit(scan_add_ref)
        f(x).block_until_ready()
        return lambda: f(x).block_until_ready()
    if op == "tridiag":
        from repro.kernels.tridiag import ops as tops
        from repro.kernels.tridiag.ref import random_system
        a, b, c, d = random_system(jax.random.PRNGKey(0), batch, n)
        f = jax.jit(lambda a, b, c, d: tops.solve(a, b, c, d,
                                                  variant=variant, config=cfg))
        f(a, b, c, d).block_until_ready()
        return lambda: f(a, b, c, d).block_until_ready()
    # fft / large_fft: pure-jnp stockham (host XLA), radix from config
    from repro.kernels.fft.ref import stockham_jnp
    x = jnp.asarray(rng.normal(size=(batch, n))
                    + 1j * rng.normal(size=(batch, n)), jnp.complex64)
    radix = cfg.get("radix", 2)
    f = jax.jit(lambda x: stockham_jnp(x, radix))
    f(x).block_until_ready()
    return lambda: f(x).block_until_ready()


def run(emit, host_wallclock: bool = True) -> None:
    fig_of = {"tridiag": "fig5", "scan": "fig6", "fft": "fig7",
              "large_fft": "fig8"}
    table2: List[str] = []
    for op, spec in PREFIX_OPS.items():
        unit, metric = METRIC[op]
        for variant in spec["variants"]:
            effs = {"analytical": [], "bayesian": []}
            perfs = {"analytical": [], "bayesian": [], "exhaustive": []}
            for n in spec["sizes"]:
                batch = max(TOTAL_ELEMS // n, 1)
                wl = Workload(op=op, n=n, batch=batch, variant=variant)
                res = tune_all_methods(wl)
                for method, r in res.items():
                    val = metric(n, batch, r["time_s"])
                    emit(f"{fig_of[op]},{op},{variant},{n},{method},"
                         f"{unit},{val:.2f},{r['evals']}")
                    perfs.setdefault(method, []).append(val)
                    if method != "exhaustive":
                        effs[method].append(r["efficiency"])
                if host_wallclock and op != "large_fft" and n <= 4096:
                    hb = max(HOST_ELEMS // n, 1)
                    cfg = res["bayesian"]["config"]
                    t = median_time(_host_thunk(op, variant, n, hb, cfg))
                    emit(f"{fig_of[op]}-host,{op},{variant},{n},host_xla,"
                         f"{unit},{metric(n, hb, t):.2f},0")
            for method in ("analytical", "bayesian"):
                avg = float(np.mean(perfs[method]))
                table2.append(
                    f"table2,{op},{variant},avg,{method},{unit},"
                    f"{avg:.2f},{phi(effs[method]):.4f}")
    for row in table2:
        emit(row)


if __name__ == "__main__":
    run(print)
