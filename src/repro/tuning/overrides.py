"""Scoped config overrides for benchmarks, tests, and experiments.

    with tuning.overrides(scan={"radix": 4}):
        prefix_sum(x)                      # resolves with radix forced to 4

Overrides stack: nested ``with`` blocks merge per-op fragments with the
innermost block winning, and every block restores the previous state on
exit (including on exceptions). The stack is thread-local, so concurrent
request threads cannot see each other's experiments.

Keys are op names (``scan``, ``tridiag``, ``fft``, ``large_fft``, ``ssd``,
``rglru``, ``attention``, ``matmul``); values are partial config dicts
merged on top of whatever the session resolves (DB hit, analytical
suggestion, or an explicit ``config=`` argument).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Iterator, Mapping, Optional

_LOCAL = threading.local()


def _stack() -> list:
    stack = getattr(_LOCAL, "stack", None)
    if stack is None:
        stack = _LOCAL.stack = []
    return stack


@contextlib.contextmanager
def overrides(**per_op: Mapping[str, int]) -> Iterator[None]:
    """Force config knobs for the ops named by keyword, within the block."""
    frame: Dict[str, Dict[str, int]] = {}
    for op, fragment in per_op.items():
        if not isinstance(fragment, Mapping):
            raise TypeError(
                f"overrides({op}=...) expects a mapping of knob -> value, "
                f"got {type(fragment).__name__}")
        frame[op] = dict(fragment)
    stack = _stack()
    stack.append(frame)
    try:
        yield
    finally:
        stack.pop()


def active_overrides(op: str) -> Optional[Dict[str, int]]:
    """Merged override fragment for ``op`` (innermost wins), or None."""
    stack = getattr(_LOCAL, "stack", None)
    if not stack:
        return None
    merged: Dict[str, int] = {}
    for frame in stack:
        fragment = frame.get(op)
        if fragment:
            merged.update(fragment)
    return merged or None


def overrides_active() -> bool:
    return bool(getattr(_LOCAL, "stack", None))
