"""Micro-benchmark: online-tuner overhead on the serving decode path.

Serves the same request load through two engines built from one model:

  * plain    — no step hooks registered (the timing branch never runs);
  * tuned    — an OnlineTuner attached: every decode step is timed, fed
               to the EWMA state machine, and wrapped in the active
               trial's config override.

Reported metric: **steady-state** per-decode-step wall time, min over
repetitions. Each repetition warms a fresh engine until its tuner's
trial phase is over (trial configs re-trace the jitted decode — a real,
bounded startup cost a production rollout pays once per candidate, not
per step), so the measured window isolates the per-step hook cost:
timer reads, EWMA bookkeeping, override plumbing. Acceptance: the tuned
engine pays **< 5%** per step over the untimed engine.

    PYTHONPATH=src python benchmarks/bench_online.py --json BENCH_ONLINE.json
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

MAX_OVERHEAD = 0.05     # the <5% per-step acceptance gate


def _make_engine(model, params, tuned: bool, *, max_batch: int, budget: int):
    from repro.core.space import Workload
    from repro.serve.engine import ServeEngine
    from repro.tuning import OnlineTuner, TunerSession, attach

    # harvest_every=1 on BOTH arms: a listener forces the tuned engine to
    # sync every step, so the plain engine must match that cadence or the
    # comparison measures async batching, not hook cost
    engine = ServeEngine(model, params, max_batch=max_batch, max_len=128,
                         harvest_every=1)
    tuner = None
    if tuned:
        wl = Workload(op="attention", n=128, batch=max_batch,
                      variant="flash")
        session = TunerSession(db_path=os.path.join(
            tempfile.mkdtemp(prefix="bench_online_"), "db.json"))
        tuner = OnlineTuner(wl, session, budget=budget, min_samples=2,
                            samples_per_trial=4, store=True)
        attach(engine, tuner)
    return engine, tuner


def _serve_load(engine, vocab: int, requests: int, max_new: int,
                seed: int) -> int:
    rng = np.random.default_rng(seed)
    for _ in range(requests):
        plen = int(rng.integers(4, 12))
        engine.submit(rng.integers(0, vocab, size=plen),
                      max_new_tokens=max_new)
    before = engine._step_index
    engine.run(max_steps=10_000)
    return engine._step_index - before


def run(emit, *, seed: int = 0, smoke: bool = False) -> float:
    from repro.configs.base import get_arch
    from repro.models.model import build_model

    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    requests = 4 if smoke else 12
    max_new = 8 if smoke else 16
    reps = 2 if smoke else 5

    # throwaway engine first: the very first decode pays one-time process
    # warmth (allocator, XLA autotuning) that would otherwise land entirely
    # on whichever mode is measured first
    _serve_load(_make_engine(model, params, False, max_batch=4, budget=1)[0],
                cfg.vocab, requests=2, max_new=4, seed=seed + 999)

    # interleave plain/tuned reps: host drift (turbo ramp, cache warmth)
    # hits both modes, not whichever ran last
    per_step = {"plain": float("inf"), "tuned": float("inf")}
    for rep in range(reps):
        # alternate which mode runs first: within a rep the second run is
        # always warmer, and a fixed order turns that into a fake win
        order = (False, True) if rep % 2 == 0 else (True, False)
        for tuned in order:
            name = "tuned" if tuned else "plain"
            engine, tuner = _make_engine(model, params, tuned, max_batch=4,
                                         budget=8)
            # warmup: compile decode AND drain the tuner's trial phase
            # (per-config re-traces are startup cost, not per-step cost)
            # outside the measured window; hooks stay live afterwards
            warm = 0
            while warm < 8 and (tuner is None or not tuner.finished):
                _serve_load(engine, cfg.vocab, requests=4, max_new=8,
                            seed=seed + 100 + rep + warm)
                warm += 1
                if tuner is None:
                    break
            assert tuner is None or tuner.finished, "trials did not drain"
            t0 = time.perf_counter()
            steps = _serve_load(engine, cfg.vocab, requests=requests,
                                max_new=max_new, seed=seed + rep)
            dt = time.perf_counter() - t0
            per_step[name] = min(per_step[name], dt / max(steps, 1))
    for name, best in per_step.items():
        emit(f"online,{name},step_us,{best*1e6:.1f}")

    overhead = per_step["tuned"] / per_step["plain"] - 1.0
    emit(f"online,overhead,frac,{overhead:.4f}")
    return overhead


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_ONLINE.json summary")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced load for CI smoke runs")
    ap.add_argument("--no-assert", action="store_true",
                    help="record the overhead without gating on it (noisy "
                         "shared CI runners)")
    args = ap.parse_args()
    rows = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    overhead = run(emit, seed=args.seed, smoke=args.smoke)
    if not args.no_assert:
        assert overhead < MAX_OVERHEAD, \
            f"online tuner costs {overhead:.1%} per decode step " \
            f"(gate: <{MAX_OVERHEAD:.0%})"
        print(f"# acceptance ok: tuner overhead {overhead:.2%} per step "
              f"(< {MAX_OVERHEAD:.0%})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "online", "seed": args.seed,
                       "smoke": bool(args.smoke), "rows": rows,
                       "summary": {"overhead_frac": overhead}},
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
