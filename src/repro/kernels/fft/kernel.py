"""Pallas TPU kernel: batched complex FFT (self-sorting Stockham, radix-r).

Complex data is carried as split re/im f32 planes (TPU VREGs are real; the
paper's BPLG similarly multiplexes real/imaginary shared-memory planes for
large tiles, §V-C). Each grid program transforms `rows_per_program` whole
problems resident in VMEM.

The staged loop is static (n, radix known at trace time): stage t views the
buffer as (rows, n_cur, s), applies the radix-rr butterfly (rr = min(radix,
n_cur) — ragged final stage = the paper's mixed-radix case) with twiddles
computed in-kernel via iota+cos/sin, and re-packs. Stage re-packs are
lane-dim permutations; on real hardware these are the index-digit layout
transforms BPLG optimizes, here delegated to Mosaic.

Tunables: rows_per_program, radix; tile_n = n (whole-problem residency);
multi-pass large-N handled by the four-step driver in ops.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _fft_kernel(re_ref, im_ref, ore_ref, oim_ref, *, n: int, radix: int,
                inverse: bool):
    rows = re_ref.shape[0]
    sign = 1.0 if inverse else -1.0
    re = re_ref[...].astype(jnp.float32)
    im = im_ref[...].astype(jnp.float32)

    n_cur, s = n, 1
    while n_cur > 1:
        rr = min(radix, n_cur)
        m = n_cur // rr
        vr = re.reshape(rows, n_cur, s)
        vi = im.reshape(rows, n_cur, s)
        parts = [(vr[:, k * m:(k + 1) * m, :], vi[:, k * m:(k + 1) * m, :])
                 for k in range(rr)]
        p = jax.lax.broadcasted_iota(jnp.float32, (1, m, 1), 1)
        outs = []
        for j in range(rr):
            tr = jnp.zeros((rows, m, s), jnp.float32)
            ti = jnp.zeros((rows, m, s), jnp.float32)
            for k in range(rr):
                ang = sign * 2.0 * math.pi * ((j * k) % rr) / rr
                wr, wi = math.cos(ang), math.sin(ang)
                pr, pi_ = parts[k]
                tr += pr * wr - pi_ * wi
                ti += pr * wi + pi_ * wr
            theta = sign * 2.0 * math.pi * j / n_cur
            twr = jnp.cos(theta * p)
            twi = jnp.sin(theta * p)
            tr, ti = _cmul(tr, ti, twr, twi)
            outs.append((tr, ti))
        re = jnp.stack([o[0] for o in outs], axis=2).reshape(rows, n)
        im = jnp.stack([o[1] for o in outs], axis=2).reshape(rows, n)
        n_cur, s = m, s * rr

    scale = (1.0 / n) if inverse else 1.0
    ore_ref[...] = (re * scale).astype(ore_ref.dtype)
    oim_ref[...] = (im * scale).astype(oim_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows_per_program", "radix",
                                             "inverse", "interpret"))
def fft_pallas(re: jax.Array, im: jax.Array, *, rows_per_program: int = 4,
               radix: int = 2, inverse: bool = False,
               interpret: bool = False):
    """Row-wise complex FFT on split planes; returns (re, im)."""
    batch, n = re.shape
    rows = rows_per_program
    grid = (batch // rows,)
    spec = pl.BlockSpec((rows, n), lambda i: (i, 0))
    kernel = functools.partial(_fft_kernel, n=n, radix=radix, inverse=inverse)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(re.shape, re.dtype)] * 2,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(re, im)
