"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation happens here: shapes/dtypes only, shardable through
the specs produced alongside. Modality frontends are stubs — audio cells
receive precomputed frame embeddings, vlm cells patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model, build_model

PyTree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def memory_struct(cfg: ModelConfig, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    if cfg.family == "audio":
        return _sds((batch, cfg.enc_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        return _sds((batch, cfg.vision_len, cfg.d_model), jnp.bfloat16)
    return None


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((b, s), jnp.int32),
        "targets": _sds((b, s), jnp.int32),
        "mask": _sds((b, s), jnp.float32),
    }
    mem = memory_struct(cfg, b)
    if mem is not None:
        batch["memory"] = mem
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32)}
    mem = memory_struct(cfg, b)
    if mem is not None:
        batch["memory"] = mem
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig
                       ) -> Tuple[Dict, PyTree]:
    """Returns (inputs, cache_struct): one new token against a seq_len cache."""
    b, s = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(b, max_len=s, dtype=jnp.bfloat16))
    inputs = {"token": _sds((b, 1), jnp.int32),
              "pos": _sds((b, 1), jnp.int32)}
    mem = memory_struct(cfg, b)
    if mem is not None:
        inputs["memory"] = mem
    return inputs, cache


def abstract_train_state(model: Model, hp) -> PyTree:
    from repro.train.step import init_train_state

    return jax.eval_shape(
        lambda: init_train_state(model, hp, jax.random.PRNGKey(0)))
