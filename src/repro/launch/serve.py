"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --requests 8 --max-new 16

Online tuning against live traffic (see docs/tuning.md "Online tuning"):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --requests 32 --online-tune --tune-op attention --tune-budget 24 \
      --record-trace artifacts/serve_trace.jsonl

``--online-tune`` attaches an :class:`repro.tuning.OnlineTuner` to the
engine's step-timing hooks: decode steps are wall-clock timed, candidate
configs run as shadowed trials (guard-banded, rolled back on slowdown),
and a promoted winner is persisted to the TuningDB. ``--record-trace``
writes every (config, step latency) pair to a JSONL trace that
``python -m repro.launch.tune online-replay`` can replay deterministically;
on its own it records PASSIVELY (the resolved incumbent config, no
trials) — combine with ``--online-tune`` to capture trial coverage.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.space import Workload
from repro.models.model import build_model
from repro.serve.engine import ServeEngine
from repro.tuning import (OnlineTuner, TraceRecorder, attach,
                          default_session, warm_tuner)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="max tokens per prefill dispatch (pow2-quantized "
                         "chunks bound jit retraces)")
    ap.add_argument("--admit-threshold", type=int, default=1,
                    help="hold admissions until this many slots free so "
                         "co-admitted prompts share prefill scans "
                         "(1 = eager/latency-first)")
    ap.add_argument("--harvest-every", type=int, default=4,
                    help="decode steps batched per device->host token "
                         "harvest when untimed")
    ap.add_argument("--max-prefill-tokens", type=int, default=None,
                    help="per-engine-step prefill token budget so long "
                         "prompts cannot starve active decoders")
    ap.add_argument("--fleet-dirs", default=None,
                    help="comma list of fleet replica journal dirs: "
                         "warm-start the online tuner from the fleet "
                         "consensus (implies --online-tune)")
    ap.add_argument("--online-tune", action="store_true",
                    help="attach an OnlineTuner to the decode step hooks")
    ap.add_argument("--tune-op", default="attention",
                    help="tuned op the online trials target (default "
                         "attention — the decode hot kernel)")
    ap.add_argument("--tune-variant", default="flash")
    ap.add_argument("--tune-budget", type=int, default=24,
                    help="measurement budget: max production steps spent "
                         "on non-incumbent configs")
    ap.add_argument("--guard-band", type=float, default=0.25,
                    help="rollback threshold: trial EWMA above "
                         "incumbent*(1+band) is abandoned")
    ap.add_argument("--journal-dir", default=None,
                    help="journal trial EWMAs here (sweep-journal format)")
    ap.add_argument("--record-trace", default=None,
                    help="record (config, step latency) pairs to this JSONL "
                         "trace for deterministic replay")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=args.max_batch,
                         max_len=args.max_len,
                         prefill_chunk=args.prefill_chunk,
                         admit_threshold=args.admit_threshold,
                         harvest_every=args.harvest_every,
                         max_prefill_tokens=args.max_prefill_tokens)

    tuner = None
    recorder = None
    if args.online_tune or args.record_trace or args.fleet_dirs:
        wl = Workload(op=args.tune_op, n=args.max_len,
                      batch=args.max_batch, variant=args.tune_variant)
        if args.record_trace:
            recorder = TraceRecorder(args.record_trace, wl)
        if args.online_tune or args.fleet_dirs:
            kwargs = dict(budget=args.tune_budget,
                          guard_band=args.guard_band,
                          journal_dir=args.journal_dir)
            if args.fleet_dirs:
                # warm start: prior = fleet consensus winner, trial queue =
                # fleet runner-ups (falls back to cold when dirs are empty)
                tuner = warm_tuner(wl, args.fleet_dirs.split(","),
                                   default_session(), **kwargs)
            else:
                tuner = OnlineTuner(wl, default_session(), **kwargs)
            attach(engine, tuner, recorder=recorder)
        else:
            # --record-trace alone is PASSIVE: time the incumbent config
            # the session already resolves, run no trials, perturb nothing
            session = default_session()
            baseline = session.resolve_raw(wl)
            engine.add_step_listener(
                lambda rec: recorder.add(baseline, rec.duration_s))

    rng = np.random.default_rng(0)
    # the engine's injectable clock (fake-able in tests) is the serving
    # stack's one time source; timing the request loop on anything else
    # would disagree with the per-step latencies the tuner/trace see
    t0 = engine.step_timer()
    for _ in range(args.requests):
        plen = int(rng.integers(4, 16))
        engine.submit(rng.integers(0, cfg.vocab, size=plen),
                      max_new_tokens=args.max_new)
    done = engine.run(max_steps=10_000)
    dt = engine.step_timer() - t0
    toks = sum(len(r.output) for r in done)
    reasons = {}
    for r in done:
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, prefill_calls={engine.prefill_calls}, "
          f"host_transfers={engine.host_transfers})")
    print("[serve] finish reasons: " + ", ".join(
        f"{k}={v}" for k, v in sorted(reasons.items())))
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> out[:8]={r.output[:8]}")
    if tuner is not None:
        s = tuner.summary()
        ewma = s["incumbent_ewma_s"]
        print(f"[online] state={s['state']} stopped_by={s['stopped_by']} "
              f"steps={s['steps']} measured={s['measured']}/{s['budget']} "
              f"promotions={s['promotions']}")
        if ewma:
            print(f"[online] incumbent {s['incumbent']} "
                  f"ewma={ewma*1e3:.2f}ms")
        for t in s["trials"]:
            print(f"[online]   trial {t['config']} -> {t['state']} "
                  f"(samples={t['samples']})")
    if recorder is not None:
        print(f"[online] trace: {recorder.records} records "
              f"-> {args.record_trace}")


if __name__ == "__main__":
    main()
