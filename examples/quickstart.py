"""Quickstart: tune a kernel offline, use it online — the paper's flow,
through the `repro.tuning` session API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Workload
from repro.kernels.scan.ops import prefix_sum
from repro.kernels.scan.ref import scan_add_ref
from repro.tuning import TunerSession, overrides

session = TunerSession(db_path="/tmp/quickstart_db.json")

# 1. offline: Bayesian-optimization search on the TPU device model
wl = Workload(op="scan", n=1024, batch=65536, variant="ks")
result = session.tune(wl, method="bayesian")
print(f"offline BO: best={result.best_config} "
      f"t={result.best_time*1e6:.1f}us evals={result.evaluations}")

# 2. online: resolve() reads the DB (or falls back to the zero-evaluation
#    analytical model for unseen workloads) and caches the resolved config
cfg = session.resolve(wl)
print(f"online config: {cfg}")

# 3. run the tuned kernel (interpret mode validates the Pallas body on CPU)
x = jnp.asarray(np.random.default_rng(0).normal(size=(16, 1024)), jnp.float32)
y = prefix_sum(x, config=cfg, interpret=True)
err = float(jnp.max(jnp.abs(y - scan_add_ref(x))))
print(f"tuned scan matches oracle: max_err={err:.2e}")

# 4. an unseen workload: analytical answer, no evaluations needed
wl2 = Workload(op="scan", n=2048, batch=32768, variant="ks")
print(f"online (analytical, cold): {session.resolve(wl2)}")

# 5. scoped experiments: force knobs without touching the DB
with overrides(scan={"radix": 4}):
    y4 = prefix_sum(x, interpret=True)
print(f"override radix=4 matches: "
      f"{float(jnp.max(jnp.abs(y4 - scan_add_ref(x)))):.2e}")
