"""Reference serving engine: the per-token replay/host-loop baseline.

This is the pre-throughput-rework :class:`~repro.serve.engine.ServeEngine`
preserved as an executable specification.  It prefills a prompt by
replaying it one token at a time through the full-batch jitted decode fn
and round-trips tokens/positions/logits through host numpy on every step —
exactly the semantics the optimized engine must reproduce, at exactly the
cost it must beat:

  * ``tests/test_serve_prefill.py`` proves the optimized engine's
    single-dispatch prefill leaves the target slot's cache lanes
    **bit-identical** to this engine's replay, and that decoded tokens
    match bit-for-bit end to end.
  * ``benchmarks/bench_serving.py`` gates the optimized engine's
    tokens/sec against this engine on a multi-tenant trace.

Scheduling semantics are shared with the optimized engine (same
slot-based lockstep batching, same completion rules, same
submission-order ``run()`` contract, same ``finish_reason``); only the
execution strategy differs.  One deliberate difference: this engine runs
*every* batch lane through every replay/decode step, so inactive lanes'
recurrent/SSM state advances on padding work (harmless for KV caches,
whose stale tail is masked by position, but real cross-request pollution
for state-carrying archs) — the optimized engine lane-masks instead,
which is why the differential test compares the *target* slot's lanes.
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serve.engine import FINISH_LENGTH, FINISH_STOP, Request

PyTree = Any


class ReferenceEngine:
    """Seed-semantics engine: O(prompt_len) replay prefill, host-loop decode."""

    def __init__(self, model: Model, params: PyTree, max_batch: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0,
                 step_timer: Optional[Callable[[], float]] = None,
                 cache_dtype=jnp.float32):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        self.cache = model.init_cache(max_batch, max_len, dtype=cache_dtype)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(model.decode_step)
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.step_timer: Callable[[], float] = step_timer or time.perf_counter
        self._step_index = 0

    # -- public API --
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt: need at least one token")
        rid = len(self.queue) + len(self.completed) + sum(
            r is not None for r in self.slot_req)
        self.queue.append(Request(rid, prompt, max_new_tokens))
        return rid

    def run(self, max_steps: int = 1000) -> List[Request]:
        """Serve until the queue drains; results in submission order."""
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self._admit()
            self._decode_step()
            self._step_index += 1
            steps += 1
        return sorted(self.completed, key=lambda r: r.rid)

    # -- internals --
    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None:
                continue
            while self.queue:
                req = self.queue.pop(0)
                if np.asarray(req.prompt).size == 0:
                    req.done = True
                    req.finish_reason = FINISH_STOP
                    self.completed.append(req)
                    continue
                self.slot_req[slot] = req
                # replay prompt through decode to build this slot's cache
                for t, tok in enumerate(req.prompt[:-1]):
                    self._step_slot(slot, int(tok), t)
                self.slot_pos[slot] = len(req.prompt) - 1
                break

    def _step_slot(self, slot: int, token: int, pos: int) -> int:
        """Single-slot step via the batched decode fn (other slots run
        their current token as padding work — lockstep batching)."""
        tokens = np.zeros((self.max_batch, 1), np.int32)
        poss = np.maximum(self.slot_pos[:, None], 0).astype(np.int32)
        tokens[slot, 0] = token
        poss[slot, 0] = pos
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.asarray(poss))
        return int(np.argmax(np.asarray(logits)[slot]))

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits_row))
        z = logits_row / self.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self.rng.choice(len(p), p=p))

    def _decode_step(self) -> None:
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tokens = np.zeros((self.max_batch, 1), np.int32)
        poss = np.maximum(self.slot_pos[:, None], 0).astype(np.int32)
        for s in active:
            req = self.slot_req[s]
            last = (req.output[-1] if req.output
                    else int(req.prompt[-1]))
            tokens[s, 0] = last
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.asarray(poss))
        logits = np.asarray(logits)
        for s in active:
            req = self.slot_req[s]
            nxt = self._sample(logits[s])
            req.output.append(nxt)
            self.slot_pos[s] += 1
            if (len(req.output) >= req.max_new_tokens
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                req.finish_reason = (
                    FINISH_STOP if len(req.output) >= req.max_new_tokens
                    else FINISH_LENGTH)
                self.completed.append(req)
                self.slot_req[s] = None
