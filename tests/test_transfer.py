"""Transfer (multi-task) tuning: GPTune's cross-size amortization."""
import numpy as np

from repro.core import (BayesianTuner, CachedObjective, ExhaustiveSearch,
                        TPUCostModelObjective, Workload, build_space)
from repro.core.transfer import TaskHistory, TransferBayesianTuner, \
    tune_family


def _obj():
    return CachedObjective(TPUCostModelObjective(noise=0.02))


def test_transfer_reduces_evaluations_at_equal_quality():
    sizes = [128, 256, 512, 1024]
    fam = tune_family("scan", "lf", sizes, lambda n: 2**26 // n, _obj,
                      seed=0)
    effs_t, tot_t = [], 0
    effs_p, tot_p = [], 0
    for n in sizes:
        sp = build_space(Workload(op="scan", n=n, batch=2**26 // n,
                                  variant="lf"))
        best = ExhaustiveSearch().tune(sp, _obj()).best_time
        tot_t += fam[n].evaluations
        effs_t.append(min(best / fam[n].best_time, 1.0))
        bo = BayesianTuner(seed=0).tune(sp, _obj())
        tot_p += bo.evaluations
        effs_p.append(min(best / bo.best_time, 1.0))
    assert tot_t < tot_p                       # fewer evaluations...
    assert np.mean(effs_t) > np.mean(effs_p) - 0.02   # ...no quality loss


def test_transfer_without_history_still_works():
    wl = Workload(op="fft", n=512, batch=2**17, variant="stockham")
    sp = build_space(wl)
    res = TransferBayesianTuner(seed=1).tune(sp, _obj(), histories=())
    assert sp.is_valid(res.best_config)
