"""Adafactor (factored second moment) — memory-frugal option for 34B/90B.

Row/column factored accumulators: O(n+m) state per (n, m) matrix instead of
Adam's O(nm) fp32 pair. Vectors keep full second moment.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdafactorState(NamedTuple):
    vr: PyTree      # row accumulators (or full v for <2D)
    vc: PyTree      # col accumulators (zeros for <2D)
    count: jax.Array


def adafactor(lr: Callable | float, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params: PyTree) -> AdafactorState:
        def vr_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(jax.tree.map(vr_init, params),
                              jax.tree.map(vc_init, params),
                              jnp.zeros((), jnp.int32))

    def update(grads: PyTree, state: AdafactorState, params: PyTree
               ) -> Tuple[PyTree, AdafactorState]:
        count = state.count + 1
        t = count.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        step_lr = lr_fn(count)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.einsum("...r,...c->...rc", vr, vc)
                denom = denom / jnp.clip(
                    jnp.mean(vr, axis=-1)[..., None, None], 1e-30)
                u = g / jnp.sqrt(denom + eps)
            else:
                vr = beta * vr + (1 - beta) * g2
                u = g / jnp.sqrt(vr + eps)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_lr * u).astype(p.dtype), vr, vc

        flat = jax.tree.map(upd, grads, state.vr, state.vc, params)
        is_t = lambda x: isinstance(x, tuple)
        return (jax.tree.map(lambda x: x[0], flat, is_leaf=is_t),
                AdafactorState(jax.tree.map(lambda x: x[1], flat, is_leaf=is_t),
                               jax.tree.map(lambda x: x[2], flat, is_leaf=is_t),
                               count))

    return init, update
