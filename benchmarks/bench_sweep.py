"""Micro-benchmark: vectorized sweep engine vs the seed per-config loop.

The seed's ``ExhaustiveSearch`` walked the valid space one Python
``objective(space, cfg)`` call at a time; the sweep engine pushes the whole
candidate set through ``Objective.batch_eval`` (numpy array ops on the
cost model).  This bench times both on the paper-suite's biggest spaces
and asserts the acceptance criterion (batched >= 10x faster), emitting
CSV rows and an optional BENCH_SWEEP.json artifact.

    PYTHONPATH=src python benchmarks/bench_sweep.py --json BENCH_SWEEP.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import TPUCostModelObjective, Workload, build_space
from repro.core.bayesian import TuneResult
from repro.core.exhaustive import ExhaustiveSearch
from repro.core.objective import PENALTY_TIME

# the spaces exhaustive sweeps actually spend their wall-clock in: the
# scan family's big (tile x rows x radix x unroll x shuffle) products
WORKLOADS = [
    Workload(op="scan", n=8192, batch=2**17, variant="lf"),
    Workload(op="scan", n=4096, batch=2**17, variant="ks"),
    Workload(op="ssd", n=1024, batch=2**16),
    Workload(op="rglru", n=4096, batch=2**17),
]


def seed_tune(space, objective) -> TuneResult:
    """The seed ExhaustiveSearch.tune, verbatim: one Python objective call
    per config (kept here as the benchmark baseline)."""
    history = []
    best_cfg, best_t = None, float("inf")
    for cfg in space.enumerate_valid():
        m = objective(space, cfg)
        t = m.time_s if m.valid else PENALTY_TIME
        history.append((cfg, t))
        if t < best_t:
            best_cfg, best_t = cfg, t
    return TuneResult(best_cfg, best_t, len(history), history, "exhausted")


def run(emit, reps: int = 7) -> float:
    worst = float("inf")
    for wl in WORKLOADS:
        space = build_space(wl)
        objective = TPUCostModelObjective()
        size = space.size()   # warm the enumeration for both contenders

        # best-of-reps: the minimum is the honest cost of each contender on
        # a noisy shared host (scheduler hiccups only ever add time)
        t_loop = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            baseline = seed_tune(space, objective)
            t_loop = min(t_loop, time.perf_counter() - t0)

        engine = ExhaustiveSearch()
        t_sweep = float("inf")
        # the sweep side is ~15x cheaper per rep: buy a much tighter minimum
        # with extra reps so one scheduler hiccup can't fake a regression
        for _ in range(reps * 3):
            t0 = time.perf_counter()
            result = engine.tune(space, objective)
            t_sweep = min(t_sweep, time.perf_counter() - t0)

        assert result.best_config == baseline.best_config \
            and result.best_time == baseline.best_time \
            and np.array_equal(np.asarray([t for _, t in baseline.history]),
                               np.asarray([t for _, t in result.history])), \
            f"sweep result diverged from the per-config loop on {wl.key}"
        speedup = t_loop / max(t_sweep, 1e-12)
        worst = min(worst, speedup)
        tag = f"{wl.op}:{wl.variant or 'default'}:n{wl.n}"
        emit(f"sweep,{tag},space,{size}")
        emit(f"sweep,{tag},loop_ms,{t_loop*1e3:.2f}")
        emit(f"sweep,{tag},batched_ms,{t_sweep*1e3:.2f}")
        emit(f"sweep,{tag},speedup,{speedup:.1f}")
    return worst


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_SWEEP.json summary")
    ap.add_argument("--seed", type=int, default=0,
                    help="accepted for CLI uniformity; the cost model is "
                         "deterministic")
    ap.add_argument("--no-assert", action="store_true",
                    help="record the speedup without gating on it (noisy "
                         "shared CI runners; the pytest suite enforces the "
                         "criterion)")
    args = ap.parse_args()
    rows = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    worst = run(emit)
    if not args.no_assert:
        assert worst >= 10, \
            f"vectorized sweep only {worst:.1f}x faster than per-config loop"
        print(f"# acceptance ok: worst-case speedup {worst:.1f}x (>= 10x)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "sweep", "seed": args.seed, "rows": rows,
                       "summary": {"worst_speedup": worst}},
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
