"""Analytic FLOP/byte counting from the jaxpr (trip-count exact).

XLA:CPU's compiled.cost_analysis() counts a `while` body ONCE, so any
lax.scan-over-layers model is undercounted by its depth (granite-34b: 88x).
The jaxpr still has the structure — scan carries its `length` — so this
module walks the closed jaxpr and produces:

  flops — 2*M*N*K per dot_general, small constants for elementwise /
          transcendental ops, multiplied through scan lengths (remat'd
          backward recompute appears as explicit eqns, so recompute is
          counted, as it should be for a compute-roofline);
  bytes — an HBM-traffic model of the XLA TPU path: dot_general counts
          operands + result (matmul tiles stream through HBM; attention
          score tensors ARE materialized on the non-flash path — switching
          to the flash Pallas kernel removes exactly that traffic, which is
          the §Perf lever), gathers/scatters/cache updates count their
          outputs, scans count stacked xs/ys once plus length x body, and
          elementwise/transcendental chains are assumed fused (0 bytes).

This is the framework's deterministic cost layer; the roofline uses it for
the compute/memory terms and cross-checks against cost_analysis().
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

_TRANSCENDENTAL = {"exp", "log", "tanh", "logistic", "sin", "cos", "rsqrt",
                   "sqrt", "erf", "log1p", "expm1", "pow", "cumsum",
                   "cumprod", "cumlogsumexp"}
_CHEAP = {"add", "sub", "mul", "div", "max", "min", "neg", "abs", "and",
          "or", "not", "xor", "select_n", "ge", "gt", "le", "lt", "eq",
          "ne", "sign", "floor", "ceil", "round", "clamp", "rem",
          "integer_pow", "square"}
_FREE = {"reshape", "broadcast_in_dim", "transpose", "convert_element_type",
         "squeeze", "slice", "concatenate", "pad", "iota", "rev",
         "dynamic_slice", "dynamic_update_slice", "gather", "scatter",
         "scatter-add", "bitcast_convert_type", "stop_gradient", "copy",
         "sharding_constraint", "reduce_sum", "reduce_max", "reduce_min",
         "argmax", "argmin", "reduce_and", "reduce_or", "top_k", "sort"}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _nelems(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs = eqn.invars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    out = eqn.outvars[0].aval
    return 2 * _nelems(out) * max(k, 1)


def _dot_bytes(eqn) -> int:
    return (_nbytes(eqn.invars[0].aval) + _nbytes(eqn.invars[1].aval)
            + _nbytes(eqn.outvars[0].aval))


def _sub_jaxprs(eqn):
    for key in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr",
                "branches", "fun_jaxpr"):
        if key in eqn.params:
            v = eqn.params[key]
            if key == "branches":
                for b in v:
                    yield b
            elif v is not None:
                yield v


def _walk(jaxpr, mult: float, acc: Dict[str, float]) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            acc["flops"] += mult * _dot_flops(eqn)
            acc["bytes"] += mult * _dot_bytes(eqn)
            acc["dot_count"] += mult
        elif name == "scan":
            length = eqn.params.get("length", 1)
            inner = eqn.params["jaxpr"]
            _walk(inner.jaxpr if hasattr(inner, "jaxpr") else inner,
                  mult * length, acc)
            # stacked xs/ys are read/written once in total
            for v in list(eqn.invars) + list(eqn.outvars):
                acc["bytes"] += mult * _nbytes(v.aval)
        elif name == "while":
            # only bounded fori-style loops appear (none in our models);
            # treat conservatively as one iteration
            for sub in _sub_jaxprs(eqn):
                _walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub, mult, acc)
        else:
            recursed = False
            for sub in _sub_jaxprs(eqn):
                _walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub, mult, acc)
                recursed = True
            out_elems = sum(_nelems(v.aval) for v in eqn.outvars)
            out_bytes = sum(_nbytes(v.aval) for v in eqn.outvars)
            if not recursed:
                if name in _TRANSCENDENTAL:
                    acc["flops"] += mult * 4 * out_elems
                elif name in _CHEAP:
                    acc["flops"] += mult * out_elems
                elif name.startswith("reduce") or name in ("cumsum",):
                    acc["flops"] += mult * out_elems
                # HBM traffic only at materialization points — gathers,
                # scatters, KV-cache updates; fused elementwise chains are
                # free (XLA fuses them into the surrounding dots/reduces)
                if name in ("gather", "scatter", "scatter-add",
                            "dynamic_update_slice", "sort", "top_k"):
                    acc["bytes"] += mult * out_bytes


def analyze_jaxpr(fn, *abstract_args) -> Dict[str, float]:
    """Counts over jax.make_jaxpr(fn)(*abstract_args)."""
    jaxpr = jax.make_jaxpr(fn)(*abstract_args)
    acc = {"flops": 0.0, "bytes": 0.0, "dot_count": 0.0}
    _walk(jaxpr.jaxpr, 1.0, acc)
    # entry arguments (params etc.) are read once
    acc["bytes"] += sum(_nbytes(v.aval) for v in jaxpr.jaxpr.invars)
    return acc
