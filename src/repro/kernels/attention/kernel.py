"""Pallas TPU kernel: flash attention with tunable block sizes.

Online-softmax tiling (Dao et al., re-tiled for the MXU): grid
(batch*heads, Lq/block_q, Lk/block_k) with the key dimension sequential per
core; VMEM scratch carries the running max/denominator/accumulator. block_q
and block_k are the tuned parameters (op="attention" search space) — the
beyond-paper application of the paper's methodology to the framework's
hottest kernel.

Causal and local-window (RecurrentGemma) masks are computed from global
positions; with causal masking, fully-masked k-blocks are skipped via
pl.when (the occupancy analogue of not launching dead threadblocks).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, lq: int, lk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global positions; queries occupy the LAST lq slots of the kv stream
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (lk - lq)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # a block is live unless its whole score tile is masked out
    live = jnp.bool_(True)
    if causal:
        live &= (ki * block_k) <= (qi * block_q + (lk - lq) + block_q - 1)
    if window is not None:
        live &= ((ki + 1) * block_k - 1) > (qi * block_q + (lk - lq) - window)

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "window", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           block_q: int = 256, block_k: int = 256,
                           causal: bool = True,
                           window: Optional[int] = None,
                           interpret: bool = False) -> jax.Array:
    """q: (BH, Lq, D), k/v: (BH, Lk, D) -> (BH, Lq, D)."""
    BH, lq, d = q.shape
    lk = k.shape[1]
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    grid = (BH, lq // block_q, lk // block_k)
    scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, lq=lq, lk=lk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j, t: (i, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j, t: (i, t, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j, t: (i, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j, t: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
