"""The paper's own workload set: parallel-prefix operations on batched
problem sizes (paper §V/§VI). Used by the benchmark harness."""
PREFIX_OPS = {
    "scan": {"variants": ["lf", "ks"], "sizes": [128, 256, 512, 1024, 2048, 4096]},
    "tridiag": {"variants": ["cr", "pcr", "lf", "wm"],
                "sizes": [64, 128, 256, 512, 1024]},
    "fft": {"variants": ["stockham"], "sizes": [64, 128, 256, 512, 1024, 2048, 4096]},
    "large_fft": {"variants": ["stockham"],
                  "sizes": [8192, 65536, 1048576, 8388608]},
}
TOTAL_ELEMS = 2 ** 26   # paper: batch = 2^26 / N problems per invocation
