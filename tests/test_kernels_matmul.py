"""Tiled matmul kernel vs jnp.dot."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (256, 256, 256, 128, 128, 128),
    (256, 384, 512, 128, 256, 128),
    (128, 128, 128, 128, 128, 128),
    (512, 256, 256, 256, 128, 256),
])
def test_matmul_block_sweep(m, k, n, bm, bn, bk):
    a = jax.random.normal(KEY, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), jnp.float32)
    got = matmul(a, b, config={"block_m": bm, "block_n": bn, "block_k": bk},
                 interpret=True)
    np.testing.assert_allclose(got, matmul_ref(a, b), rtol=1e-4, atol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    a = jax.random.normal(KEY, (128, 128), dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (128, 128), dtype)
    got = matmul(a, b, config={"block_m": 128, "block_n": 128,
                               "block_k": 128}, interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(matmul_ref(a, b), np.float32),
                               rtol=tol, atol=tol * 20)
