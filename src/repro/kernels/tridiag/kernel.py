"""Pallas TPU kernel: batched Parallel Cyclic Reduction (PCR) tridiagonal solve.

Each grid program solves `rows_per_program` independent systems of size n
kept fully VMEM-resident (the paper's BPLG requirement that the problem fit
shared memory maps to the whole system fitting the VMEM block; each element
carries 4 coefficients, matching the paper's accounting).

PCR runs ceil(log2 n) full-width reduction steps; after the last step every
equation is decoupled: x_i = d_i / b_i. Shifted neighbour access is a
lane-dim `concatenate` with identity fill (b=1 so the pivots stay finite;
a/c/d fill 0 so out-of-range terms vanish).

Tunables: rows_per_program (DMA block height), unroll (fold grouping hint),
in_register (skip scratch; systems solved wholly in VREG tiles). PCR's radix
is fixed at 2 (paper §V-A: only WM admits radix retuning).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams
from repro.kernels.blocks import primitives as prim


def _pcr_kernel(a_ref, b_ref, c_ref, d_ref, x_ref, *, n: int, unroll: int):
    del unroll
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)

    steps = max(1, math.ceil(math.log2(n)))
    stride = 1
    for _ in range(steps):
        a, b, c, d = prim.pcr_step(a, b, c, d, stride)
        stride *= 2
    x_ref[...] = (d / b).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows_per_program", "unroll",
                                             "interpret"))
def pcr_pallas(a: jax.Array, b: jax.Array, c: jax.Array, d: jax.Array, *,
               rows_per_program: int = 8, unroll: int = 1,
               interpret: bool = False) -> jax.Array:
    batch, n = a.shape
    rows = rows_per_program
    grid = (batch // rows,)
    spec = pl.BlockSpec((rows, n), lambda i: (i, 0))
    kernel = functools.partial(_pcr_kernel, n=n, unroll=unroll)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(a, b, c, d)
