"""Tuned matmul entry point (TunerSession-driven block shapes)."""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.space import Workload, fit_block, matmul_space
from repro.kernels.matmul.kernel import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref
from repro.tuning import default_session, plan_execution, tuned_kernel


def _normalize(cfg, wl, dims=None):
    """Fit block shapes to (M, N, K); wl carries batch=M, n=N and the entry
    point passes K through ``dims``."""
    dims = dims or {}
    m = int(dims.get("m", wl.batch))
    k = int(dims.get("k", wl.n))
    return {"block_m": fit_block(cfg.get("block_m", 256), m),
            "block_n": fit_block(cfg.get("block_n", 256), wl.n),
            "block_k": fit_block(cfg.get("block_k", 256), k)}


@tuned_kernel("matmul", space=matmul_space, pallas=matmul_pallas,
              reference=matmul_ref, normalize=_normalize, variants=("tiled",))
def matmul(a: jax.Array, b: jax.Array, config: Optional[dict] = None,
           interpret: Optional[bool] = None,
           use_pallas: Optional[bool] = None) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    use_pallas, interpret = plan_execution(use_pallas, interpret)
    if not use_pallas:
        return matmul_ref(a, b)
    cfg = default_session().resolve(
        Workload(op="matmul", n=n, batch=m, variant="tiled"),
        config=config, dims={"m": m, "k": k})
    return matmul_pallas(a, b, interpret=interpret, **cfg)
