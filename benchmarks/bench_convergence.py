"""Paper Fig 4: candidate evaluations the ML-based search needs per size
(including the large multi-pass FFT spaces where BO shines)."""
from __future__ import annotations

from benchmarks.common import NOISE
from repro.core import (BayesianTuner, CachedObjective, ExhaustiveSearch,
                        RandomSearch, TPUCostModelObjective, Workload,
                        build_space)
from repro.core.multikernel import MultiPassObjective


def run(emit) -> None:
    cases = [("tridiag", "wm", [64, 128, 256, 512, 1024]),
             ("scan", "lf", [64, 128, 256, 512, 1024, 2048, 4096]),
             ("fft", "stockham", [64, 256, 1024, 4096])]
    for op, variant, sizes in cases:
        for n in sizes:
            wl = Workload(op=op, n=n, batch=max(2**26 // n, 1),
                          variant=variant)
            space = build_space(wl)
            bo = BayesianTuner(seed=0).tune(
                space, CachedObjective(TPUCostModelObjective(noise=NOISE)))
            emit(f"fig4,{op},{variant},{n},bayesian,evals,"
                 f"{bo.evaluations},{space.size()}")

    # fig 4d: large FFT multi-pass spaces
    for n in [2**13, 2**16, 2**19, 2**20, 2**23]:
        wl = Workload(op="large_fft", n=n, batch=max(2**26 // n, 1),
                      variant="stockham")
        space = build_space(wl)
        bo = BayesianTuner(seed=0).tune(
            space, CachedObjective(MultiPassObjective(
                TPUCostModelObjective(noise=NOISE))))
        emit(f"fig4d,large_fft,stockham,{n},bayesian,evals,"
             f"{bo.evaluations},{space.size()}")

    # search-quality control: BO vs random at matched budgets (one size)
    wl = Workload(op="scan", n=1024, batch=2**16, variant="lf")
    space = build_space(wl)
    ex = ExhaustiveSearch().tune(
        space, CachedObjective(TPUCostModelObjective(noise=NOISE)))
    for seed in range(5):
        bo = BayesianTuner(seed=seed).tune(
            space, CachedObjective(TPUCostModelObjective(noise=NOISE)))
        rnd = RandomSearch(max_evals=bo.evaluations, seed=seed).tune(
            space, CachedObjective(TPUCostModelObjective(noise=NOISE)))
        emit(f"fig4-control,scan,lf,1024,bo_vs_random_seed{seed},eff,"
             f"{min(ex.best_time/bo.best_time,1.0):.4f},"
             f"{min(ex.best_time/rnd.best_time,1.0):.4f}")


if __name__ == "__main__":
    run(print)
