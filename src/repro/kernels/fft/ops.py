"""Tuned FFT entry points: in-VMEM Stockham + four-step large-N driver.

`fft(x)` — x complex (batch, n):
  * n <= max in-VMEM tile: single Stockham kernel launch, radix/rows from
    the TunerSession (paper §V-C small/medium sizes);
  * larger n: the op="large_fft" workload resolves through the same
    session and its StagePlan describes the Bailey four-step decomposition
    N = n1*n2 — executed by ``repro.kernels.blocks.driver.four_step_fft``
    (the paper's §IV-C multi-kernel strategy with m kernels; the tile
    split n1 comes from the tuned `tile_n`).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.space import Workload, fft_space, large_fft_space
from repro.core.multikernel import max_resident_tile
from repro.kernels.blocks import driver
from repro.kernels.blocks.plan import plan_for
from repro.kernels.fft.kernel import fft_pallas
from repro.kernels.fft.ref import fft_ref
from repro.tuning import default_session, on_cpu, tuned_kernel


def _normalize(cfg, wl, dims=None):
    """Raw Stockham knobs; rows are re-fitted per sub-launch (the four-step
    path runs the kernel at several different sub-batch sizes)."""
    return {"radix": cfg.get("radix", 2),
            "rows_per_program": cfg.get("rows_per_program", 4),
            "tile_n": cfg.get("tile_n", 2048)}


@tuned_kernel("fft", space=fft_space, pallas=fft_pallas, reference=fft_ref,
              normalize=_normalize, variants=("stockham",))
def fft(x: jax.Array, config: Optional[dict] = None,
        interpret: Optional[bool] = None, inverse: bool = False) -> jax.Array:
    batch, n = x.shape
    interpret = on_cpu() if interpret is None else interpret
    session = default_session()
    wl_small = Workload(op="fft", n=n, batch=batch, variant="stockham")
    max_tile = max_resident_tile(wl_small)
    if n <= max_tile:
        cfg = session.resolve(wl_small, config=config)
        plan = plan_for(wl_small, cfg)
        return driver.dispatch_fft(x, plan, inverse=inverse,
                                   interpret=interpret)

    # ---- four-step multi-kernel path (plan-driven) ----
    wl = Workload(op="large_fft", n=n, batch=batch, variant="stockham")
    cfg = session.resolve(wl, config=config)
    plan = plan_for(wl, cfg, max_tile=max_tile)
    return driver.four_step_fft(x, plan, inverse=inverse, interpret=interpret)


# the four-step driver resolves op="large_fft" through the same session;
# register its space under that name too
tuned_kernel("large_fft", space=large_fft_space, pallas=fft_pallas,
             reference=fft_ref, normalize=_normalize,
             variants=("stockham",))(fft)


def ifft(x: jax.Array, config: Optional[dict] = None,
         interpret: Optional[bool] = None) -> jax.Array:
    return fft(x, config=config, interpret=interpret, inverse=True)
