"""Methodology comparison against the exhaustive optimum (paper Table II).

For every workload the exhaustive sweep supplies the ground-truth optimum;
each methodology (analytical / ml / online / bayesian / random / ...) is
then scored on the SAME cached objective, so every reported time is a time
the sweep actually measured.  That construction makes the report a bug detector:
performance efficiency is ``best_time / achieved_time`` and can only
exceed 1.0 — "a methodology beat exhaustive search" — if the sweep, the
cache, or a strategy mishandled the objective.  ``check_report`` turns any
such violation (equivalently Phi > 1) into a CI failure.

Emitted metrics per (op, methodology) and overall:

  * **Phi** — the harmonic-mean performance-portability metric
    (``repro.core.metrics``), computed raw (no clamping) so violations
    surface;
  * **mean/max slowdown** — achieved time / optimum;
  * **evaluation counts** — what each methodology paid for its answer
    (the paper's Fig-4 axis).

``policies`` extends the table to the multi-objective setting: for every
non-latency policy (``energy``, ``edp``, ``memory_cap`` — see
:mod:`repro.core.policy`) the full sweep's metric vectors define the
policy optimum, each method re-runs on a
:class:`~repro.core.policy.PolicyObjective` wrapper of the SAME cache,
and a per-(method, policy) Phi lands in ``report["per_policy"]`` — any
cell above 1 is a violation exactly like the latency gate.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.exhaustive import ExhaustiveSearch
from repro.core.objective import (CachedObjective, CostModelObjective,
                                  Objective)
from repro.core.policy import PolicyObjective, get_policy, policy_scalar_cols
from repro.core.space import Workload, build_space
from repro.hw.profiles import HardwareProfile, get_profile
from repro.tuning.session import get_strategy

DEFAULT_METHODS = ("analytical", "ml", "online", "bayesian", "random")

# device-matrix default: tpu_v5e first so its journals exist when the
# transfer strategy runs on the other devices
DEFAULT_MATRIX_PROFILES = ("tpu_v5e", "gpu_sm", "cpu_interpret")
DEFAULT_MATRIX_METHODS = ("analytical", "bayesian", "transfer")

# efficiencies this far above 1.0 are fp-noise, beyond it a violation
EFFICIENCY_EPS = 1e-9


def evals_to_optimum(history: Sequence[tuple], best_time: float) -> Optional[int]:
    """Evaluations spent until the search first measured the optimum.

    1-based index of the first history entry within fp-noise of
    ``best_time`` (the exhaustive optimum); None when the search never
    reached it — the matrix's evaluations-to-Phi<=1 cell.
    """
    for i, (_, t) in enumerate(history):
        if t <= best_time * (1.0 + EFFICIENCY_EPS):
            return i + 1
    return None


def _phi_raw(efficiencies: Sequence[float]) -> float:
    """Harmonic mean WITHOUT the (0, 1] range check of metrics.phi — a
    Phi > 1 here is exactly the signal check_report exists to catch."""
    return len(efficiencies) / sum(1.0 / max(e, 1e-12) for e in efficiencies)


def compare_methods(workloads: Iterable[Workload],
                    methods: Sequence[str] = DEFAULT_METHODS,
                    objective_factory: Optional[Callable[[], Objective]] = None,
                    *, seed: int = 0, max_evals: int = 20,
                    journal_dir: Optional[str] = None,
                    profile: Optional[HardwareProfile] = None,
                    policies: Sequence[str] = ("latency",)) -> Dict:
    """Run every methodology against the exhaustive optimum.

    One ``CachedObjective`` per workload is shared by the sweep and every
    strategy, so all methods are scored on identical measurements (and the
    non-exhaustive strategies' repeat visits are cache hits, not new
    evaluations — their ``evaluations`` field still reports what each
    method would have paid standalone).

    ``profile`` bounds the spaces and (absent an explicit factory) the
    cost model by that device; default is the process-wide active profile.

    ``policies`` adds per-policy scoring: the base table is always the
    latency one; each non-latency entry re-runs every method on a
    :class:`~repro.core.policy.PolicyObjective` over the same cache and
    scores it against that policy's scalarized optimum (the min over the
    exhaustive sweep's metric vectors).
    """
    rows: List[Dict] = []
    policy_keys: List[str] = []
    for wl in workloads:
        wl = wl.canonical()
        space = build_space(wl, profile)
        obj = CachedObjective(objective_factory() if objective_factory
                              else CostModelObjective(profile))
        ex = ExhaustiveSearch(journal_dir=journal_dir).tune(space, obj)
        # journal-resumed configs never went through `obj` — seed the shared
        # cache with the sweep's times so every strategy reads the exact
        # measurements the optimum came from (re-measuring on a drifted
        # host would let a method "beat" exhaustive and trip the Phi gate)
        obj.seed(space, ex.history)
        row = {"workload": wl.key, "op": wl.op, "n": wl.n,
               "profile": space.spec.name,
               "space_size": len(ex.history),
               "best_time_s": ex.best_time,
               "exhaustive_evaluations": ex.evaluations,
               "methods": {}}
        for name in methods:
            res = get_strategy(name)(space, obj, seed=seed,
                                     max_evals=max_evals,
                                     journal_dir=journal_dir)
            eff = ex.best_time / res.best_time
            row["methods"][name] = {
                "time_s": res.best_time,
                "slowdown": res.best_time / ex.best_time,
                "efficiency": eff,
                "evaluations": res.evaluations,
                "evals_to_optimum": evals_to_optimum(res.history,
                                                     ex.best_time),
                "stopped_by": res.stopped_by,
                "config": dict(res.best_config),
            }
        pols = [get_policy(p, space.spec) for p in policies]
        if not policy_keys:
            policy_keys = [p.key for p in pols]
        extra = [p for p in pols if p.name != "latency"]
        if extra:
            hist_cfgs = [c for c, _ in ex.history]
            cols = obj.batch_eval_metrics(space, hist_cfgs,
                                          assume_valid=True)
            row["policies"] = {}
        for pol in extra:
            scal = policy_scalar_cols(pol, cols)
            best_i = int(np.argmin(scal))
            pol_best = float(scal[best_i])
            cell = {"best_scalar": pol_best,
                    "best_config": dict(hist_cfgs[best_i]),
                    "methods": {}}
            pobj = PolicyObjective(obj, pol)
            for name in methods:
                res = get_strategy(name)(space, pobj, seed=seed,
                                         max_evals=max_evals,
                                         journal_dir=journal_dir)
                if not np.isfinite(pol_best) and not np.isfinite(res.best_time):
                    # a cap no config satisfies: optimum and method are
                    # equally impossible, not a violation
                    eff = slow = 1.0
                else:
                    eff = pol_best / res.best_time
                    slow = res.best_time / pol_best
                cell["methods"][name] = {
                    "scalar": res.best_time,
                    "slowdown": slow,
                    "efficiency": eff,
                    "evaluations": res.evaluations,
                    "stopped_by": res.stopped_by,
                    "config": dict(res.best_config),
                }
            row["policies"][pol.key] = cell
        rows.append(row)

    report = {"methods": list(methods), "workloads": rows,
              "profile": rows[0]["profile"] if rows else None,
              "policies": policy_keys,
              "per_op": {}, "overall": {}, "per_policy": {},
              "violations": []}

    ops = sorted({r["op"] for r in rows})
    for name in methods:
        for op in ops:
            sub = [r for r in rows if r["op"] == op]
            effs = [r["methods"][name]["efficiency"] for r in sub]
            slows = [r["methods"][name]["slowdown"] for r in sub]
            report["per_op"].setdefault(op, {})[name] = {
                "phi": _phi_raw(effs),
                "mean_slowdown": sum(slows) / len(slows),
                "mean_evaluations": (sum(r["methods"][name]["evaluations"]
                                         for r in sub) / len(sub)),
                "n": len(sub),
            }
        effs = [r["methods"][name]["efficiency"] for r in rows]
        slows = [r["methods"][name]["slowdown"] for r in rows]
        reached = [r["methods"][name]["evals_to_optimum"] for r in rows
                   if r["methods"][name]["evals_to_optimum"] is not None]
        report["overall"][name] = {
            "phi": _phi_raw(effs),
            "mean_slowdown": sum(slows) / len(slows),
            "max_slowdown": max(slows),
            "total_evaluations": sum(r["methods"][name]["evaluations"]
                                     for r in rows),
            # evaluations-to-Phi<=1: how fast the method finds the optimum
            # when it does, and on what fraction of workloads it does at all
            "mean_evals_to_optimum": (sum(reached) / len(reached)
                                      if reached else None),
            "optimum_rate": len(reached) / len(rows),
            "n": len(rows),
        }
        for r in rows:
            if r["methods"][name]["efficiency"] > 1.0 + EFFICIENCY_EPS:
                report["violations"].append(
                    f"{name} beat exhaustive on {r['workload']}: "
                    f"efficiency={r['methods'][name]['efficiency']:.6f}")
    for pol_key in policy_keys:
        if pol_key == "latency":
            # the base table IS the latency policy; mirror it so the
            # per-(method, policy) gate sees a uniform structure
            report["per_policy"]["latency"] = {
                name: {"phi": report["overall"][name]["phi"],
                       "mean_slowdown":
                           report["overall"][name]["mean_slowdown"],
                       "total_evaluations":
                           report["overall"][name]["total_evaluations"],
                       "n": len(rows)}
                for name in methods}
            continue
        per: Dict[str, Dict] = {}
        for name in methods:
            cells = [r["policies"][pol_key]["methods"][name] for r in rows]
            effs = [c["efficiency"] for c in cells]
            slows = [c["slowdown"] for c in cells]
            per[name] = {
                "phi": _phi_raw(effs),
                "mean_slowdown": sum(slows) / len(slows),
                "total_evaluations": sum(c["evaluations"] for c in cells),
                "n": len(cells),
            }
            for r in rows:
                c = r["policies"][pol_key]["methods"][name]
                if c["efficiency"] > 1.0 + EFFICIENCY_EPS:
                    report["violations"].append(
                        f"[policy={pol_key}] {name} beat the {pol_key} "
                        f"optimum on {r['workload']}: "
                        f"efficiency={c['efficiency']:.6f}")
        report["per_policy"][pol_key] = per
    report["exhaustive_total_evaluations"] = sum(
        r["exhaustive_evaluations"] for r in rows)
    return report


def check_report(report: Dict) -> List[str]:
    """Failure strings; empty when the report is sane.

    Exhaustive search being beaten (efficiency or Phi above 1) is never a
    better methodology — it is a correctness bug in the sweep/objective
    stack, which is why CI fails on it.
    """
    failures = list(report.get("violations", ()))
    for name, agg in report.get("overall", {}).items():
        if agg["phi"] > 1.0 + EFFICIENCY_EPS:
            failures.append(f"overall Phi({name})={agg['phi']:.6f} > 1: "
                            f"exhaustive search was beaten")
    for pol_key, per in report.get("per_policy", {}).items():
        if pol_key == "latency":
            continue    # mirrors `overall`, already checked above
        for name, agg in per.items():
            if agg["phi"] > 1.0 + EFFICIENCY_EPS:
                failures.append(
                    f"Phi({name}, policy={pol_key})={agg['phi']:.6f} > 1: "
                    f"the {pol_key} optimum was beaten")
    return failures


# ---------------------------------------------------------------------------
# Per-(device, method) matrix (the portability story, quantified)
# ---------------------------------------------------------------------------

def compare_methods_matrix(workloads: Iterable[Workload],
                           methods: Sequence[str] = DEFAULT_MATRIX_METHODS,
                           profiles: Sequence[str] = DEFAULT_MATRIX_PROFILES,
                           *, seed: int = 0, max_evals: int = 20,
                           journal_dir: Optional[str] = None,
                           policies: Sequence[str] = ("latency",)) -> Dict:
    """``compare_methods`` once per hardware profile, shared journal dir.

    Profiles run in order; every sweep journals into the same directory, so
    by the time device k runs, ``strategy="transfer"`` finds devices
    0..k-1's journals and warm-starts from them (on the first device it is
    a cold Bayesian search — its baseline). The result is the per-(device,
    method) matrix of Phi / evaluations-to-optimum the paper's portability
    claim needs.
    """
    wls = [wl.canonical() for wl in workloads]
    matrix: Dict[str, Dict] = {}
    for name in profiles:
        prof = get_profile(name)
        matrix[name] = compare_methods(
            wls, methods, seed=seed, max_evals=max_evals,
            journal_dir=journal_dir, profile=prof, policies=policies)
    return {"profiles": list(profiles), "methods": list(methods),
            "reports": matrix}


def check_matrix(matrix_report: Dict) -> List[str]:
    """Failure strings over every (device, method) cell; empty when sane.

    Phi > 1 in ANY cell means a methodology "beat" that device's exhaustive
    sweep — a correctness bug somewhere in the profile-threaded stack.
    """
    failures: List[str] = []
    for prof, report in matrix_report.get("reports", {}).items():
        for msg in check_report(report):
            failures.append(f"[{prof}] {msg}")
    return failures


def format_matrix(matrix_report: Dict) -> str:
    """Per-(device, method) table: Phi, mean slowdown, evals-to-optimum."""
    lines = []
    header = f"{'device':<14} {'method':<11} {'Phi':>6} {'mean_slow':>9} " \
             f"{'evals_to_opt':>12} {'opt_rate':>8}"
    lines.append(header)
    lines.append("-" * len(header))
    for prof in matrix_report["profiles"]:
        overall = matrix_report["reports"][prof]["overall"]
        for name in matrix_report["methods"]:
            agg = overall[name]
            eto = agg.get("mean_evals_to_optimum")
            eto_s = f"{eto:12.1f}" if eto is not None else f"{'-':>12}"
            lines.append(f"{prof:<14} {name:<11} {agg['phi']:6.3f} "
                         f"{agg['mean_slowdown']:9.3f} {eto_s} "
                         f"{agg['optimum_rate']:8.2f}")
    return "\n".join(lines)


def format_report(report: Dict) -> str:
    """Human-readable per-op + overall table (the Table-II layout)."""
    lines = []
    header = f"{'op':<10} {'method':<11} {'Phi':>6} {'mean_slow':>9} " \
             f"{'mean_evals':>10}"
    lines.append(header)
    for op, per in sorted(report["per_op"].items()):
        for name in report["methods"]:
            agg = per[name]
            lines.append(f"{op:<10} {name:<11} {agg['phi']:6.3f} "
                         f"{agg['mean_slowdown']:9.3f} "
                         f"{agg['mean_evaluations']:10.1f}")
    lines.append("-" * len(header))
    for name in report["methods"]:
        agg = report["overall"][name]
        lines.append(f"{'OVERALL':<10} {name:<11} {agg['phi']:6.3f} "
                     f"{agg['mean_slowdown']:9.3f} "
                     f"{agg['total_evaluations']:10d}")
    extra = [k for k in report.get("policies", ()) if k != "latency"]
    if extra:
        lines.append("-" * len(header))
        for pol_key in extra:
            for name in report["methods"]:
                agg = report["per_policy"][pol_key][name]
                lines.append(f"{pol_key:<10} {name:<11} {agg['phi']:6.3f} "
                             f"{agg['mean_slowdown']:9.3f} "
                             f"{agg['total_evaluations']:10d}")
    return "\n".join(lines)
