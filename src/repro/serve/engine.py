"""Serving engine: single-dispatch batched prefill + donated decode loop.

A slot-based continuous-batching scheduler rebuilt for throughput.  The
engine owns ``max_batch`` slots, each slot one lane of the KV/state cache:

* **Prefill** bulk-writes a prompt's KV/state into its slot's cache lanes
  via :meth:`repro.models.model.Model.prefill` — a ``lax.scan`` over the
  decode step inside one jitted call, so a chunk of ``prefill_chunk``
  prompt tokens costs **one** device dispatch instead of one per token.
  All newly admitted slots prefill *together* (per-lane write masks let
  lanes with different prompt lengths share the scan), so a burst of
  admissions pays ``ceil(max(prompt_len) / chunk)`` dispatches rather
  than ``sum(prompt_len)``.  Per-lane results are bit-identical to the
  per-token replay path (:class:`repro.serve.reference.ReferenceEngine`),
  proven by ``tests/test_serve_prefill.py``.
* **Decode** is a fused jitted step over device-resident state: tokens,
  positions, per-slot active flags, remaining-token budgets, and the
  sampling PRNG key all live on device; sampling (argmax, or categorical
  at ``temperature > 0``) happens inside the step; the cache and the
  token-state pytree are donated (``donate_argnums``), so steady-state
  decode allocates no second cache copy and performs **at most one small
  host transfer per step** — the (B, 2) [token, finish-code] row.  With
  no listeners registered those rows are harvested in batches of
  ``harvest_every`` steps, letting dispatch run ahead asynchronously.
* **Admission** pops a :class:`collections.deque` under a
  ``max_prefill_tokens``-per-step budget, so one long prompt cannot
  starve active decoders: prefill yields to decode between chunks.

Inactive/prefilling lanes ride decode and prefill dispatches as padding
work but are *lane-masked out* of every cache merge, so their
recurrent/SSM state never advances on padding steps — the seed engine's
cross-request state pollution (see ``reference.py``) is gone, and a
freed lane is zeroed before its next tenant prefills.

Online-tuning hooks (see ``repro.tuning.online``) are unchanged from the
pre-rework engine: an injectable ``step_timer``, per-step
:class:`StepRecord` reports to listeners (a timed engine harvests every
step so the duration covers real device work), and an override-provider
whose config fragments select a per-fragment jitted variant — now held
in an LRU-capped table (``max_variants``) with the baseline and the
live variant pinned.  With no listeners the loop takes the exact
pre-hook path — an untimed engine pays nothing for the hooks.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Callable, Deque, Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.tuning.overrides import overrides as _tuning_overrides

PyTree = Any

FINISH_STOP = "stop"        # produced max_new_tokens naturally
FINISH_LENGTH = "length"    # truncated at the cache ceiling (max_len - 1)
# device-side finish codes in the harvested (B, 2) row; 0 = still going.
# "stop" wins when a request hits both bounds on the same token.
_FINISH_REASONS = {1: FINISH_STOP, 2: FINISH_LENGTH}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (len,) int32
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None   # "stop" | "length" once done


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """One timed decode step, as reported to step listeners."""

    index: int          # monotonically increasing decode-step counter
    duration_s: float   # wall-clock (or fake-clock) duration of the step
    active: int         # slots that were occupied during the step


def _lane_where(mask: jnp.ndarray, new: jnp.ndarray,
                old: jnp.ndarray) -> jnp.ndarray:
    """Per-lane select on a cache leaf; batch is axis 1 of every leaf."""
    return jnp.where(mask.reshape((1, -1) + (1,) * (new.ndim - 2)), new, old)


def _build_step_fn(model: Model, temperature: float, max_len: int):
    """Fused decode step: decode + sample + bookkeeping, one dispatch.

    Takes and returns the full device state; the cache and state pytrees
    are donated, so XLA updates them in place.  Emits a small (B, 2)
    int32 row — [sampled token or -1, finish code] — the only thing the
    host ever needs to read back.
    """
    def step(params, cache, state):
        tokens, pos, active = state["tokens"], state["pos"], state["active"]
        logits, new_cache = model.decode_step(params, tokens, cache, pos)
        row = logits.reshape((tokens.shape[0], -1))
        if temperature > 0.0:
            key, sub = jax.random.split(state["key"])
            nxt = jax.random.categorical(
                sub, row.astype(jnp.float32) / temperature, axis=-1)
        else:
            key = state["key"]
            nxt = jnp.argmax(row, axis=-1)
        nxt = nxt.astype(jnp.int32)
        act = active.astype(jnp.int32)
        emitted = jnp.where(active, nxt, -1)
        new_tokens = jnp.where(active, nxt, tokens[:, 0])[:, None]
        new_pos = pos + act[:, None]
        remaining = state["remaining"] - act
        hit_stop = remaining <= 0
        hit_len = new_pos[:, 0] >= max_len - 1
        finished = active & (hit_stop | hit_len)
        codes = jnp.where(finished,
                          jnp.where(hit_stop, 1, 2), 0).astype(jnp.int32)
        out = jnp.stack([emitted, codes], axis=-1)
        # inactive lanes keep their cache/state bit-exactly: padding
        # compute never pollutes a parked or prefilling tenant
        merged = jax.tree.map(
            lambda n, o: _lane_where(active, n, o), new_cache, cache)
        new_state = {"tokens": new_tokens, "pos": new_pos,
                     "active": active & ~finished,
                     "remaining": remaining, "key": key}
        return merged, new_state, out
    return jax.jit(step, donate_argnums=(1, 2))


def _build_prefill_fn(model: Model):
    """Jitted chunk prefill; retraces per chunk length (bounded: chunk
    lengths are powers of two capped at ``prefill_chunk``)."""
    def prefill(params, cache, toks, poss, writes):
        return model.prefill(params, toks, cache, poss, writes)
    return jax.jit(prefill, donate_argnums=(1,))


def _build_lane_reset_fn():
    # the template is a batch-1 init cache: its (n_groups, 1, ...) leaves
    # broadcast against the engine's (n_groups, B, ...) lanes, restoring
    # each reset lane to its *init* value (not zero — ring-buffer caches
    # init their position leaf to a "never written" sentinel)
    def reset(cache, template, mask):
        return jax.tree.map(
            lambda leaf, init: _lane_where(mask, init, leaf),
            cache, template)
    return jax.jit(reset, donate_argnums=(0,))


def _build_activate_fn():
    # full-batch masked update (not a gather by slot index): one traced
    # shape regardless of how many lanes activate together, so a server
    # never recompiles on a new admission-group size
    def activate(state, mask, tok, pos, rem):
        return {"tokens": jnp.where(mask[:, None], tok[:, None],
                                    state["tokens"]),
                "pos": jnp.where(mask[:, None], pos[:, None], state["pos"]),
                "active": state["active"] | mask,
                "remaining": jnp.where(mask, rem, state["remaining"]),
                "key": state["key"]}
    return jax.jit(activate, donate_argnums=(0,))


class _DecodeVariant:
    """The jitted step/prefill pair traced under one override fragment.

    Decode is jitted, so kernel configs resolved at TRACE time are baked
    into the compiled executable — an overrides() frame around later
    calls cannot reach it.  Each distinct override fragment therefore
    gets its own variant, re-traced (and its config re-resolved) under
    that frame on first call; revisits are cache hits.
    """

    __slots__ = ("step", "prefill")

    def __init__(self, model: Model, temperature: float, max_len: int):
        self.step = _build_step_fn(model, temperature, max_len)
        self.prefill = _build_prefill_fn(model)


def _pow2_chunk(need: int, cap: int) -> int:
    """Smallest power-of-two scan length covering ``need``, capped.

    Quantizing chunk lengths bounds jit retraces to log2(cap) shapes
    while wasting < 2x padding steps on the final partial chunk.
    """
    c = 1
    while c < need and c < cap:
        c *= 2
    return min(c, cap)


class ServeEngine:
    def __init__(self, model: Model, params: PyTree, max_batch: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0,
                 step_timer: Optional[Callable[[], float]] = None,
                 prefill_chunk: int = 32, harvest_every: int = 4,
                 max_prefill_tokens: Optional[int] = None,
                 admit_threshold: int = 1, max_variants: int = 8,
                 cache_dtype=jnp.float32):
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if harvest_every < 1:
            raise ValueError(f"harvest_every must be >= 1, got {harvest_every}")
        if not 1 <= admit_threshold <= max_batch:
            raise ValueError(f"admit_threshold must be in [1, {max_batch}], "
                             f"got {admit_threshold}")
        if max_variants < 2:
            # must at least hold the pinned baseline + one live variant
            raise ValueError(f"max_variants must be >= 2, got {max_variants}")
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.prefill_chunk = prefill_chunk
        self.harvest_every = harvest_every
        self.max_prefill_tokens = max_prefill_tokens
        # throughput knob: hold admissions until this many slots are free,
        # so co-admitted prompts share prefill scans (1 = admit eagerly,
        # latency-first; the serving benchmark raises it to batch prefill)
        self.admit_threshold = admit_threshold
        self.max_variants = max_variants
        self.cache_dtype = cache_dtype
        self.cache = model.init_cache(max_batch, max_len, dtype=cache_dtype)
        self._cache_template = model.init_cache(1, max_len, dtype=cache_dtype)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        # device-resident token state (donated through every decode step)
        self._state: Dict[str, jax.Array] = {
            "tokens": jnp.zeros((max_batch, 1), jnp.int32),
            "pos": jnp.zeros((max_batch, 1), jnp.int32),
            "active": jnp.zeros((max_batch,), bool),
            "remaining": jnp.zeros((max_batch,), jnp.int32),
            "key": jax.random.PRNGKey(seed),
        }
        self._lane_reset = _build_lane_reset_fn()
        self._activate_lanes = _build_activate_fn()
        self._decode_variants: "collections.OrderedDict[object, _DecodeVariant]" \
            = collections.OrderedDict()
        self._active_overrides: Optional[Dict] = None
        self._active_key: object = None
        self._decode = self._get_variant(None)
        self.queue: Deque[Request] = collections.deque()
        self.completed: List[Request] = []
        # slot -> prompt tokens already written (mid-prefill slots)
        self._prefilling: Dict[int, int] = {}
        self._pending_out: List[jax.Array] = []
        # perf counters (read by benchmarks/tests)
        self.prefill_calls = 0        # prefill device dispatches
        self.host_transfers = 0       # device->host reads (via _fetch)
        # -- step hooks (timing is only paid when a listener is registered)
        self.step_timer: Callable[[], float] = step_timer or time.perf_counter
        self._step_listeners: List[Callable[[StepRecord], None]] = []
        self._override_provider: Optional[
            Callable[[], Optional[Mapping[str, Mapping[str, int]]]]] = None
        self._step_index = 0

    def add_step_listener(self, fn: Callable[[StepRecord], None]) -> None:
        """Register a callback invoked after every timed decode step."""
        self._step_listeners.append(fn)

    def set_override_provider(
            self, fn: Optional[
                Callable[[], Optional[Mapping[str, Mapping[str, int]]]]],
    ) -> None:
        """Install a provider of per-op config overrides, consulted before
        each step and applied (via the thread-local override stack) around
        it — how an online tuner's active trial reaches the kernels."""
        self._override_provider = fn

    # -- public API --
    def warmup(self) -> None:
        """Pre-trace the active variant's decode step, every prefill chunk
        shape, and the admission helpers, so a live server (or a timed
        benchmark) never pays a jit compile mid-traffic.  Runs against
        throwaway buffers — engine state, caches, and the sampling PRNG
        stream are untouched."""
        cache = self.model.init_cache(self.max_batch, self.max_len,
                                      dtype=self.cache_dtype)
        state = {
            "tokens": jnp.zeros((self.max_batch, 1), jnp.int32),
            "pos": jnp.zeros((self.max_batch, 1), jnp.int32),
            "active": jnp.zeros((self.max_batch,), bool),
            "remaining": jnp.zeros((self.max_batch,), jnp.int32),
            "key": jax.random.PRNGKey(0),
        }
        cache, state, out = self._decode.step(self.params, cache, state)
        c = 1
        while True:
            toks = jnp.zeros((c, self.max_batch), jnp.int32)
            writes = jnp.zeros((c, self.max_batch), bool)
            cache = self._decode.prefill(self.params, cache, toks, toks,
                                         writes)
            if c >= self.prefill_chunk:
                break
            c = min(c * 2, self.prefill_chunk)
        mask = jnp.zeros((self.max_batch,), bool)
        zeros = jnp.zeros((self.max_batch,), jnp.int32)
        state = self._activate_lanes(state, mask, zeros, zeros, zeros)
        cache = self._lane_reset(cache, self._cache_template, mask)
        jax.block_until_ready((cache, state, out))

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            # an empty prompt has no last token to decode from; reject at
            # the door instead of poisoning the batch
            raise ValueError("empty prompt: need at least one token")
        rid = len(self.queue) + len(self.completed) + sum(
            r is not None for r in self.slot_req)
        self.queue.append(Request(rid, prompt, max_new_tokens))
        return rid

    def run(self, max_steps: int = 1000) -> List[Request]:
        """Serve until the queue drains (or ``max_steps``).

        Returns completed requests in **submission order** (ascending
        ``rid``) — a stable contract that deterministic consumers (trace
        replay, batched clients zipping prompts with results) rely on.
        ``self.completed`` retains completion order for schedulers that
        care about finishing sequence.
        """
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            ov = self._override_provider() if self._override_provider else None
            if ov != self._active_overrides:
                self._select_decode_variant(ov)
            ctx = _tuning_overrides(**ov) if ov else contextlib.nullcontext()
            with ctx:
                self._admit()
                active = sum(r is not None for r in self.slot_req)
                if self._step_listeners and active:
                    # timed mode: harvest inside the timed window so the
                    # duration covers the device step (not just its async
                    # dispatch) — exactly two timer reads per step
                    t0 = self.step_timer()
                    self._dispatch_step()
                    self._harvest()
                    record = StepRecord(self._step_index,
                                        self.step_timer() - t0, active)
                    for listener in self._step_listeners:
                        listener(record)
                else:
                    self._dispatch_step()
                    if len(self._pending_out) >= self.harvest_every:
                        self._harvest()
            self._step_index += 1
            steps += 1
        self._harvest()
        return sorted(self.completed, key=lambda r: r.rid)

    # -- internals --
    def _get_variant(self, key: object) -> _DecodeVariant:
        variant = self._decode_variants.get(key)
        if variant is None:
            variant = _DecodeVariant(self.model, self.temperature,
                                     self.max_len)
            self._decode_variants[key] = variant
        self._decode_variants.move_to_end(key)
        return variant

    def _select_decode_variant(self, ov: Optional[Dict]) -> None:
        """Switch to (or build) the jitted variant traced under ``ov``.

        First use of a config pays one re-trace/compile — landing inside
        that trial's first timed step, which the online tuner's
        first-sample baseline discard absorbs; returning to a previously
        seen config (the incumbent after a rollback) is a dict hit.  The
        table is LRU-capped at ``max_variants``: the baseline (``None``)
        and the variant being selected are pinned, the least recently
        used of the rest is evicted.
        """
        self._active_overrides = None if ov is None \
            else {op: dict(frag) for op, frag in ov.items()}
        key = None if ov is None else tuple(
            (op, tuple(sorted(frag.items())))
            for op, frag in sorted(ov.items()))
        self._decode = self._get_variant(key)
        self._active_key = key
        while len(self._decode_variants) > self.max_variants:
            victim = next((k for k in self._decode_variants
                           if k is not None and k != key), None)
            if victim is None:
                break
            del self._decode_variants[victim]

    def _fetch(self, x: jax.Array) -> np.ndarray:
        """The one device->host chokepoint (counted; fake-able in tests)."""
        self.host_transfers += 1
        return np.asarray(x)

    # -- harvest: drain emitted tokens back to host ------------------------

    def _harvest(self) -> None:
        if not self._pending_out:
            return
        outs, self._pending_out = self._pending_out, []
        rows = self._fetch(jnp.stack(outs))       # (k, B, 2), ONE transfer
        for row in rows:
            for s, req in enumerate(self.slot_req):
                if req is None:
                    continue
                tok, code = int(row[s, 0]), int(row[s, 1])
                if tok < 0:
                    continue        # lane was prefilling / already finished
                req.output.append(tok)
                self.slot_pos[s] += 1
                if code:
                    req.done = True
                    req.finish_reason = _FINISH_REASONS[code]
                    self.completed.append(req)
                    self.slot_req[s] = None

    # -- admission + prefill ----------------------------------------------

    def _admit(self) -> None:
        if self.queue and self._pending_out and (
                len(self._pending_out) >= self.harvest_every
                or not self._any_decoding()):
            # a backlog is waiting on freed slots: sync the host view.
            # (Prefill itself tolerates stale mirrors — padding lanes are
            # write-masked — so no other path forces an early harvest.)
            self._harvest()
        free = [s for s in range(self.max_batch) if self.slot_req[s] is None]
        busy = self.max_batch - len(free)
        # hold admissions until a worthwhile prefill group has formed;
        # with nothing in flight there is no reason (or way) to wait
        want = min(self.admit_threshold, len(self.queue)) if busy else 1
        if not self.queue or len(free) < want:
            self._run_prefill()
            return
        newly: List[int] = []
        for slot in free:
            while self.queue:
                req = self.queue.popleft()
                if np.asarray(req.prompt).size == 0:
                    # hand-built Request bypassing submit(): complete it
                    # empty rather than poisoning the whole batch
                    req.done = True
                    req.finish_reason = FINISH_STOP
                    self.completed.append(req)
                    continue
                self.slot_req[slot] = req
                self.slot_pos[slot] = 0
                self._prefilling[slot] = 0
                newly.append(slot)
                break
        if newly:
            # evict the previous tenant's state from the reused lanes in
            # one dispatch (stale KV is position-masked anyway, but
            # SSM/recurrent state is not position-indexed)
            mask = np.zeros(self.max_batch, bool)
            mask[newly] = True
            self.cache = self._lane_reset(self.cache, self._cache_template,
                                          jnp.asarray(mask))
        self._run_prefill()

    def _run_prefill(self) -> None:
        """Advance all mid-prefill slots, chunked and budgeted.

        Every pending slot shares each scan (per-lane write masks), so a
        burst of admissions costs ceil(max(prompt_len)/chunk) dispatches,
        not sum(prompt_len).  At most ``max_prefill_tokens`` prompt
        tokens are written per engine step (always at least one chunk, so
        long prompts keep making progress), then control returns to the
        decode loop — active slots never starve behind a long prompt.
        """
        budget = self.max_prefill_tokens
        spent = 0
        while self._prefilling:
            ready = [s for s, filled in self._prefilling.items()
                     if filled >= len(self.slot_req[s].prompt) - 1]
            if ready:
                self._activate_slots(ready)
            if not self._prefilling:
                break
            if budget is not None and spent >= budget:
                break
            need = {s: len(self.slot_req[s].prompt) - 1 - filled
                    for s, filled in self._prefilling.items()}
            c = _pow2_chunk(max(need.values()), self.prefill_chunk)
            toks = np.zeros((c, self.max_batch), np.int32)
            poss = np.tile(np.maximum(self.slot_pos, 0).astype(np.int32),
                           (c, 1))
            writes = np.zeros((c, self.max_batch), bool)
            for s, n in need.items():
                filled = self._prefilling[s]
                take = min(c, n)
                prompt = self.slot_req[s].prompt
                idx = np.arange(take)
                toks[idx, s] = prompt[filled:filled + take]
                poss[idx, s] = filled + idx
                if take < c:
                    # masked tail steps: hold a valid position, write=False
                    poss[take:, s] = max(filled + take - 1, 0)
                writes[:take, s] = True
                self._prefilling[s] = filled + take
                spent += take
            self.cache = self._decode.prefill(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(poss), jnp.asarray(writes))
            self.prefill_calls += 1

    def _activate_slots(self, slots: List[int]) -> None:
        """Prompts fully written: arm the lanes to decode from their last
        token, one device update for the whole group (a 1-token prompt
        activates with no prefill at all)."""
        mask = np.zeros(self.max_batch, bool)
        toks = np.zeros(self.max_batch, np.int32)
        poss = np.zeros(self.max_batch, np.int32)
        rems = np.zeros(self.max_batch, np.int32)
        for slot in slots:
            req = self.slot_req[slot]
            plen = len(req.prompt)
            self.slot_pos[slot] = plen - 1
            del self._prefilling[slot]
            mask[slot] = True
            toks[slot] = int(req.prompt[-1])
            poss[slot] = plen - 1
            rems[slot] = req.max_new_tokens
        self._state = self._activate_lanes(
            self._state, jnp.asarray(mask), jnp.asarray(toks),
            jnp.asarray(poss), jnp.asarray(rems))

    # -- decode ------------------------------------------------------------

    def _any_decoding(self) -> bool:
        return any(r is not None and s not in self._prefilling
                   for s, r in enumerate(self.slot_req))

    def _dispatch_step(self) -> None:
        if not self._any_decoding():
            return
        self.cache, self._state, out = self._decode.step(
            self.params, self.cache, self._state)
        self._pending_out.append(out)
