"""Serving engine: continuous batching + greedy consistency."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.model import build_model
from repro.serve.engine import ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def test_single_request(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    eng.submit(np.asarray([1, 5, 9], np.int32), max_new_tokens=4)
    done = eng.run()
    assert len(done) == 1
    assert len(done[0].output) == 4
    assert all(0 <= t < cfg.vocab for t in done[0].output)


def test_continuous_batching_mixed_lengths(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    for i in range(5):   # more requests than slots -> queueing
        eng.submit(rng.integers(0, cfg.vocab, size=3 + i), max_new_tokens=3)
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in done)


def test_greedy_matches_direct_decode(small_model):
    cfg, model, params = small_model
    import jax.numpy as jnp
    prompt = np.asarray([2, 7, 11], np.int32)
    eng = ServeEngine(model, params, max_batch=1, max_len=32)
    eng.submit(prompt, max_new_tokens=4)
    out_engine = eng.run()[0].output

    # direct greedy loop
    cache = model.init_cache(1, 32, dtype=jnp.float32)
    toks = list(prompt)
    for t in range(len(prompt) - 1):
        _, cache = model.decode_step(params, jnp.asarray([[toks[t]]]),
                                     cache, jnp.asarray([[t]]))
    out = []
    pos = len(prompt) - 1
    cur = toks[-1]
    for _ in range(4):
        lg, cache = model.decode_step(params, jnp.asarray([[cur]]), cache,
                                      jnp.asarray([[pos]]))
        cur = int(jnp.argmax(lg[0, 0]))
        out.append(cur)
        pos += 1
    assert out == out_engine


def test_empty_prompt_rejected(small_model):
    """An empty prompt used to IndexError in _decode_step (prompt[-1]) and
    poison slot_pos with -1; it must be rejected at submit."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.asarray([], np.int32))
    # the engine stays healthy for real traffic afterwards
    eng.submit(np.asarray([3, 1], np.int32), max_new_tokens=2)
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 2


def test_handcrafted_empty_request_drained_not_crashing(small_model):
    """A Request built around submit() must not crash the whole batch."""
    from repro.serve.engine import Request
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    eng.queue.append(Request(0, np.asarray([], np.int32), 4))
    eng.submit(np.asarray([5], np.int32), max_new_tokens=2)
    done = eng.run()
    assert len(done) == 2
    empty = next(r for r in done if r.prompt.size == 0)
    assert empty.done and empty.output == []
    real = next(r for r in done if r.prompt.size == 1)
    assert len(real.output) == 2


def test_single_token_prompt(small_model):
    """prompt[:-1] is empty for a 1-token prompt — no replay steps, decode
    starts straight from the prompt token at position 0."""
    cfg, model, params = small_model
    eng = ServeEngine(model, params, max_batch=2, max_len=32)
    eng.submit(np.asarray([7], np.int32), max_new_tokens=3)
    done = eng.run()
    assert len(done) == 1
    assert len(done[0].output) == 3
    assert all(0 <= t < cfg.vocab for t in done[0].output)

    # greedy consistency against a direct decode loop
    import jax.numpy as jnp
    cache = model.init_cache(1, 32, dtype=jnp.float32)
    out, cur, pos = [], 7, 0
    for _ in range(3):
        lg, cache = model.decode_step(params, jnp.asarray([[cur]]), cache,
                                      jnp.asarray([[pos]]))
        cur = int(jnp.argmax(lg[0, 0]))
        out.append(cur)
        pos += 1
    assert out == done[0].output
