"""repro.core — the paper's contribution: tuning methodologies.

Public API:
  Workload, build_space      — declare what to tune (paper Table I)
  AnalyticalTuner            — model-driven, zero-evaluation (paper IV-A)
  BayesianTuner              — BO with GP surrogate + EI (paper IV-B)
  ExhaustiveSearch, RandomSearch
  phi, efficiency            — portability metric (paper VI)
  TuningDB                   — offline config store (canonical home:
                               repro.tuning.db; the legacy repro.core.tuner
                               facade was removed — use repro.tuning)
"""
from repro.core.analytical import AnalyticalTuner
from repro.core.bayesian import BayesianTuner, TuneResult
from repro.core.exhaustive import ExhaustiveSearch, RandomSearch
from repro.core.metrics import efficiency, phi, phi_from_times
from repro.core.objective import (CachedObjective, CostModelObjective,
                                  Measurement, Objective, PENALTY_TIME,
                                  TPUCostModelObjective, WallClockObjective)
from repro.core.space import Config, ParamSpec, SearchSpace, Workload, build_space
from repro.tuning.db import TuningDB

__all__ = [
    "AnalyticalTuner", "BayesianTuner", "TuneResult", "ExhaustiveSearch",
    "RandomSearch", "efficiency", "phi", "phi_from_times", "CachedObjective",
    "Measurement", "Objective", "PENALTY_TIME", "CostModelObjective",
    "TPUCostModelObjective",
    "WallClockObjective", "Config", "ParamSpec", "SearchSpace", "Workload",
    "build_space", "TuningDB",
]
