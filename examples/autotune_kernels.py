"""The full paper workflow: exhaustive vs analytical vs Bayesian tuning on
every prefix-op family, with Table-II-style Phi reporting.

    PYTHONPATH=src python examples/autotune_kernels.py
"""
import numpy as np

from repro.core import Workload
from repro.core.metrics import phi
from benchmarks.common import tune_all_methods

CASES = [("scan", "lf", [128, 256, 512, 1024]),
         ("scan", "ks", [128, 256, 512, 1024]),
         ("tridiag", "wm", [64, 128, 256, 512]),
         ("tridiag", "pcr", [64, 128, 256, 512]),
         ("fft", "stockham", [64, 256, 1024, 4096])]

print(f"{'op':22s} {'PHI_analytical':>15s} {'PHI_bayesian':>13s} "
      f"{'BO evals':>9s}")
for op, variant, sizes in CASES:
    effs = {"analytical": [], "bayesian": []}
    evals = []
    for n in sizes:
        res = tune_all_methods(
            Workload(op=op, n=n, batch=max(2**26 // n, 1), variant=variant))
        effs["analytical"].append(res["analytical"]["efficiency"])
        effs["bayesian"].append(res["bayesian"]["efficiency"])
        evals.append(res["bayesian"]["evals"])
    print(f"{op+'-'+variant:22s} {phi(effs['analytical']):15.4f} "
          f"{phi(effs['bayesian']):13.4f} {str(evals):>9s}")
