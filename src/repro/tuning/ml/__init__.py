"""repro.tuning.ml — the paper's ML-based tuning methodology, deployable.

Offline: export labeled (config, time) data from exhaustive sweeps and
TuningDB records, train a pure-numpy random forest per kernel family, save
a versioned ``.npz`` artifact.  Online: ``strategy="ml"`` ranks a
workload's valid candidates through the forest in zero objective
evaluations, falling back to the analytical model when no artifact /
forest exists or tree disagreement is high.

    PYTHONPATH=src python -m repro.launch.tune train-model --out artifacts/ml_model.npz
    PYTHONPATH=src python -m repro.launch.tune eval-model  --model artifacts/ml_model.npz

    session.tune(wl, method="ml")      # via the strategy registry

See docs/tuning.md ("ML-based tuning") for the full lifecycle.
"""
from repro.tuning.ml.dataset import (Dataset, build_dataset, dataset_from_db,
                                     dataset_from_journal,
                                     dataset_from_journal_dir,
                                     merge, parse_db_key, split_by_size,
                                     suite_workloads, sweep_workload, SUITE)
from repro.tuning.ml.evaluate import check_floors, evaluate_model
from repro.tuning.ml.features import (FEATURE_NAMES, FEATURE_VERSION,
                                      N_FEATURES, featurize, featurize_batch)
from repro.tuning.ml.forest import (Forest, MODEL_SCHEMA, ModelArtifactError,
                                    ModelBundle, train_bundle)
from repro.tuning.ml.strategy import (DEFAULT_MODEL_PATH, MLStrategy,
                                      default_model_path, default_strategy)

__all__ = [
    "Dataset", "DEFAULT_MODEL_PATH", "FEATURE_NAMES", "FEATURE_VERSION",
    "Forest", "MLStrategy", "MODEL_SCHEMA", "ModelArtifactError",
    "ModelBundle", "N_FEATURES", "SUITE", "build_dataset", "check_floors",
    "dataset_from_db", "dataset_from_journal", "dataset_from_journal_dir",
    "default_model_path", "default_strategy",
    "evaluate_model", "featurize",
    "featurize_batch", "merge", "parse_db_key", "split_by_size",
    "suite_workloads", "sweep_workload", "train_bundle",
]
