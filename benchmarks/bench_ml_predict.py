"""Micro-benchmark: the learned predictor's online path.

Compares, per holdout workload, the two zero-evaluation online answers:
  * analytical suggest — enumerate + score the space with the expert model;
  * ml predict — featurize the candidates and rank them with the trained
    forest (the strategy="ml" hot path), plus its accuracy vs exhaustive.

By default a small bundle is trained in-process on the training suite
(``--smoke`` shrinks ops/trees so CI finishes in seconds); pass ``--model``
to benchmark a saved artifact instead.

Emits CSV rows (ml_predict,<op>:<variant>,<N>,<metric>,<value>) and, with
``--json``, a BENCH_ML_PREDICT.json artifact for the CI perf trajectory.

    PYTHONPATH=src python benchmarks/bench_ml_predict.py --smoke --seed 0
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

from repro.core import build_space
from repro.core.analytical import AnalyticalTuner
from repro.tuning.ml import (ModelBundle, build_dataset, evaluate_model,
                             featurize_batch, suite_workloads, train_bundle)
from repro.tuning.ml.dataset import POOLED_OPS

SMOKE_OPS = ["scan", "fft", "attention"]


def timeit(fn, reps: int) -> float:
    fn()                                     # warm caches / allocators
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _bundle(ops: Optional[List[str]], seed: int, trees: int,
            depth: int) -> ModelBundle:
    ds = build_dataset(suite_workloads("train", ops=ops))
    return train_bundle(ds.by_op(), n_trees=trees, max_depth=depth,
                        seed=seed, meta={"aliases": POOLED_OPS})


def run(emit, *, seed: int = 0, smoke: bool = False,
        model_path: Optional[str] = None) -> dict:
    ops = SMOKE_OPS if smoke else None
    reps = 3 if smoke else 10
    t0 = time.perf_counter()
    if model_path:
        bundle = ModelBundle.load(model_path)
        emit(f"ml_predict,_,_,artifact,{model_path}")
    else:
        bundle = _bundle(ops, seed, trees=12 if smoke else 48,
                         depth=10 if smoke else 12)
    train_s = time.perf_counter() - t0
    emit(f"ml_predict,_,_,train_s,{train_s:.2f}")

    ana = AnalyticalTuner()
    holdout = suite_workloads("holdout", ops=ops)
    summary = {"train_s": train_s, "seed": seed, "workloads": []}
    for wl in holdout:
        wl = wl.canonical()
        space = build_space(wl)
        cfgs = space.enumerate_valid()
        X = featurize_batch(space, cfgs)
        forest = bundle.forest_for(wl.op)
        if forest is None:
            continue
        tag = f"{wl.op}:{wl.variant or 'default'},{wl.n}"
        t_feat = timeit(lambda: featurize_batch(space, cfgs), reps)
        t_rank = timeit(lambda: forest.predict(X), reps)
        t_ana = timeit(lambda: ana.suggest(space), reps)
        emit(f"ml_predict,{tag},candidates,{len(cfgs)}")
        emit(f"ml_predict,{tag},featurize_us,{t_feat*1e6:.0f}")
        emit(f"ml_predict,{tag},rank_us,{t_rank*1e6:.0f}")
        emit(f"ml_predict,{tag},analytical_us,{t_ana*1e6:.0f}")
        summary["workloads"].append(
            {"workload": wl.key, "candidates": len(cfgs),
             "featurize_us": t_feat * 1e6, "rank_us": t_rank * 1e6,
             "analytical_us": t_ana * 1e6})

    report = evaluate_model(bundle, holdout)
    if report["n_scored"]:
        emit(f"ml_predict,_,_,top1_rate,{report['top1_rate']:.3f}")
        emit(f"ml_predict,_,_,mean_slowdown,{report['mean_slowdown']:.4f}")
        emit(f"ml_predict,_,_,max_slowdown,{report['max_slowdown']:.4f}")
        summary["top1_rate"] = report["top1_rate"]
        summary["mean_slowdown"] = report["mean_slowdown"]
        summary["max_slowdown"] = report["max_slowdown"]
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced ops/trees/reps for CI")
    ap.add_argument("--model", default=None,
                    help="benchmark a saved artifact instead of training")
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_ML_PREDICT.json summary")
    args = ap.parse_args()
    rows: List[str] = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    summary = run(emit, seed=args.seed, smoke=args.smoke,
                  model_path=args.model)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "ml_predict", "seed": args.seed,
                       "smoke": bool(args.smoke), "rows": rows,
                       "summary": summary}, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
