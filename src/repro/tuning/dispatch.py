"""Shared backend planning for tuned kernel entry points.

Every kernels/*/ops.py used to carry a private ``_on_cpu()`` plus the same
three-line default dance for ``use_pallas``/``interpret``. The one copy
lives here.

Policy (unchanged from the historical per-file copies):
  * default: Pallas on accelerators; on CPU hosts the XLA reference path
    runs unless the caller explicitly asks for interpret-mode validation
    (production CPU paths should not pay the interpret-mode python loop);
  * explicit ``use_pallas=`` always wins;
  * when the Pallas path runs and ``interpret`` was not forced, interpret
    mode is enabled exactly on CPU hosts.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def plan_execution(use_pallas: Optional[bool], interpret: Optional[bool],
                   gate: bool = True) -> Tuple[bool, bool]:
    """Resolve (use_pallas, interpret) defaults for a kernel launch.

    ``gate`` lets an op veto the Pallas default for shapes where tiling has
    nothing to add (e.g. decode-shaped attention) without affecting an
    explicit ``use_pallas=True``.
    """
    if use_pallas is None:
        use_pallas = ((not on_cpu()) or bool(interpret)) and gate
    if not use_pallas:
        return False, False
    return True, on_cpu() if interpret is None else interpret
