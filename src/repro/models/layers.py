"""Shared neural-net layers (pure functional JAX; params are dict pytrees).

Initializers return (params, ...) dicts; apply functions are pure. Sharding
is attached externally by repro.distributed.sharding from parameter paths.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # keep the full-tensor math in the input dtype: an upfront
    # x.astype(f32) gives XLA a full-width convert it will hoist ABOVE the
    # upstream TP all-reduce, doubling every residual all-reduce to f32
    # (measured on granite-34b: 2x collective bytes). Only the variance
    # reduction runs in f32 (fused, never materialized).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale.astype(x.dtype))


def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: Optional[float] = None) -> Dict:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Dict, x: jax.Array, compute_dtype=None) -> jax.Array:
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def _act(name: str, x: jax.Array) -> jax.Array:
    if name in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if name in ("geglu", "gelu"):
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": init_dense(k1, d_model, d_ff, dtype),       # gate proj
        "wu": init_dense(k2, d_model, d_ff, dtype),       # up proj
        "wo": init_dense(k3, d_ff, d_model, dtype),
    }


def mlp(p: Dict, x: jax.Array, activation: str, compute_dtype) -> jax.Array:
    g = _act(activation, dense(p["wi"], x, compute_dtype))
    u = dense(p["wu"], x, compute_dtype)
    return dense(p["wo"], g * u, compute_dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, L, H, D); positions: (B, L) int."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, L, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def init_embedding(key, vocab: int, d_model: int, dtype) -> Dict:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * (1.0 / math.sqrt(d_model))).astype(dtype)}


def embed(p: Dict, tokens: jax.Array, compute_dtype,
          one_hot: bool = False) -> jax.Array:
    if one_hot:
        # distributed path: the gather's backward is a scatter-add that the
        # SPMD partitioner replicates to a full (V, D) per device; a one-hot
        # einsum keeps both forward and backward partitioned (vocab stays on
        # "model"), and XLA fuses the iota-compare into the matmul.
        v = p["table"].shape[0]
        oh = jax.nn.one_hot(tokens, v, dtype=compute_dtype)
        return jnp.einsum("blv,vd->bld", oh,
                          p["table"].astype(compute_dtype))
    return p["table"].astype(compute_dtype)[tokens]


def unembed(p: Dict, x: jax.Array, softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("bld,vd->blv", x.astype(jnp.float32),
                        p["table"].astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def causal_conv1d(x: jax.Array, w: jax.Array, cache: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B, L, D); w: (K, D).

    Returns (y, new_cache) with cache = last K-1 inputs (for decode)."""
    K = w.shape[0]
    if cache is None:
        ctx = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    y = sum(ctx[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(K))
    new_cache = ctx[:, -(K - 1):, :] if K > 1 else ctx[:, :0, :]
    return y.astype(x.dtype), new_cache
