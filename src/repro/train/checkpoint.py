"""Sharded checkpointing with manifests, async writes, and auto-resume.

Layout per step:
    <dir>/step_<n>.tmp/ -> (atomic rename) -> <dir>/step_<n>/
        manifest.json    tree structure, shapes, dtypes, content hashes
        <leaf-id>.npy    one file per leaf (addressable shards gathered)

Fault-tolerance contract:
  * writes land in a .tmp dir and are renamed only after the manifest is
    fsync'd -> a crash mid-write can never produce a "latest" checkpoint
    that fails to load;
  * `latest_step` only considers directories with a valid manifest whose
    per-leaf hashes verify lazily on load;
  * async mode runs the serialize+write on a worker thread; `wait()` joins
    (called before the next save and at exit);
  * keep_last prunes old checkpoints after a successful save.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 async_write: bool = True):
        self.dir = os.path.abspath(directory)
        os.makedirs(self.dir, exist_ok=True)
        self.keep_last = keep_last
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---------- save ----------
    def save(self, step: int, state: PyTree) -> None:
        self.wait()
        # materialize on host BEFORE handing to the worker (the train loop
        # may donate/overwrite device buffers in the next step)
        leaves, _ = _flatten(state)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in leaves]
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write_guarded(self, step: int, host) -> None:
        try:
            self._write(step, host)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, host) -> None:
        tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for i, (key, arr) in enumerate(host):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._prune()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {err!r}")

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------- load ----------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: PyTree, verify: bool = True,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Restore into the structure of `like` (resharded if given)."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(like)
        restored = []
        for key, leaf in leaves:
            ent = manifest["leaves"].get(key)
            if ent is None:
                raise KeyError(f"checkpoint {step} missing leaf {key!r}")
            arr = np.load(os.path.join(d, ent["file"]))
            if verify:
                h = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                if h != ent["sha256"]:
                    raise IOError(f"checkpoint corruption in {key!r}")
            if list(arr.shape) != list(np.shape(leaf)):
                raise ValueError(
                    f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                    f"model {np.shape(leaf)} (elastic re-mesh requires "
                    f"matching global shapes)")
            restored.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), restored)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree

    def restore_latest(self, like: PyTree, shardings: Optional[PyTree] = None
                       ) -> Tuple[Optional[int], Optional[PyTree]]:
        """Auto-resume: newest checkpoint that loads cleanly; corrupt ones
        are skipped (the node-failure story: a partially written or damaged
        checkpoint must not wedge the restart)."""
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step, like, shardings=shardings)
            except (IOError, KeyError, ValueError, json.JSONDecodeError):
                continue
        return None, None
