"""End-to-end behaviour: the paper's offline->online tuning flow feeding the
framework's kernels, and the full tuning-methodology comparison on one op."""
import jax.numpy as jnp
import numpy as np

from repro.core import (AnalyticalTuner, BayesianTuner, CachedObjective,
                        ExhaustiveSearch, TPUCostModelObjective, TuningDB,
                        Workload, build_space)
from repro.tuning import TunerSession
from repro.core.metrics import phi


def test_offline_online_flow(tmp_path):
    """Offline BO -> DB -> online kernel launch consumes the stored config."""
    db = TuningDB(path=str(tmp_path / "db.json"))
    wl = Workload(op="scan", n=256, batch=1024, variant="ks")
    session = TunerSession(db=db)
    res = session.tune(wl, method="bayesian")
    cfg = session.resolve_raw(wl)
    assert cfg == res.best_config

    from repro.kernels.scan.ops import prefix_sum
    from repro.kernels.scan.ref import scan_add_ref
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 256)),
                    jnp.float32)
    got = prefix_sum(x, config=cfg, interpret=True)
    np.testing.assert_allclose(got, scan_add_ref(x), rtol=2e-5, atol=2e-4)


def test_methodology_comparison_reproduces_paper_ordering(monkeypatch):
    """Both predictive methodologies land near the exhaustive optimum
    (paper Table II: Phi >= 0.87 everywhere, >= 0.97 for single-kernel).

    Pinned to tpu_v5e: the Phi floors are calibrated against that machine
    model (other devices' floors live in the compare-methods device-matrix
    gate), so the REPRO_HW_PROFILE matrix must not retarget this test."""
    monkeypatch.setenv("REPRO_HW_PROFILE", "tpu_v5e")
    effs = {"analytical": [], "bayesian": []}
    for n in [128, 256, 512, 1024]:
        wl = Workload(op="scan", n=n, batch=2**22 // n, variant="lf")
        space = build_space(wl)
        obj = CachedObjective(TPUCostModelObjective(noise=0.02))
        best = ExhaustiveSearch().tune(space, obj).best_time
        t_ana = obj(space, AnalyticalTuner().suggest(space)).time_s
        bo = BayesianTuner(seed=0).tune(
            space, CachedObjective(TPUCostModelObjective(noise=0.02)))
        effs["analytical"].append(min(best / t_ana, 1.0))
        effs["bayesian"].append(min(best / bo.best_time, 1.0))
    assert phi(effs["analytical"]) > 0.9
    assert phi(effs["bayesian"]) > 0.9
