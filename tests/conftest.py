"""Shared test plumbing: tolerance helpers, the kernel-vs-reference
differential case table, and deterministic hypothesis profiles.

Every ``kernels/*/ops.py`` entry point is registered once in
``KERNEL_CASES`` with its input builder and reference; the
``kernel_case`` fixture (via ``pytest_generate_tests``) fans the table
out over dtype x odd/prime shapes.  This replaces the per-file
copy-pasted size lists and per-file tolerance dances: a new tuned kernel
gets differential coverage by adding one table row, and a tolerance
change happens in exactly one place.

Hypothesis (optional dep): the ``ci`` profile pins a fixed derandomized
seed and disables deadlines so the property suites are deterministic on
shared CI runners — select it with ``HYPOTHESIS_PROFILE=ci``.
"""
from __future__ import annotations

import dataclasses
import os
import zlib
from typing import Callable, Sequence, Tuple

import numpy as np

try:    # optional dep — the property suites importorskip it themselves
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", deadline=None, derandomize=True, max_examples=30,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass


# ---------------------------------------------------------------------------
# Shared tolerance helpers
# ---------------------------------------------------------------------------
# One tolerance per compute dtype. atol scales with the reference
# magnitude (prefix-style ops accumulate, so absolute error grows with
# the partial sums — a fixed atol either misses real bugs at small n or
# flakes at large n).

DTYPE_TOL = {
    "float32": (2e-5, 2e-5),
    "bfloat16": (2e-2, 2e-2),
    "complex64": (1e-4, 1e-4),
}


def assert_kernel_close(got, ref, dtype: str = "float32",
                        scale: float = 1.0) -> None:
    """Assert a kernel output matches its reference at the dtype's shared
    tolerance; ``scale`` loosens both bounds for ops with known extra
    error accumulation (multi-level tree reductions)."""
    rtol, atol_rel = DTYPE_TOL[str(dtype)]
    got = np.asarray(got)
    ref = np.asarray(ref)
    if np.iscomplexobj(ref):
        mag = float(np.max(np.abs(ref))) or 1.0
        err = float(np.max(np.abs(got - ref))) / mag
        assert err < rtol * scale, f"relative error {err:.3e}"
        return
    got = got.astype(np.float32)
    ref = ref.astype(np.float32)
    atol = atol_rel * max(float(np.max(np.abs(ref))), 1.0)
    np.testing.assert_allclose(got, ref, rtol=rtol * scale,
                               atol=atol * scale)


# ---------------------------------------------------------------------------
# Differential kernel-vs-reference case table
# ---------------------------------------------------------------------------
# Shapes deliberately include odd/prime batches (3, 5, 7 — e.g. a serve
# engine with 3 active slots) and non-power-of-two lengths: the config
# normalizers must fit tuned knobs to them and the kernels must still
# match their references bit-for-tolerance. Lengths stay even because
# the radix-based spaces have no valid config for odd n (asserted in
# test_kernels_differential.py, so the boundary is pinned, not implied).

ODD_BATCH_SHAPES: Tuple[Tuple[int, int], ...] = (
    (3, 256),    # prime batch, pow2 length
    (7, 96),     # prime batch, non-pow2 length (96 = 2^5 * 3)
    (5, 128),
)


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One (entry point, dtype, shape) differential check."""

    entry: str                       # ops.py entry-point name (test id)
    dtype: str
    batch: int
    n: int
    run: Callable[[str, int, int], None]   # (dtype, batch, n) -> asserts

    @property
    def id(self) -> str:
        return f"{self.entry}-{self.dtype}-b{self.batch}-n{self.n}"

    def __call__(self) -> None:
        self.run(self.dtype, self.batch, self.n)


def _rng(tag: str) -> np.random.Generator:
    # crc32, not hash(): string hashing is salted per process, and these
    # suites promise run-to-run reproducible inputs
    return np.random.default_rng(zlib.crc32(tag.encode()))


def _run_prefix_sum(dtype, batch, n):
    import jax.numpy as jnp

    from repro.kernels.scan.ops import prefix_sum
    from repro.kernels.scan.ref import scan_add_ref
    x = jnp.asarray(_rng(f"scan{batch}x{n}").normal(size=(batch, n)),
                    getattr(jnp, dtype))
    got = prefix_sum(x, interpret=True, use_pallas=True)
    assert_kernel_close(got, scan_add_ref(x), dtype)


def _run_linear_recurrence(dtype, batch, n):
    import jax.numpy as jnp

    from repro.kernels.scan.ops import linear_recurrence
    from repro.kernels.scan.ref import scan_linrec_assoc_ref
    rng = _rng(f"linrec{batch}x{n}")
    a = jnp.asarray(rng.uniform(0.8, 0.99, size=(batch, n)),
                    getattr(jnp, dtype))
    b = jnp.asarray(rng.normal(size=(batch, n)), getattr(jnp, dtype))
    got = linear_recurrence(a, b, interpret=True, use_pallas=True)
    assert_kernel_close(got, scan_linrec_assoc_ref(a, b), dtype)


def _run_tridiag(variant):
    def run(dtype, batch, n):
        import jax

        from repro.kernels.tridiag import ops
        from repro.kernels.tridiag.ref import random_system, thomas_ref
        a, b, c, d = random_system(jax.random.PRNGKey(batch * 1000 + n),
                                   batch, n)
        got = ops.solve(a, b, c, d, variant=variant)
        # diagonally-dominant solves are well conditioned but the parallel
        # eliminations reassociate heavily vs Thomas: shared f32 tol x50
        assert_kernel_close(got, thomas_ref(a, b, c, d), dtype, scale=50.0)
    return run


def _run_fft(dtype, batch, n):
    import jax.numpy as jnp

    from repro.kernels.fft.ops import fft
    from repro.kernels.fft.ref import fft_ref
    rng = _rng(f"fft{batch}x{n}")
    x = jnp.asarray(rng.normal(size=(batch, n))
                    + 1j * rng.normal(size=(batch, n)), jnp.complex64)
    got = fft(x, interpret=True)
    assert_kernel_close(got, fft_ref(x), dtype)


def _run_matmul(dtype, batch, n):
    import jax.numpy as jnp

    from repro.kernels.matmul.ops import matmul
    from repro.kernels.matmul.ref import matmul_ref
    rng = _rng(f"matmul{batch}x{n}")
    k = 65                      # prime inner dim
    a = jnp.asarray(rng.normal(size=(batch * 11, k)), getattr(jnp, dtype))
    b = jnp.asarray(rng.normal(size=(k, n)), getattr(jnp, dtype))
    got = matmul(a, b, interpret=True, use_pallas=True)
    assert_kernel_close(got, matmul_ref(a, b), dtype, scale=10.0)


def _run_ssd(dtype, batch, n):
    import jax

    from repro.kernels.ssd.ops import ssd
    from repro.kernels.ssd.ref import ssd_ref
    ks = jax.random.split(jax.random.PRNGKey(batch * 1000 + n), 4)
    x = jax.random.normal(ks[0], (batch, n, 2, 16))
    a = jax.random.uniform(ks[1], (batch, n, 2), minval=0.85, maxval=0.999)
    b = jax.random.normal(ks[2], (batch, n, 8)) * 0.3
    c = jax.random.normal(ks[3], (batch, n, 8)) * 0.3
    got = ssd(x, a, b, c, interpret=True)
    assert_kernel_close(got, ssd_ref(x, a, b, c), dtype, scale=10.0)


def _run_rglru(dtype, batch, n):
    import jax

    from repro.kernels.rglru.ops import rglru
    from repro.kernels.rglru.ref import rglru_ref
    ks = jax.random.split(jax.random.PRNGKey(batch * 1000 + n), 2)
    a = jax.random.uniform(ks[0], (batch, n, 16), minval=0.8, maxval=0.99)
    u = jax.random.normal(ks[1], (batch, n, 16))
    got = rglru(a, u, interpret=True)
    assert_kernel_close(got, rglru_ref(a, u), dtype, scale=10.0)


def _run_ssd_fused(dtype, batch, n):
    """Chain-fusion differential: fuse=1 (fused state-apply launch) must
    match both fuse=0 (phase-B through the shared linrec block) and the
    sequential reference on the same odd/prime grid."""
    import jax

    from repro.kernels.ssd.ops import ssd
    from repro.kernels.ssd.ref import ssd_ref
    ks = jax.random.split(jax.random.PRNGKey(batch * 1000 + n), 4)
    x = jax.random.normal(ks[0], (batch, n, 2, 16))
    a = jax.random.uniform(ks[1], (batch, n, 2), minval=0.85, maxval=0.999)
    b = jax.random.normal(ks[2], (batch, n, 8)) * 0.3
    c = jax.random.normal(ks[3], (batch, n, 8)) * 0.3
    cfg = {"tile_n": min(128, n), "radix": 2}
    fused = ssd(x, a, b, c, config=dict(cfg, fuse=1), interpret=True,
                use_pallas=True)
    unfused = ssd(x, a, b, c, config=dict(cfg, fuse=0), interpret=True,
                  use_pallas=True)
    assert_kernel_close(fused, unfused, dtype, scale=10.0)
    assert_kernel_close(fused, ssd_ref(x, a, b, c), dtype, scale=10.0)


def _run_rglru_fused(dtype, batch, n):
    """fuse=1 folds the gate into the scan kernel's first stage; must
    match the unfused chain (XLA gate pass) and the oracle."""
    import jax

    from repro.kernels.rglru.ops import rglru
    from repro.kernels.rglru.ref import rglru_ref
    ks = jax.random.split(jax.random.PRNGKey(batch * 1000 + n), 2)
    a = jax.random.uniform(ks[0], (batch, n, 16), minval=0.8, maxval=0.99)
    u = jax.random.normal(ks[1], (batch, n, 16))
    cfg = {"tile_n": min(128, n), "rows_per_program": 8, "radix": 2}
    fused = rglru(a, u, config=dict(cfg, fuse=1), interpret=True,
                  use_pallas=True)
    unfused = rglru(a, u, config=dict(cfg, fuse=0), interpret=True,
                    use_pallas=True)
    assert_kernel_close(fused, unfused, dtype, scale=10.0)
    assert_kernel_close(fused, rglru_ref(a, u), dtype, scale=10.0)


def _run_prefix_sum_radix(radix):
    """Mixed-radix stage plans: the forced radix does NOT divide n, so the
    plan's ragged final stage (stage_radices) is on the execution path."""
    def run(dtype, batch, n):
        import jax.numpy as jnp

        from repro.kernels.scan.ops import prefix_sum
        from repro.kernels.scan.ref import scan_add_ref
        x = jnp.asarray(_rng(f"scanr{radix}x{batch}x{n}").normal(
            size=(batch, n)), getattr(jnp, dtype))
        got = prefix_sum(x, config={"radix": radix, "tile_n": n},
                         interpret=True, use_pallas=True)
        assert_kernel_close(got, scan_add_ref(x), dtype)
    return run


def _run_linrec_radix(radix):
    def run(dtype, batch, n):
        import jax.numpy as jnp

        from repro.kernels.scan.ops import linear_recurrence
        from repro.kernels.scan.ref import scan_linrec_assoc_ref
        rng = _rng(f"linrecr{radix}x{batch}x{n}")
        a = jnp.asarray(rng.uniform(0.8, 0.99, size=(batch, n)),
                        getattr(jnp, dtype))
        b = jnp.asarray(rng.normal(size=(batch, n)), getattr(jnp, dtype))
        got = linear_recurrence(a, b, config={"radix": radix, "tile_n": n},
                                interpret=True, use_pallas=True)
        assert_kernel_close(got, scan_linrec_assoc_ref(a, b), dtype)
    return run


def _run_fft_radix(radix):
    """Historically crashed at trace time (rr = min(radix, n_cur) stopped
    dividing n_cur); the plan's exact factorization must launch and match."""
    def run(dtype, batch, n):
        import jax.numpy as jnp

        from repro.kernels.fft.ops import fft
        from repro.kernels.fft.ref import fft_ref
        rng = _rng(f"fftr{radix}x{batch}x{n}")
        x = jnp.asarray(rng.normal(size=(batch, n))
                        + 1j * rng.normal(size=(batch, n)), jnp.complex64)
        got = fft(x, config={"radix": radix}, interpret=True)
        assert_kernel_close(got, fft_ref(x), dtype)
    return run


def _run_attention(dtype, batch, n):
    import jax
    import jax.numpy as jnp

    from repro.kernels.attention.ops import attention
    from repro.kernels.attention.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(batch * 1000 + n), 3)
    q = jax.random.normal(ks[0], (batch, n, 64), getattr(jnp, dtype))
    k = jax.random.normal(ks[1], (batch, n, 64), getattr(jnp, dtype))
    v = jax.random.normal(ks[2], (batch, n, 64), getattr(jnp, dtype))
    got = attention(q, k, v, causal=True, interpret=True, use_pallas=True)
    assert_kernel_close(got, attention_ref(q, k, v, causal=True), dtype,
                        scale=10.0)


# entry -> (runner, dtypes, shapes). Shapes default to the shared
# odd/prime table; ops with extra constraints narrow them here, visibly.
_KERNEL_TABLE = {
    "prefix_sum": (_run_prefix_sum, ("float32", "bfloat16"),
                   ODD_BATCH_SHAPES),
    "linear_recurrence": (_run_linear_recurrence, ("float32",),
                          ODD_BATCH_SHAPES),
    "solve_pcr": (_run_tridiag("pcr"), ("float32",), ODD_BATCH_SHAPES),
    "solve_cr": (_run_tridiag("cr"), ("float32",), ((3, 96), (5, 100))),
    "solve_lf": (_run_tridiag("lf"), ("float32",), ((7, 96),)),
    "solve_wm": (_run_tridiag("wm"), ("float32",), ((5, 96),)),
    "fft": (_run_fft, ("complex64",), ODD_BATCH_SHAPES),
    # mixed-radix stage plans: radix does not divide n (96 = 2^5*3,
    # 768 = 2^8*3), odd/prime batches — exercises the ragged final stage
    "prefix_sum_radix3": (_run_prefix_sum_radix(3), ("float32",),
                          ((7, 96), (3, 768))),
    "prefix_sum_radix8": (_run_prefix_sum_radix(8), ("float32",),
                          ((7, 96), (3, 768))),
    "linear_recurrence_radix8": (_run_linrec_radix(8), ("float32",),
                                 ((5, 96),)),
    "fft_radix3": (_run_fft_radix(3), ("complex64",), ((5, 96),)),
    "fft_radix8": (_run_fft_radix(8), ("complex64",), ((7, 96), (3, 768))),
    # matmul shapes: (batch*11) x 65 x n — every dim odd or prime-factored
    "matmul": (_run_matmul, ("float32", "bfloat16"), ((3, 96), (5, 128))),
    "ssd": (_run_ssd, ("float32",), ((3, 96),)),
    # chain-fusion differentials: fused == unfused == oracle. ssd shapes
    # pick nc = 2 and nc = 3 chunks — odd nc has no valid phase-B linrec
    # config, so fuse=0 crosses the XLA fallback while fuse=1 stays fused
    "ssd_fused": (_run_ssd_fused, ("float32",), ((3, 256), (5, 384))),
    "rglru": (_run_rglru, ("float32",), ((3, 96), (5, 128))),
    "rglru_fused": (_run_rglru_fused, ("float32",), ODD_BATCH_SHAPES),
    "attention": (_run_attention, ("float32",), ((3, 192), (5, 256))),
}

KERNEL_CASES = tuple(
    KernelCase(entry, dtype, batch, n, run)
    for entry, (run, dtypes, shapes) in sorted(_KERNEL_TABLE.items())
    for dtype in dtypes
    for batch, n in shapes
)


def kernel_ops_entries() -> Sequence[str]:
    """Entry names the table covers (asserted against the registry)."""
    return tuple(sorted(_KERNEL_TABLE))


def pytest_generate_tests(metafunc):
    if "kernel_case" in metafunc.fixturenames:
        metafunc.parametrize("kernel_case", KERNEL_CASES,
                             ids=[c.id for c in KERNEL_CASES])
