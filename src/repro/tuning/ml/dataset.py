"""Labeled training data for the learned config predictor.

Two sources, mirroring the paper's offline pipeline:

  * **exhaustive sweeps** (``core/exhaustive.py`` semantics): every valid
    config of a workload's space evaluated on the offline objective — the
    dense signal the forest actually learns the ranking from;
  * **TuningDB records**: the winners persisted by earlier offline tuning
    runs; sparse (one config per workload) but real, so they ride along.

Labels are ``log(slowdown)`` vs the workload group's best config: the
winner of every group sits at exactly 0.0.  Prediction is only ever
*compared within one workload* — pinning the winner to one aligned level
across groups removes the absolute-scale burden (times span four orders
of magnitude across N) and spends all model capacity on the ranking,
which is what top-1 match and slowdown measure.

Splits follow the paper's generalization axis: train on problem sizes
{N_train}, evaluate on *unseen* sizes — never a random row split, which
would leak every size into training.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.paper_ops import TOTAL_ELEMS
from repro.core.objective import CostModelObjective, Objective
from repro.core.space import Config, Workload, build_space
from repro.tuning.db import TuningDB
from repro.tuning.ml.features import N_FEATURES, featurize_batch
from repro.tuning.sweep import SweepJournal, config_key, run_sweep

# ---------------------------------------------------------------------------
# Default suite: per-op train / holdout problem sizes (paper Table I sizes)
# ---------------------------------------------------------------------------
# Holdout sizes sit strictly *between or beyond* train sizes so eval-model
# measures interpolation/extrapolation to unseen N, not memorization.

SUITE: Dict[str, Dict] = {
    "scan": {"variants": ("lf", "ks", "linrec"),
             "train": (128, 256, 1024, 2048), "holdout": (512, 4096)},
    "ssd": {"variants": ("",), "train": (256, 1024), "holdout": (512,)},
    "rglru": {"variants": ("",), "train": (256, 1024), "holdout": (512,)},
    "tridiag": {"variants": ("cr", "pcr", "wm"),
                "train": (64, 128, 512, 1024), "holdout": (256,)},
    "fft": {"variants": ("stockham",),
            "train": (64, 128, 512, 2048, 4096), "holdout": (256, 1024)},
    "large_fft": {"variants": ("stockham",),
                  "train": (8192, 1048576, 8388608), "holdout": (65536,)},
    "attention": {"variants": ("flash",),
                  "train": (512, 1024, 4096), "holdout": (2048,),
                  "batch": 64},
    "matmul": {"variants": ("",),
               "train": (512, 2048), "holdout": (1024,), "batch": 1024},
}


# Ops that share a search space and cost structure train one pooled forest
# (tripling the scan family's rows); ModelBundle.meta["aliases"] routes
# lookups for the aliased ops back to the pooled key.
POOLED_OPS: Dict[str, str] = {"ssd": "scan", "rglru": "scan"}


def _batch_for(op: str, n: int) -> int:
    fixed = SUITE.get(op, {}).get("batch")
    return int(fixed) if fixed else max(TOTAL_ELEMS // n, 1)


def suite_workloads(split: str = "train",
                    ops: Optional[Iterable[str]] = None) -> List[Workload]:
    """The default (op, variant, size) grid for one split."""
    assert split in ("train", "holdout"), split
    selected = list(ops) if ops else list(SUITE)
    unknown = [op for op in selected if op not in SUITE]
    if unknown:
        raise ValueError(f"unknown op(s) {', '.join(map(repr, unknown))}; "
                         f"known: {', '.join(sorted(SUITE))}")
    out = []
    for op in selected:
        spec = SUITE[op]
        for variant in spec["variants"]:
            for n in spec[split]:
                out.append(Workload(op=op, n=n, batch=_batch_for(op, n),
                                    variant=variant))
    return out


# ---------------------------------------------------------------------------
# Dataset container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Dataset:
    """Feature rows + labels, grouped by workload key."""

    X: np.ndarray                         # (rows, N_FEATURES)
    y: np.ndarray                         # (rows,) log-time, group-centered
    group: np.ndarray                     # (rows,) index into .keys
    keys: List[str]                       # workload key per group
    ops: List[str]                        # op per group

    def __len__(self) -> int:
        return len(self.y)

    def by_op(self, pool: Optional[Dict[str, str]] = None
              ) -> Dict[str, Tuple[np.ndarray, np.ndarray]]:
        """Per-op (X, y) splits for ``forest.train_bundle``.

        ``pool`` merges rows of aliased ops into their pooled key (default:
        ``POOLED_OPS``); pass ``{}`` to keep every op separate.
        """
        pool = POOLED_OPS if pool is None else pool
        out: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        op_per_row = np.array([pool.get(self.ops[g], self.ops[g])
                               for g in self.group])
        for op in sorted(set(op_per_row)):
            mask = op_per_row == op
            out[op] = (self.X[mask], self.y[mask])
        return out


class _Builder:
    def __init__(self) -> None:
        self.rows: List[np.ndarray] = []
        self.labels: List[float] = []
        self.group: List[int] = []
        self.keys: List[str] = []
        self.ops: List[str] = []

    def add_group(self, wl: Workload, X: np.ndarray,
                  times: Sequence[float]) -> None:
        if not len(X):
            return
        # label = log(slowdown vs the group's best): 0.0 marks the winner in
        # EVERY group, so "what a winner looks like" is one aligned level
        # across problem sizes (mean-centering left it group-dependent and
        # near-twin features across sizes got contradictory labels)
        logs = np.log(np.maximum(np.asarray(times, np.float64), 1e-12))
        logs -= logs.min()
        gid = len(self.keys)
        self.keys.append(wl.key)
        self.ops.append(wl.op)
        self.rows.extend(X)
        self.labels.extend(logs)
        self.group.extend([gid] * len(X))

    def build(self) -> Dataset:
        if not self.rows:
            return Dataset(np.empty((0, N_FEATURES)), np.empty(0),
                           np.empty(0, np.int64), [], [])
        return Dataset(np.stack(self.rows), np.asarray(self.labels),
                       np.asarray(self.group, np.int64), self.keys, self.ops)


# ---------------------------------------------------------------------------
# Sources
# ---------------------------------------------------------------------------

def sweep_workload(wl: Workload, objective: Optional[Objective] = None,
                   journal_dir: Optional[str] = None,
                   policy: Optional[str] = None
                   ) -> Tuple[List[Config], np.ndarray, np.ndarray]:
    """Exhaustively evaluate ``wl``'s valid space on the offline objective.

    Returns (configs, feature rows, labels). This is the dense ground
    truth: identical to what ``ExhaustiveSearch`` visits, kept as arrays
    instead of a ``TuneResult`` so every (config, label) pair becomes a
    training row rather than just the winner.  Runs on the vectorized
    sweep engine; with ``journal_dir`` the sweep checkpoints to (and
    resumes from) the per-(workload, objective) journal.

    ``policy`` makes the labels metric-aware: instead of raw seconds the
    group is labeled with that policy's scalars over the sweep's metric
    vectors (see ``repro.core.policy``), so a forest can learn the
    energy/EDP ranking from the same sweeps.  The journal stays keyed by
    the raw objective — one sweep feeds every policy's dataset.  Default
    ``None`` keeps the historical time labels bit-for-bit.
    """
    objective = objective or CostModelObjective()
    wl = wl.canonical()
    space = build_space(wl)
    journal = SweepJournal.for_workload(journal_dir, wl, objective) \
        if journal_dir else None
    res = run_sweep(space, objective, journal=journal)
    cfgs = [c for c, _ in res.history]
    times = np.array([t for _, t in res.history])
    if policy is not None:
        from repro.core.policy import get_policy, policy_scalar_cols
        pol = get_policy(policy, getattr(space, "spec", None))
        if pol.name != "latency" and res.metrics is not None:
            times = policy_scalar_cols(pol, res.metrics)
    X = featurize_batch(space, cfgs)
    return cfgs, X, times


def build_dataset(workloads: Iterable[Workload],
                  objective: Optional[Objective] = None,
                  on_sweep: Optional[Callable] = None,
                  journal_dir: Optional[str] = None,
                  policy: Optional[str] = None) -> Dataset:
    """Sweep every workload; one centered group per workload.

    ``on_sweep(wl, cfgs, times)`` is invoked once per workload with the
    sweep results, so callers (e.g. ``tune.py train-model --db``) can
    persist each exhaustive winner without sweeping a second time.
    ``journal_dir`` checkpoints every sweep (see ``repro.tuning.sweep``),
    making a long dataset build resumable.  ``policy`` labels every group
    with that policy's scalars instead of raw seconds (see
    :func:`sweep_workload`).
    """
    objective = objective or CostModelObjective()
    b = _Builder()
    for wl in workloads:
        wl = wl.canonical()
        cfgs, X, times = sweep_workload(wl, objective,
                                        journal_dir=journal_dir,
                                        policy=policy)
        b.add_group(wl, X, times)
        if on_sweep is not None:
            on_sweep(wl, cfgs, times)
    return b.build()


def dataset_from_journal(path: str,
                         signature: Optional[str] = None,
                         policy: Optional[str] = None) -> Dataset:
    """One journal file -> one labeled group (no re-evaluation).

    ``policy`` labels the group with that policy's scalars over the
    journal's metric vectors (version-3 journals record them; pre-vector
    entries fall back to their time — see ``repro.core.policy``).

    The journal header carries the workload; every completed entry whose
    config is still valid in the current space becomes a training row.
    ``signature`` (an ``Objective.signature()`` string) skips journals
    measured under a different objective — mixing, say, noisy and
    noiseless sweeps of one workload would produce conflicting labels.
    Journals from *interrupted* sweeps load too — the group is centered on
    the best time present, which is only a lower bound, but ``run_sweep``
    will finish them on the next resume.  Journals a *pruned* sweep
    started are skipped until some run completes the full space: a pruned
    subset's winner is permanently unguaranteed, and label 0.0 means
    "this IS the group optimum" (same exclusion the DB path applies to
    ``exhaustive-pruned`` records).
    """
    b = _Builder()
    journal = SweepJournal(path)
    header = journal.read_header()
    if header is None or "workload" not in header:
        return b.build()
    if signature is not None and header.get("objective") != signature:
        return b.build()
    raw_entries = journal.entries()
    if header.get("pruned") and len(raw_entries) < header.get("space_size",
                                                              float("inf")):
        return b.build()
    w = header["workload"]
    try:
        wl = Workload(op=w["op"], n=int(w["n"]), batch=int(w["batch"]),
                      dtype=w.get("dtype", "float32"),
                      variant=w.get("variant", "")).canonical()
        space = build_space(wl)
    except (KeyError, ValueError):
        return b.build()
    # featurize over the FULL valid set and select the measured rows: the
    # space-context columns (rank percentiles etc.) are defined relative to
    # every candidate in the space, and must match what sweep_workload
    # produced at training time and MLStrategy computes at predict time —
    # ranking a partial journal's subset against itself would give the same
    # config a different feature vector
    all_cfgs = space.enumerate_valid()
    index = {config_key(c): i for i, c in enumerate(all_cfgs)}
    labels = [t for _, t in raw_entries]
    if policy is not None:
        from repro.core.policy import get_policy, policy_scalar_cols
        pol = get_policy(policy, getattr(space, "spec", None))
        if pol.name != "latency":
            # metric_entries dedups exactly like entries, so the vectors
            # are positionally parallel to raw_entries
            vecs = [v for _, v in journal.metric_entries()]
            axes = sorted({k for v in vecs for k in v})
            cols = {a: np.array([v.get(a, np.nan) for v in vecs])
                    for a in axes}
            labels = list(policy_scalar_cols(pol, cols))
    rows, times = [], []
    for j, (cfg, _) in enumerate(raw_entries):
        i = index.get(config_key(cfg))
        if i is not None:              # skips configs no longer enumerated
            rows.append(i)
            times.append(labels[j])
    if rows:
        b.add_group(wl, featurize_batch(space, all_cfgs)[rows], times)
    return b.build()


def dataset_from_journal_dir(journal_dir: str,
                             objective: Optional[Objective] = None,
                             policy: Optional[str] = None) -> Dataset:
    """Every ``*.jsonl`` sweep journal under ``journal_dir``, merged.

    Pass the ``objective`` the sweeps were measured with to load only its
    journals — a directory that accumulated sweeps under several
    objectives (different noise, different cost models) would otherwise
    contribute duplicate groups of one workload with inconsistent times.
    ``policy`` forwards to :func:`dataset_from_journal` (metric-aware
    labels).
    """
    import glob
    import os
    signature = objective.signature() if objective is not None else None
    parts = [dataset_from_journal(p, signature=signature, policy=policy)
             for p in sorted(glob.glob(os.path.join(journal_dir, "*.jsonl")))]
    return merge(*parts) if parts else _Builder().build()


def parse_db_key(key: str) -> Optional[Workload]:
    """Invert ``"<platform>|op:variant:nN:bB:dtype"`` back to a Workload."""
    body = key.split("|", 1)[-1]
    parts = body.split(":")
    if len(parts) != 5:
        return None
    op, variant, n_s, b_s, dtype = parts
    if not (n_s.startswith("n") and b_s.startswith("b")):
        return None
    try:
        return Workload(op=op, n=int(n_s[1:]), batch=int(b_s[1:]),
                        dtype=dtype, variant="" if variant == "default" else variant)
    except ValueError:
        return None


def dataset_from_db(db: TuningDB,
                    methods: Sequence[str] = ("exhaustive", "exhausted")
                    ) -> Dataset:
    """Turn persisted offline winners into (sparse) training rows.

    A single-row group's label is forced to 0.0 ("this is the optimum") by
    the per-group centering, so only entries stored by an exhaustive
    search — whose winner really is the group optimum — are eligible by
    default.  A ``bayesian``/``random`` winner a few ten-percent off the
    true best would otherwise teach the forest that a mediocre feature
    pattern is optimal.  Groups whose key cannot be parsed, whose op has
    no space, or whose config is no longer valid are skipped.
    """
    allowed = set(methods)
    b = _Builder()
    for key, entry in sorted(db.entries().items()):
        wl = parse_db_key(key)
        if wl is None or "config" not in entry:
            continue
        if entry.get("method") not in allowed:
            continue
        try:
            space = build_space(wl.canonical())
            cfg = dict(entry["config"])
            if not space.is_valid(cfg):
                continue
            # context features need the full candidate set; keep cfg's row
            cfgs = space.enumerate_valid()
            i = cfgs.index(cfg)
            X = featurize_batch(space, cfgs)[i: i + 1]
        except (KeyError, ValueError, TypeError):
            # unknown op, config no longer enumerated, or a malformed
            # record (e.g. an unparseable dtype): skip, don't abort training
            continue
        b.add_group(wl.canonical(), X, [float(entry.get("time_s", 1.0))])
    return b.build()


def merge(*datasets: Dataset) -> Dataset:
    """Concatenate datasets, re-basing group ids."""
    parts = [d for d in datasets if len(d)]
    if not parts:
        return Dataset(np.empty((0, N_FEATURES)), np.empty(0),
                       np.empty(0, np.int64), [], [])
    keys: List[str] = []
    ops: List[str] = []
    groups = []
    for d in parts:
        groups.append(d.group + len(keys))
        keys.extend(d.keys)
        ops.extend(d.ops)
    return Dataset(np.concatenate([d.X for d in parts]),
                   np.concatenate([d.y for d in parts]),
                   np.concatenate(groups), keys, ops)


def split_by_size(workloads: Iterable[Workload],
                  holdout_sizes: Dict[str, Sequence[int]]
                  ) -> Tuple[List[Workload], List[Workload]]:
    """Partition workloads into (train, holdout) by per-op problem size."""
    train, hold = [], []
    for wl in workloads:
        if wl.n in set(holdout_sizes.get(wl.op, ())):
            hold.append(wl)
        else:
            train.append(wl)
    return train, hold
