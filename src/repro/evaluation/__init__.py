"""repro.evaluation — cross-methodology evaluation harnesses.

``repro.evaluation.compare`` reproduces the paper's Table-II-style
comparison: every tuning methodology scored against the exhaustive
optimum (Phi, mean slowdown, evaluation counts).
"""
from repro.evaluation.compare import (check_report, compare_methods,
                                      format_report)

__all__ = ["check_report", "compare_methods", "format_report"]
