"""Deterministic synthetic LM data pipeline with prefix-sum packing.

Documents of power-law lengths are drawn from a seeded generator, then
packed into fixed-length training rows. Packing offsets are computed with
the *tuned scan primitive* (prefix sum of document lengths) — the paper's
kernel dogfooded by the framework's own input path.

The pipeline is host-side numpy (per-host sharding by host id), yielding
already-padded (tokens, targets, mask) batches ready for device_put with a
batch NamedSharding.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.scan.ops import prefix_sum


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    bos_id: int = 1
    pad_id: int = 0


class SyntheticCorpus:
    """Infinite deterministic document stream (zipf-ish unigrams so the
    loss curve is non-trivial: frequent tokens are learnable)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, host_id]))
        self.n_hosts = n_hosts
        # fixed zipf weights over the vocab
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def documents(self) -> Iterator[np.ndarray]:
        cfg = self.cfg
        while True:
            length = int(np.clip(self.rng.pareto(1.5) * cfg.mean_doc_len * 0.5
                                 + 16, 16, 4 * cfg.mean_doc_len))
            # first-order structure: next token correlated with previous
            toks = self.rng.choice(cfg.vocab, size=length, p=self.probs)
            shift = np.roll(toks, 1)
            mix = self.rng.random(length) < 0.3
            toks = np.where(mix, (shift * 31 + 7) % cfg.vocab, toks)
            toks[0] = cfg.bos_id
            yield toks.astype(np.int32)


def pack_documents(docs, seq_len: int, batch: int, pad_id: int = 0,
                   use_kernel_scan: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Greedy sequential packing of docs into (batch, seq_len+1) rows.

    Row boundaries come from the prefix sum of document lengths — computed
    with the tuned scan op when requested (CPU ref path otherwise).
    """
    rows = np.full((batch, seq_len + 1), pad_id, np.int32)
    seg = np.zeros((batch, seq_len + 1), np.int32)
    lengths = []
    chunks = []
    total = 0
    while total < batch * (seq_len + 1):
        d = next(docs)
        chunks.append(d)
        lengths.append(len(d))
        total += len(d)
    lens = np.asarray(lengths, np.float32)[None, :]
    if use_kernel_scan:
        offsets = np.asarray(prefix_sum(jnp.asarray(lens), interpret=True))[0]
    else:
        offsets = np.asarray(prefix_sum(jnp.asarray(lens), use_pallas=False))[0]
    starts = np.concatenate([[0], offsets[:-1]]).astype(np.int64)
    stream = np.concatenate(chunks)[: batch * (seq_len + 1)]
    rows = stream.reshape(batch, seq_len + 1).astype(np.int32)
    # segment ids from document starts (for packed-attention masks)
    doc_marks = np.zeros(batch * (seq_len + 1), np.int32)
    valid = starts[starts < batch * (seq_len + 1)].astype(np.int64)
    doc_marks[valid] = 1
    seg = np.cumsum(doc_marks).reshape(batch, seq_len + 1).astype(np.int32)
    return rows, seg, offsets


class Batcher:
    """Yields {tokens, targets, mask} host arrays of the global batch shard
    owned by this host."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg, host_id, n_hosts)
        self.docs = self.corpus.documents()
        self.local_batch = cfg.global_batch // n_hosts

    def __iter__(self):
        return self

    def __next__(self):
        cfg = self.cfg
        rows, seg, _ = pack_documents(self.docs, cfg.seq_len,
                                      self.local_batch, cfg.pad_id)
        tokens = rows[:, :-1]
        targets = rows[:, 1:]
        mask = ((targets != cfg.pad_id)
                & (seg[:, 1:] == seg[:, :-1])).astype(np.float32)
        return {"tokens": tokens, "targets": targets, "mask": mask}
