"""Fault tolerance: step watchdog, straggler detection, elastic re-mesh.

On a real pod these hooks pair with the cluster coordinator (preemption
signals, ICI health). In this repo the logic is deterministic and fully
unit-tested with simulated clocks:

  * HeartbeatWatchdog — flags a stalled step when no heartbeat lands within
    `timeout x EMA(step_time)`; the loop responds by checkpoint-and-raise
    (so the job restarts from the last manifest instead of hanging).
  * StragglerDetector — per-step EMA; a step slower than `threshold x EMA`
    is a straggler event. Policy "log" | "abort" (abort -> restart path).
  * ElasticPlan — given a shrunken device set, recompute the largest mesh
    that preserves the model axis (TP cannot shrink without resharding
    weights layouts; the data axis absorbs losses), and report the
    new global batch so the data pipeline can rescale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple


class StragglerDetector:
    def __init__(self, threshold: float = 2.5, ema_alpha: float = 0.2,
                 warmup_steps: int = 5):
        self.threshold = threshold
        self.alpha = ema_alpha
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.seen = 0
        self.events: List[Tuple[int, float, float]] = []

    def observe(self, step: int, step_time: float) -> bool:
        """Returns True if this step is a straggler."""
        self.seen += 1
        if self.ema is None:
            self.ema = step_time
            return False
        is_straggler = (self.seen > self.warmup
                        and step_time > self.threshold * self.ema)
        if is_straggler:
            self.events.append((step, step_time, self.ema))
        else:
            # stragglers don't poison the EMA
            self.ema = (1 - self.alpha) * self.ema + self.alpha * step_time
        return is_straggler


class HeartbeatWatchdog:
    """Deadline tracker (pure logic — poll() is called by the supervisor)."""

    def __init__(self, timeout_factor: float = 5.0, min_timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.factor = timeout_factor
        self.min_timeout = min_timeout
        self.clock = clock
        self.last_beat = clock()
        self.ema: Optional[float] = None

    def beat(self) -> None:
        now = self.clock()
        dt = now - self.last_beat
        self.ema = dt if self.ema is None else 0.8 * self.ema + 0.2 * dt
        self.last_beat = now

    def deadline(self) -> float:
        base = self.ema if self.ema is not None else self.min_timeout
        return max(self.factor * base, self.min_timeout)

    def poll(self) -> bool:
        """True -> stalled (no heartbeat within the deadline)."""
        return (self.clock() - self.last_beat) > self.deadline()


@dataclasses.dataclass
class ElasticPlan:
    data_axis: int
    model_axis: int
    pod_axis: int
    global_batch: int
    dropped_chips: int


def plan_elastic_remesh(available_chips: int, model_axis: int,
                        target_batch: int, pods: int = 1) -> ElasticPlan:
    """Largest (pod, data, model) mesh from the surviving chips.

    Keeps the model axis fixed (weight layouts stay valid so restore is a
    straight load), shrinks data parallelism to the largest fit, and scales
    the global batch to keep per-replica batch constant.
    """
    if available_chips < model_axis:
        raise ValueError(
            f"cannot keep model_axis={model_axis} with only "
            f"{available_chips} chips; full resharding required")
    per_pod = available_chips // pods
    data = max(per_pod // model_axis, 1)
    used = pods * data * model_axis
    # per-replica batch when healthy: target_batch / (pods*data_healthy)
    new_batch = target_batch * (pods * data) // max(pods * data, 1)
    # keep divisibility: round batch down to a multiple of replicas
    replicas = pods * data
    new_batch = max((target_batch // replicas) * replicas, replicas)
    return ElasticPlan(data_axis=data, model_axis=model_axis, pod_axis=pods,
                       global_batch=new_batch,
                       dropped_chips=available_chips - used)


class FaultInjector:
    """Deterministic failure schedule for tests/examples: raises at the
    configured steps to exercise checkpoint-restart."""

    def __init__(self, fail_at_steps: Tuple[int, ...] = ()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")
