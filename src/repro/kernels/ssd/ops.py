"""Tuned SSD op: three-phase chunked state-space dual.

`ssd(x, a, b, c)` with shapes (B, L, H, P), (B, L, H), (B, L, S), (B, L, S).
The chunk length comes from the TuningDB (op="ssd" shares the scan space;
tile_n -> chunk). On CPU hosts the pure-jnp chunked formulation runs (same
math, XLA-fused); the Pallas path is exercised in interpret mode by tests
and compiled on real TPUs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import Workload, get_config
from repro.kernels.scan.ref import scan_linrec_assoc_ref
from repro.kernels.ssd.kernel import ssd_apply_entry_pallas, ssd_intra_pallas
from repro.kernels.ssd.ref import ssd_chunked_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pick_chunk(L: int, cfg: dict) -> int:
    chunk = min(cfg.get("tile_n", 128), L)
    while L % chunk:
        chunk //= 2
    return max(chunk, 1)


def ssd(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
        config: Optional[dict] = None, interpret: Optional[bool] = None,
        use_pallas: Optional[bool] = None) -> jax.Array:
    B, L, H, P = x.shape
    S = b.shape[-1]
    cfg = config or get_config(Workload(op="ssd", n=L, batch=B * H,
                                        variant="chunked"))
    chunk = _pick_chunk(L, cfg)
    if use_pallas is None:
        use_pallas = (not _on_cpu()) or bool(interpret)
    if not use_pallas:
        return ssd_chunked_ref(x, a, b, c, chunk=chunk)
    interpret = _on_cpu() if interpret is None else interpret

    # reshape to (BH, L, ...) rows; broadcast b/c over heads (n_groups=1)
    xbh = jnp.transpose(x, (0, 2, 1, 3)).reshape(B * H, L, P)
    abh = jnp.transpose(a, (0, 2, 1)).reshape(B * H, L)
    bbh = jnp.broadcast_to(b[:, None], (B, H, L, S)).reshape(B * H, L, S)
    cbh = jnp.broadcast_to(c[:, None], (B, H, L, S)).reshape(B * H, L, S)

    y_intra, a_chunk, state = ssd_intra_pallas(
        xbh, abh, bbh, cbh, chunk=chunk, interpret=interpret)
    nc = L // chunk

    # phase B: inter-chunk linear recurrence (rows = BH*S*P, length nc)
    a_rows = jnp.broadcast_to(a_chunk[:, None, None, :], (B * H, S, P, nc))
    s_rows = jnp.transpose(state, (0, 2, 3, 1))          # (BH, S, P, nc)
    h = scan_linrec_assoc_ref(a_rows.reshape(-1, nc), s_rows.reshape(-1, nc))
    h = h.reshape(B * H, S, P, nc)
    entry = jnp.concatenate(
        [jnp.zeros_like(h[..., :1]), h[..., :-1]], axis=-1)
    entry = jnp.transpose(entry, (0, 3, 1, 2))           # (BH, nc, S, P)

    y = ssd_apply_entry_pallas(y_intra, abh, cbh, entry, chunk=chunk,
                               interpret=interpret)
    return jnp.transpose(y.reshape(B, H, L, P), (0, 2, 1, 3))
