"""Fault-tolerance demo: a training job killed mid-run by an injected node
failure auto-resumes from the newest intact checkpoint.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import shutil

import sys, os
sys.path.insert(0, os.path.dirname(__file__))
from train_lm import preset_host
from repro.data.pipeline import Batcher, DataConfig
from repro.models.model import build_model
from repro.train.fault import FaultInjector
from repro.train.loop import LoopConfig, run_training
from repro.train.step import TrainHParams

CKPT = "/tmp/fault_demo_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = preset_host()
hp = TrainHParams(peak_lr=1e-3, warmup_steps=5, total_steps=30, z_weight=0.0)
loop = LoopConfig(total_steps=30, checkpoint_dir=CKPT, checkpoint_every=10,
                  log_every=10)
inj = FaultInjector(fail_at_steps=(17,))


def data():
    return iter(Batcher(DataConfig(vocab=cfg.vocab, seq_len=64,
                                   global_batch=4)))


try:
    run_training(build_model(cfg), hp, loop, data(), injector=inj)
except RuntimeError as e:
    print(f"[fault demo] job died: {e}")

print("[fault demo] restarting (auto-resume from latest checkpoint)...")
out = run_training(build_model(cfg), hp, loop, data(), injector=inj)
print(f"[fault demo] resumed from step {out['resumed_from']}, "
      f"finished at step {out['history'][-1]['step']}")
