"""Versioned, thread-safe JSON config store (the offline -> online handoff).

Schema 4 stamps every entry with its metric vector and the policy it was
tuned under:

    {"schema": 4,
     "entries": {"<platform>|<workload-key>": {"config": {...},
                                               "time_s": ..., "method": ...,
                                               "evaluations": ...,
                                               "profile": "<profile-name>",
                                               "policy": "latency",
                                               "metrics": {"time_s": ...,
                                                           "energy_j": ...}}}}

The platform prefix in the key namespaces devices; the per-entry
``profile`` field makes the device explicit and lets ``lookup`` refuse an
entry whose profile disagrees with the session's (a config tuned for one
device must never silently resolve under another — see docs/hardware.md).
Non-latency winners key under ``<platform>|policy=<key>|<workload-key>``
— latency keys are unchanged from schema 3, so every existing entry keeps
resolving, and an energy-tuned config never answers a latency lookup (or
vice versa).  ``lookup`` double-checks the per-entry ``policy`` stamp.

Legacy files migrate transparently: schema-1 files were a flat
``{key: entry}`` mapping; schema-2 entries lack the ``profile`` field and
are defaulted to their key's platform prefix; schema-3 entries lack
``policy``/``metrics`` and load as latency winners with a ``time_s``-only
metric vector. A key with no platform prefix at all is re-keyed under
``tpu_v5e`` — every pre-profile entry was tuned on the v5e model, and
without the rewrite such entries could never resolve (``lookup`` always
prefixes the session platform). The next ``store`` persists the new
envelope. Unknown top-level envelope keys (annotations from other tools,
future-schema side-channels) are preserved across load/flush rather than
dropped. Writes are atomic (tmp file + ``os.replace``) and serialized by
a lock, so concurrent ``store`` calls from threads never corrupt the
file.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Mapping, Optional

SCHEMA_VERSION = 4

# the only policy that existed before schema 4; also the keyless default
DEFAULT_POLICY = "latency"

# every entry written before the profile field existed was tuned against
# the v5e machine model
LEGACY_PROFILE = "tpu_v5e"

DEFAULT_DB_PATH = os.environ.get(
    "REPRO_TUNING_DB", os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                    "artifacts", "tuning_db.json"))

# the entry-key shapes, as format templates: latency keys keep the schema-3
# shape so pre-policy entries resolve; non-latency winners carry the policy
# segment.  ``repro.analysis`` fingerprints these against SCHEMA_VERSION —
# reshaping a key without bumping the schema orphans every stored winner.
KEY_FORMATS = ("{platform}|{workload_key}",
               "{platform}|policy={policy}|{workload_key}")

# per-entry field layout (same contract, same fingerprint)
ENTRY_FIELDS = ("config", "time_s", "method", "evaluations", "profile",
                "policy", "metrics")


def make_entry(cfg: Dict, time_s: float, method: str, evaluations: int,
               profile: str, policy: str,
               metrics: Mapping[str, float]) -> Dict:
    """One schema-4 DB entry; the single construction site for
    ``ENTRY_FIELDS``."""
    return {"config": dict(cfg), "time_s": time_s, "method": method,
            "evaluations": evaluations, "profile": profile,
            "policy": policy, "metrics": dict(metrics)}


def _migrate_entry(key: str, entry: Dict) -> Dict:
    """Schema <=3 -> 4: stamp profile, policy, and the metric vector.

    Pre-vector entries were all tuned for latency; their scalar ``time_s``
    becomes a ``time_s``-only metric vector.
    """
    if not isinstance(entry, dict):
        return entry
    out = dict(entry)
    if "profile" not in out:
        out["profile"] = key.split("|", 1)[0] if "|" in key else LEGACY_PROFILE
    if "policy" not in out:
        out["policy"] = DEFAULT_POLICY
    if not isinstance(out.get("metrics"), dict):
        out["metrics"] = {"time_s": out.get("time_s")}
    return out


def _migrate_key(key: str) -> str:
    """Bare pre-platform keys re-key under the legacy device so ``lookup``
    (which always prefixes the session platform) can actually find them."""
    return key if "|" in key else f"{LEGACY_PROFILE}|{key}"


class TuningDB:
    """JSON-backed config store; thread-safe; content-addressed by workload key."""

    def __init__(self, path: Optional[str] = None, platform: str = "tpu_v5e"):
        self.path = os.path.abspath(path or DEFAULT_DB_PATH)
        self.platform = platform
        self._lock = threading.Lock()
        self._data: Dict[str, Dict] = {}
        self._extra: Dict[str, object] = {}   # unknown envelope keys, kept
        self._loaded = False

    # -- persistence ---------------------------------------------------------

    def _load(self) -> None:
        if self._loaded:
            return
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    raw = json.load(f)
            except (json.JSONDecodeError, OSError):
                raw = {}
            if isinstance(raw, dict) and "schema" in raw:
                entries = dict(raw.get("entries") or {})
                try:
                    schema = int(raw.get("schema") or 0)
                except (TypeError, ValueError):
                    schema = 0
                if schema < SCHEMA_VERSION:
                    entries = {_migrate_key(k): _migrate_entry(k, v)
                               for k, v in entries.items()}
                self._data = entries
                # preserve unknown envelope keys (annotations written by
                # other tools, future-schema side-channels): they round-trip
                # through the next flush instead of being dropped
                self._extra = {k: v for k, v in raw.items()
                               if k not in ("schema", "entries")}
            else:
                # legacy flat {key: entry} file (schema 1)
                raw = raw if isinstance(raw, dict) else {}
                self._data = {_migrate_key(k): _migrate_entry(k, v)
                              for k, v in raw.items()}
        self._loaded = True

    def _flush_locked(self) -> None:
        directory = os.path.dirname(self.path)
        if directory:
            os.makedirs(directory, exist_ok=True)
        payload = {**self._extra, "schema": SCHEMA_VERSION,
                   "entries": self._data}
        tmp = f"{self.path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    # -- access --------------------------------------------------------------

    def _key(self, wl, policy: Optional[str] = None) -> str:
        pol = policy or DEFAULT_POLICY
        if pol == DEFAULT_POLICY:
            return KEY_FORMATS[0].format(platform=self.platform,
                                         workload_key=wl.key)
        return KEY_FORMATS[1].format(platform=self.platform, policy=pol,
                                     workload_key=wl.key)

    def lookup(self, wl, policy: Optional[str] = None) -> Optional[Dict]:
        pol = policy or DEFAULT_POLICY
        with self._lock:
            self._load()
            entry = self._data.get(self._key(wl, pol))
            if not entry:
                return None
            # defense in depth on top of the key prefix: an entry stamped
            # for another device never resolves here (e.g. a file edited by
            # hand, or a legacy entry migrated under a foreign prefix) —
            # and same for the policy stamp
            if entry.get("profile", self.platform) != self.platform:
                return None
            if entry.get("policy", DEFAULT_POLICY) != pol:
                return None
            return dict(entry["config"])

    def store(self, wl, cfg: Dict, time_s: float, method: str,
              evaluations: int = 0, *,
              metrics: Optional[Mapping[str, float]] = None,
              policy: Optional[str] = None) -> None:
        pol = policy or DEFAULT_POLICY
        vec = {k: float(v) for k, v in (metrics or {}).items()}
        vec.setdefault("time_s", float(time_s))
        with self._lock:
            self._load()
            self._data[self._key(wl, pol)] = make_entry(
                cfg, time_s, method, evaluations, self.platform, pol, vec)
            self._flush_locked()

    def entries(self) -> Dict[str, Dict]:
        with self._lock:
            self._load()
            return dict(self._data)

    def __len__(self) -> int:
        with self._lock:
            self._load()
            return len(self._data)
