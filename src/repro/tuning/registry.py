"""Kernel registry: one declaration per tuned op.

``@tuned_kernel(...)`` ties together, in one place, everything the tuning
layer needs to know about a kernel family:

  op name -> search-space builder, pallas impl, reference impl, config
  normalizer (how raw tuned knobs are fitted to the actual launch dims).

The decorated function is the public entry point; the declaration is
attached as ``fn.kernel_spec`` and recorded so that

  * ``TunerSession.resolve`` finds the op's normalizer (the single
    config-resolution pipeline — no per-ops.py ``_norm_cfg`` copies),
  * the op's space builder is registered with ``repro.core.space`` so
    ``build_space`` works for it,
  * tooling can enumerate every tuned entry point (``registered_kernels``).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Mapping, Optional, Tuple

from repro.core.space import (Config, SearchSpace, Workload, normalize_config,
                              register_space)

# normalizer signature: (raw_cfg, workload, dims) -> launch kwargs
Normalizer = Callable[[Mapping[str, int], Workload, Optional[Mapping[str, int]]],
                      Config]
SpaceBuilder = Callable[[Workload], SearchSpace]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Declarative record for one tuned kernel entry point."""

    op: str
    entry_name: str
    space: Optional[SpaceBuilder] = None
    pallas: Optional[Callable] = None
    reference: Optional[Callable] = None
    normalize: Normalizer = normalize_config
    variants: Tuple[str, ...] = ()


# entry-point name -> spec (an op may expose several entry points, e.g.
# scan -> prefix_sum + linear_recurrence)
_KERNELS: Dict[str, KernelSpec] = {}
# op name -> spec used for config resolution (normalizer / space)
_BY_OP: Dict[str, KernelSpec] = {}


def tuned_kernel(op: str, *, space: Optional[SpaceBuilder] = None,
                 pallas: Optional[Callable] = None,
                 reference: Optional[Callable] = None,
                 normalize: Optional[Normalizer] = None,
                 variants: Tuple[str, ...] = ()) -> Callable:
    """Register the decorated function as the tuned entry point for ``op``."""

    def deco(fn: Callable) -> Callable:
        spec = KernelSpec(op=op, entry_name=fn.__name__, space=space,
                          pallas=pallas, reference=reference,
                          normalize=normalize or normalize_config,
                          variants=tuple(variants))
        # one function may serve several ops (fft drives both "fft" and
        # "large_fft"); qualify the key on collision instead of overwriting
        key = fn.__name__ if fn.__name__ not in _KERNELS else f"{op}:{fn.__name__}"
        _KERNELS[key] = spec
        _BY_OP.setdefault(op, spec)
        if normalize is not None:
            _BY_OP[op] = spec
        if space is not None:
            register_space(op, space)
        if not hasattr(fn, "kernel_spec"):
            fn.kernel_spec = spec    # primary registration wins
        return fn

    return deco


# specs register at kernels/*/ops.py import time; resolving an op before its
# module was imported would silently fall back to the generic normalizer, so
# look the module up lazily (ops sharing a module map onto it here)
_OP_MODULES = {
    "scan": "repro.kernels.scan.ops",
    "tridiag": "repro.kernels.tridiag.ops",
    "fft": "repro.kernels.fft.ops",
    "large_fft": "repro.kernels.fft.ops",
    "ssd": "repro.kernels.ssd.ops",
    "rglru": "repro.kernels.rglru.ops",
    "attention": "repro.kernels.attention.ops",
    "matmul": "repro.kernels.matmul.ops",
}


def _ensure_registered(op: str) -> None:
    if op in _BY_OP:
        return
    module = _OP_MODULES.get(op)
    if module is not None:
        try:
            importlib.import_module(module)
        except ImportError:
            pass


def get_kernel(name: str) -> KernelSpec:
    """Spec by entry-point name (or op name as a fallback)."""
    if name in _KERNELS:
        return _KERNELS[name]
    _ensure_registered(name)
    if name in _BY_OP:
        return _BY_OP[name]
    raise KeyError(f"no tuned kernel registered under {name!r}")


def normalizer_for(op: str) -> Normalizer:
    _ensure_registered(op)
    spec = _BY_OP.get(op)
    return spec.normalize if spec is not None else normalize_config


def registered_kernels() -> Dict[str, KernelSpec]:
    return dict(_KERNELS)


def known_ops() -> Tuple[str, ...]:
    """Every op name the registry can resolve (imported or lazily known).

    The ML training suite (``repro.tuning.ml.dataset.SUITE``) must cover
    exactly this set — a test enforces it, so registering a new
    ``@tuned_kernel`` op forces the author to declare its train/holdout
    sizes rather than silently shipping a kernel the predictor never
    learns.
    """
    return tuple(sorted(set(_OP_MODULES) | set(_BY_OP)))
