"""Online tuning: in-traffic measurement, safe trial/rollback, promotion.

The paper's closing guidance splits deployment into *offline* tuning (the
session/strategy stack built in PRs 1-3) and *online* tuning: refining the
config while real traffic flows, paying for measurements with production
steps instead of a dedicated sweep.  This module is the online half:

  * :class:`OnlineTuner` wraps a :class:`~repro.tuning.session.TunerSession`
    and starts from the session's prior (TuningDB hit, else the
    analytical/ML suggestion — zero evaluations, the paper's cold-start).
  * Candidate configs are trialed *in traffic*: while a trial is active the
    serving path runs the candidate, and every step's wall-clock latency
    feeds a per-config EWMA (outlier-clipped, so one GC pause cannot
    promote or kill a config).
  * A strict **measurement budget** bounds how many production steps are
    ever spent on non-incumbent configs, and a **guard band** bounds how
    bad a trial may look before it is rolled back: a trial whose EWMA
    exceeds ``incumbent * (1 + guard_band)`` is abandoned the moment it has
    enough samples to be believed.  The guard band generalizes to a
    **power envelope** (``power_envelope=``): a candidate whose
    *model-predicted* average draw (``energy_j / time_s`` from the cost
    model's metric vector — see :mod:`repro.core.policy`) exceeds the
    incumbent's modeled draw times the envelope is vetoed before it ever
    serves a production step.  Off by default; latency behavior is
    unchanged when disabled.
  * Winners are **promoted**: persisted to the TuningDB (``method="online"``
    — deliberately outside the ``dataset_from_db`` exhaustive allowlist,
    a traffic winner is not a guaranteed optimum) and journaled to the
    sweep-journal format, so completed spaces of production measurements
    feed the ML dataset exactly like offline sweeps (Schoonhoven et al.'s
    model-prior + few-live-measurements hybrid).

Trial lifecycle (exposed via :attr:`TrialRecord.state` and, in the final
:class:`~repro.core.bayesian.TuneResult`, via ``stopped_by`` — the same
truthful-semantics contract PR 3 established for the offline strategies)::

    trialing ──(EWMA < incumbent after samples_per_trial)──> incumbent
        └─────(EWMA > guard band, or loses the decision)──> rolled_back

``replay`` drives the same state machine deterministically from a recorded
:class:`ReplayTrace` (the ``tune.py online-replay`` subcommand), which is
how the convergence/rollback behavior is tested without a live engine.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)

from repro.core.analytical import AnalyticalTuner, score
from repro.core.bayesian import TuneResult
from repro.core.objective import Measurement, Objective, PENALTY_TIME
from repro.core.space import Config, SearchSpace, Workload, build_space
from repro.tuning.sweep import (SweepJournal, append_journal_lines,
                                config_key)

# A StepTimer is any zero-arg callable returning monotonic seconds —
# ``time.perf_counter`` in production, a fake clock in tests.  The serving
# engine takes one per instance so step timings are injectable end to end.
StepTimer = Callable[[], float]

TRACE_VERSION = 1

# trial / incumbent states (TrialRecord.state)
TRIALING = "trialing"
INCUMBENT = "incumbent"
ROLLED_BACK = "rolled_back"
SUPERSEDED = "superseded"     # an incumbent a promoted trial replaced


class EwmaTracker:
    """Outlier-clipped exponentially-weighted moving average of latencies.

    A sample more than ``clip``x the current EWMA is clipped to that bound
    before mixing: host jitter (GC, preemption) shifts the estimate by at
    most a bounded factor per step instead of swamping it.  ``alpha``
    defaults to 0.25 so a config's EWMA converges in a handful of steps
    but a single sample never dominates.
    """

    def __init__(self, alpha: float = 0.25, clip: float = 4.0,
                 hint: Optional[float] = None):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if clip <= 1.0:
            raise ValueError(f"clip must be > 1, got {clip}")
        self.alpha = alpha
        self.clip = clip
        # baseline for clipping the FIRST sample (a trial tracker gets the
        # incumbent's EWMA): without it a single startup spike would seed
        # the estimate unclipped and kill a genuinely good config
        self.hint = hint
        self.value: Optional[float] = None
        self.samples = 0
        self.clipped = 0

    def observe(self, dt: float) -> float:
        dt = float(dt)
        if self.value is None:
            if self.hint is not None and dt > self.clip * self.hint:
                # a first sample implausibly worse than the baseline is a
                # measurement artifact, not signal: discard it to the
                # baseline so it cannot seed (and doom) the estimate —
                # genuinely-slow configs re-assert themselves immediately
                dt = self.hint
                self.clipped += 1
            self.value = dt
        else:
            bound = self.clip * self.value
            if dt > bound:
                dt = bound
                self.clipped += 1
            self.value = (1.0 - self.alpha) * self.value + self.alpha * dt
        self.samples += 1
        return self.value


@dataclasses.dataclass
class TrialRecord:
    """One config's life in traffic: its EWMA, sample count, and fate."""

    config: Config
    tracker: EwmaTracker
    state: str = TRIALING
    baseline: Optional[float] = None   # incumbent EWMA when the trial ended

    @property
    def key(self) -> str:
        return config_key(self.config)

    @property
    def ewma(self) -> Optional[float]:
        return self.tracker.value

    @property
    def samples(self) -> int:
        return self.tracker.samples


class OnlineWallClockObjective(Objective):
    """Objective view of recorded in-traffic step timings.

    Answers from a mapping ``config_key -> [step seconds]`` (a
    :class:`ReplayTrace` or an OnlineTuner's measurement log) with the
    median recorded time; configs never measured in traffic get the
    penalty clamp, exactly like an invalid offline configuration.  This is
    the objective identity under which online measurements are journaled —
    its ``signature`` carries the traffic source so an online journal can
    never be resumed as (or by) a cost-model sweep.
    """

    def __init__(self, times: Mapping[str, Sequence[float]],
                 source: str = "trace"):
        self.times = {k: list(v) for k, v in times.items()}
        self.source = source

    def __call__(self, space: SearchSpace, cfg: Config) -> Measurement:
        if not space.is_valid(cfg):
            return Measurement(PENALTY_TIME, False)
        ts = self.times.get(config_key(cfg))
        if not ts:
            return Measurement(PENALTY_TIME, False)
        ordered = sorted(float(t) for t in ts)
        return Measurement(ordered[len(ordered) // 2], True,
                           meta={"samples": float(len(ordered))})

    def signature(self) -> str:
        return f"online_wallclock:{self.source}"


# ---------------------------------------------------------------------------
# Recorded traces (deterministic replay)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplayTrace:
    """Per-config step-latency sequences recorded from live traffic.

    JSONL on disk: a header line (workload + source), then one record per
    timed step ``{"k": <config_key>, "cfg": {...}, "t": seconds}`` in
    arrival order.  Loading tolerates a torn trailing line (a recorder
    killed mid-append), mirroring the sweep-journal contract.
    """

    workload: Workload
    source: str = "trace"
    times: Dict[str, List[float]] = dataclasses.field(default_factory=dict)
    configs: Dict[str, Config] = dataclasses.field(default_factory=dict)

    def add(self, cfg: Config, t: float) -> None:
        key = config_key(cfg)
        self.configs.setdefault(key, dict(cfg))
        self.times.setdefault(key, []).append(float(t))

    def steps(self) -> int:
        return sum(len(v) for v in self.times.values())

    def objective(self) -> OnlineWallClockObjective:
        return OnlineWallClockObjective(self.times, source=self.source)

    def save(self, path: str) -> str:
        directory = os.path.dirname(os.path.abspath(path))
        os.makedirs(directory, exist_ok=True)
        wl = self.workload
        with open(path, "w") as f:
            f.write(json.dumps(
                {"kind": "header", "version": TRACE_VERSION,
                 "source": self.source,
                 "workload": {"op": wl.op, "n": wl.n, "batch": wl.batch,
                              "dtype": wl.dtype, "variant": wl.variant}},
                sort_keys=True) + "\n")
            for key, ts in self.times.items():
                cfg = self.configs[key]
                for t in ts:
                    f.write(json.dumps({"k": key, "cfg": cfg, "t": t},
                                       sort_keys=True) + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "ReplayTrace":
        wl: Optional[Workload] = None
        source = "trace"
        trace: Optional[ReplayTrace] = None
        with open(path, "r") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue                      # torn trailing line
                if not isinstance(rec, dict):
                    continue                      # parseable but not a record
                if rec.get("kind") == "header":
                    if trace is not None:
                        # e.g. two recording sessions cat'ed together:
                        # silently resetting would replay half the data
                        raise ValueError(
                            f"trace {path!r} contains multiple headers — "
                            f"replay one recording session at a time")
                    w = rec.get("workload", {})
                    wl = Workload(op=w["op"], n=int(w["n"]),
                                  batch=int(w.get("batch", 1)),
                                  dtype=w.get("dtype", "float32"),
                                  variant=w.get("variant", ""))
                    source = rec.get("source", "trace")
                    trace = cls(wl, source=source)
                    continue
                if trace is None:
                    raise ValueError(f"trace {path!r} has no header line")
                if "cfg" in rec and "t" in rec:
                    trace.add({k: int(v) for k, v in rec["cfg"].items()},
                              float(rec["t"]))
        if trace is None:
            raise ValueError(f"trace {path!r} is empty")
        return trace


class TraceRecorder:
    """Appends (config, step latency) records to a trace file as they
    happen — crash-tolerant (every record is one line; a torn tail is
    skipped by :meth:`ReplayTrace.load`)."""

    def __init__(self, path: str, wl: Workload, source: str = "serve"):
        self.path = path
        directory = os.path.dirname(os.path.abspath(path))
        os.makedirs(directory, exist_ok=True)
        with open(path, "w") as f:
            f.write(json.dumps(
                {"kind": "header", "version": TRACE_VERSION, "source": source,
                 "workload": {"op": wl.op, "n": wl.n, "batch": wl.batch,
                              "dtype": wl.dtype, "variant": wl.variant}},
                sort_keys=True) + "\n")
        self.records = 0

    def add(self, cfg: Config, t: float) -> None:
        line = json.dumps({"k": config_key(cfg), "cfg": dict(cfg),
                           "t": float(t)}, sort_keys=True)
        # the sweep journal's O_APPEND helper: a single unbuffered write
        # per record, so a recorder killed mid-append leaves one torn line
        # (skipped by load) instead of a buffered multi-line tear, and
        # concurrent recorders never interleave mid-line
        append_journal_lines(self.path, [line])
        self.records += 1


# ---------------------------------------------------------------------------
# The online tuner
# ---------------------------------------------------------------------------

def ranked_candidates(space: SearchSpace, top_k: int,
                      exclude: Iterable[str] = ()) -> List[Config]:
    """Top-``top_k`` candidates by the zero-evaluation analytical rank.

    The expert model orders the trial queue for free, so the measurement
    budget is spent where the model expects wins first — the same
    "rank before you measure" lever as ``prune='analytical'`` offline.
    """
    skip = set(exclude)
    cands = [c for c in space.enumerate_valid() if config_key(c) not in skip]
    order = sorted(range(len(cands)),
                   key=lambda i: score(space, cands[i]).key(), reverse=True)
    return [cands[i] for i in order[:max(top_k, 0)]]


def replay_candidates(space: SearchSpace, trace: ReplayTrace,
                      prior: Config) -> List[Config]:
    """Every recorded config except the prior, expert-ranked, untruncated.

    Replay must be able to trial exactly what the traffic measured: a
    recorded config with a poor analytical rank (a DB-sourced production
    incumbent, say) still belongs in the queue — ranking orders the
    recorded set, it never filters it.  Configs no longer valid in the
    current space are dropped (they could not be applied anyway).
    """
    pk = config_key(prior)
    recorded = [cfg for key, cfg in trace.configs.items()
                if key != pk and space.is_valid(cfg)]
    return sorted(recorded, key=lambda c: score(space, c).key(),
                  reverse=True)


class OnlineTuner:
    """Trial/rollback state machine fed by in-traffic step timings.

    Feed it one wall-clock duration per serving step via :meth:`observe`;
    read the config the *next* step should run via :meth:`config` (raw
    knobs — the session normalizer fits them at resolve time).  The tuner
    never runs anything itself, so the same object serves a live engine
    (see :func:`attach`), a deterministic trace replay (:func:`replay`),
    and the ``strategy="online"`` simulation (:func:`online_search`).
    """

    def __init__(self, wl: Workload, session=None, *,
                 prior: Optional[Config] = None,
                 candidates: Optional[Sequence[Config]] = None,
                 budget: int = 64, guard_band: float = 0.25,
                 power_envelope: Optional[float] = None,
                 min_samples: int = 3, samples_per_trial: int = 8,
                 alpha: float = 0.25, clip: float = 4.0, top_k: int = 8,
                 cooldown: int = 1, journal_dir: Optional[str] = None,
                 source: str = "serve", store: bool = True):
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        if guard_band <= 0:
            raise ValueError(f"guard_band must be > 0, got {guard_band}")
        if power_envelope is not None and power_envelope <= 0:
            raise ValueError(
                f"power_envelope must be > 0, got {power_envelope}")
        if samples_per_trial < min_samples:
            raise ValueError("samples_per_trial must be >= min_samples "
                             f"({samples_per_trial} < {min_samples})")
        self.wl = wl.canonical()
        self.space = build_space(self.wl)
        if session is None and (prior is None or store):
            from repro.tuning.session import default_session
            session = default_session()
        self.session = session
        if prior is None:
            prior = session.resolve_raw(self.wl)
        self.guard_band = guard_band
        self.power_envelope = power_envelope
        self.power_vetoed: List[Config] = []
        self._watts_cache: Dict[str, float] = {}
        self._power_obj = None
        self.budget = budget
        self.min_samples = max(int(min_samples), 1)
        self.samples_per_trial = samples_per_trial
        self.cooldown = max(int(cooldown), 0)
        self.store = store and session is not None
        self._ewma_kwargs = {"alpha": alpha, "clip": clip}

        self.incumbent = TrialRecord(dict(prior), EwmaTracker(alpha, clip),
                                     state=INCUMBENT)
        if candidates is None:
            candidates = ranked_candidates(self.space, top_k,
                                           exclude=(self.incumbent.key,))
        seen = {self.incumbent.key}
        self._pending: List[Config] = []
        for cfg in candidates:
            key = config_key(cfg)
            if key not in seen:
                seen.add(key)
                self._pending.append(dict(cfg))
        self.trial: Optional[TrialRecord] = None
        self.trials: List[TrialRecord] = []      # finished trials, in order
        self.measured = 0                        # trial samples spent (budget)
        self.steps = 0                           # every observed step
        self.promotions = 0
        self.finished = False
        self.stopped_by = "running"
        self._since_trial = self.cooldown        # allow an immediate first trial

        self._journal: Optional[SweepJournal] = None
        self._journal_identity = OnlineWallClockObjective({}, source=source)
        if journal_dir is not None:
            self._journal = SweepJournal.for_workload(
                journal_dir, self.wl, self._journal_identity)

    # -- what should the next step run? -------------------------------------

    def config(self) -> Config:
        """Raw config the next serving step should run (trial or incumbent)."""
        rec = self.trial if self.trial is not None else self.incumbent
        return dict(rec.config)

    def state(self) -> str:
        """Current activity: ``trialing`` while a candidate is shadowed,
        else ``incumbent`` (serving the best known config)."""
        return TRIALING if self.trial is not None else INCUMBENT

    def overrides_fragment(self) -> Dict[str, Dict[str, int]]:
        """Per-op override dict applying :meth:`config` to the serve path."""
        return {self.wl.op: self.config()}

    # -- feed measurements ---------------------------------------------------

    def observe(self, dt: float) -> None:
        """Record one step's wall-clock duration for the active config."""
        self.steps += 1
        if self.trial is None:
            self.incumbent.tracker.observe(dt)
            self._since_trial += 1
            if self.incumbent.tracker.samples == self.min_samples:
                # baseline established: the prior's production latency is a
                # measurement worth keeping too
                self._journal_entry(self.incumbent)
            if not self.finished:
                self._maybe_start_trial()
            return

        self.trial.tracker.observe(dt)
        self.measured += 1
        inc = self.incumbent.tracker.value
        trial = self.trial
        decided = False
        if trial.samples >= self.min_samples:
            if inc is not None and trial.tracker.value > inc * (1.0 + self.guard_band):
                # guard band: visibly worse than the incumbent — stop
                # burning production steps on it immediately
                self._finish_trial(ROLLED_BACK)
                decided = True
            elif trial.samples >= self.samples_per_trial \
                    or self.measured >= self.budget:
                win = inc is None or trial.tracker.value < inc
                self._finish_trial(INCUMBENT if win else ROLLED_BACK)
                decided = True
        elif self.measured >= self.budget:
            # budget died mid-trial before min_samples: not enough evidence
            # to promote — roll back
            self._finish_trial(ROLLED_BACK)
            decided = True
        if decided and not self.finished:
            self._maybe_start_trial()

    # -- internals -----------------------------------------------------------

    def _maybe_start_trial(self) -> None:
        if self.trial is not None or self.finished:
            return
        if self.incumbent.samples < self.min_samples:
            return                     # no believable baseline yet
        if self._since_trial < self.cooldown:
            return                     # let the incumbent breathe between trials
        if self.measured >= self.budget:
            self._stop("budget")
            return
        if not self._pending:
            self._stop("exhausted")
            return
        cfg = self._pending.pop(0)
        if self.power_envelope is not None:
            # the trial queue never spends a production step on a config the
            # model says would blow the incumbent's power budget
            cap = self._modeled_watts(self.incumbent.config) \
                * self.power_envelope
            while self._modeled_watts(cfg) > cap:
                self.power_vetoed.append(cfg)
                if not self._pending:
                    self._stop("exhausted")
                    return
                cfg = self._pending.pop(0)
        self.trial = TrialRecord(cfg, EwmaTracker(
            hint=self.incumbent.tracker.value, **self._ewma_kwargs))

    def _finish_trial(self, state: str) -> None:
        trial = self.trial
        assert trial is not None
        self.trial = None
        self._since_trial = 0
        trial.state = state
        trial.baseline = self.incumbent.tracker.value
        self.trials.append(trial)
        self._journal_entry(trial)
        if state == INCUMBENT:
            old = self.incumbent
            old.state = SUPERSEDED
            old.baseline = trial.tracker.value
            if old not in self.trials and old.samples:
                # the original prior was never a trial; record its
                # measured life so result().history reports every config
                # that informed a decision
                self.trials.append(old)
            self.incumbent = trial
            self.promotions += 1
            self._persist_winner()
        if self.measured >= self.budget:
            self._stop("budget")
        elif not self._pending:
            self._stop("exhausted")

    def _modeled_watts(self, cfg: Config) -> float:
        """Model-predicted average draw (W) for ``cfg`` on the active device:
        ``energy_j / time_s`` from the cost model's metric vector.  Zero
        production cost — the power veto never spends a traffic step.  A
        config the model cannot time answers with +inf (always vetoed)."""
        key = config_key(cfg)
        if key not in self._watts_cache:
            if self._power_obj is None:
                from repro.core.objective import CostModelObjective
                profile = getattr(self.session, "spec", None)
                self._power_obj = CostModelObjective(profile)
            m = self._power_obj(self.space, cfg)
            watts = m.energy_j / m.time_s if m.valid and m.time_s > 0 \
                else float("inf")
            self._watts_cache[key] = watts
        return self._watts_cache[key]

    def _stop(self, reason: str) -> None:
        if not self.finished:
            self.finished = True
            self.stopped_by = reason

    def _persist_winner(self) -> None:
        if not self.store or self.session is None:
            return
        inc = self.incumbent
        self.session.db.store(self.wl, inc.config, float(inc.tracker.value),
                              "online", self.measured)
        self.session.invalidate(self.wl)

    def _journal_entry(self, rec: TrialRecord) -> None:
        if self._journal is None or rec.ewma is None or rec.samples == 0:
            return
        # space_size is the FULL valid space; "pruned" marks the journal as
        # a model-steered subset, so dataset export ignores it until every
        # config in the space has a production measurement (PR 3 contract).
        # The count is configs never queued: incumbent + trial queue cover
        # the rest. Only the FIRST append's value lands (the journal
        # header is write-once), and the queue never grows, so the
        # baseline-time value is the right one.
        full = len(self.space.enumerate_valid())
        self._journal.append(self.wl, self._journal_identity, full,
                             [(rec.config, float(rec.ewma))],
                             pruned=max(full - 1 - len(self._pending), 0))

    # -- results -------------------------------------------------------------

    def result(self) -> TuneResult:
        """Session-compatible result; ``stopped_by`` follows PR 3 semantics:
        ``budget`` (measurement budget was binding), ``exhausted`` (trial
        queue ran dry first), or ``running`` (mid-flight snapshot)."""
        history: List[Tuple[Config, float]] = []
        for rec in self.trials:
            if rec.ewma is not None:
                history.append((dict(rec.config), float(rec.ewma)))
        inc = self.incumbent
        best_time = float(inc.tracker.value) if inc.tracker.value is not None \
            else float("inf")
        if all(config_key(c) != inc.key for c, _ in history) \
                and inc.tracker.value is not None:
            history.append((dict(inc.config), best_time))
        return TuneResult(dict(inc.config), best_time, self.measured,
                          history, self.stopped_by)

    def summary(self) -> Dict[str, object]:
        return {
            "workload": self.wl.key,
            "incumbent": dict(self.incumbent.config),
            "incumbent_ewma_s": self.incumbent.tracker.value,
            "state": self.state(),
            "stopped_by": self.stopped_by,
            "steps": self.steps,
            "measured": self.measured,
            "budget": self.budget,
            "promotions": self.promotions,
            "power_envelope": self.power_envelope,
            "power_vetoed": len(self.power_vetoed),
            "trials": [{"config": dict(t.config), "state": t.state,
                        "samples": t.samples, "ewma_s": t.ewma}
                       for t in self.trials],
        }


# ---------------------------------------------------------------------------
# Drivers: live engine, deterministic replay, strategy simulation
# ---------------------------------------------------------------------------

def attach(engine, tuner: OnlineTuner,
           recorder: Optional[TraceRecorder] = None) -> None:
    """Wire an OnlineTuner into a serving engine's step hooks.

    The engine applies ``tuner.overrides_fragment()`` around every decode
    step (so the active trial's knobs reach the kernels through the normal
    override stack) and reports each step's wall-clock duration; the
    listener attributes the sample to the config that was live *during*
    the step — reading it before ``observe`` possibly switches trials.
    """
    engine.set_override_provider(tuner.overrides_fragment)

    def _on_step(record) -> None:
        cfg = tuner.config()
        tuner.observe(record.duration_s)
        if recorder is not None:
            recorder.add(cfg, record.duration_s)

    engine.add_step_listener(_on_step)


def replay(tuner: OnlineTuner, trace: ReplayTrace,
           max_steps: int = 100_000) -> TuneResult:
    """Drive the tuner's state machine from a recorded trace.

    Each simulated step feeds the next recorded latency of whichever
    config the tuner wants live (cycling per-config when a sequence runs
    out — steady-state traffic); a config the trace never saw answers with
    the penalty clamp, so the guard band rolls it back, exactly as an
    unmeasurable config should die in production.  Fully deterministic:
    same trace + same tuner parameters -> same promotions, same winner.
    """
    cursors: Dict[str, int] = {}
    steps = 0
    while not tuner.finished and steps < max_steps:
        key = config_key(tuner.config())
        ts = trace.times.get(key)
        if ts:
            i = cursors.get(key, 0)
            t = ts[i % len(ts)]
            cursors[key] = i + 1
        else:
            t = PENALTY_TIME
        tuner.observe(t)
        steps += 1
    return tuner.result()


def online_search(space: SearchSpace, objective: Objective, *, seed: int = 0,
                  budget: int = 16, guard_band: float = 0.25,
                  min_samples: int = 2, samples_per_trial: int = 3,
                  top_k: Optional[int] = None,
                  prior: Optional[Config] = None,
                  policy=None,
                  power_envelope: Optional[float] = None) -> TuneResult:
    """``strategy="online"`` — simulate in-traffic tuning on an objective.

    Every simulated step "measures" the active config by evaluating the
    objective (deterministic objectives make the EWMA collapse to the
    measured time, so the comparison report scores online tuning on the
    same numbers as everyone else).  The prior is the analytical
    suggestion — the paper's zero-evaluation cold start.

    ``policy`` scalarizes the objective's metric vector before the EWMA
    sees it (so e.g. ``policy="energy"`` makes trials compete on modeled
    joules); the session passes an already-wrapped
    :class:`~repro.core.policy.PolicyObjective`, so this parameter is for
    direct callers.  ``power_envelope`` forwards to :class:`OnlineTuner`.
    """
    del seed    # the trial queue is analytically ranked: deterministic
    wl = space.workload
    if policy is not None:
        from repro.core.policy import PolicyObjective, get_policy
        pol = get_policy(policy)
        if pol.name != "latency" and not isinstance(objective,
                                                    PolicyObjective):
            objective = PolicyObjective(objective, pol)
    if prior is None:
        prior = AnalyticalTuner().suggest(space)
    if top_k is None:
        # one queue slot per full trial the budget can afford
        top_k = max(budget // samples_per_trial, 1)
    tuner = OnlineTuner(wl, session=None, prior=prior, store=False,
                        budget=budget, guard_band=guard_band,
                        power_envelope=power_envelope,
                        min_samples=min_samples,
                        samples_per_trial=samples_per_trial, top_k=top_k,
                        cooldown=0)
    # cap far above budget: warmup + cooldown steps are incumbent-only
    cap = 4 * budget + 8 * tuner.min_samples + 64
    steps = 0
    while not tuner.finished and steps < cap:
        cfg = tuner.config()
        m = objective(space, cfg)
        tuner.observe(m.time_s if m.valid else PENALTY_TIME)
        steps += 1
    if not tuner.finished:
        tuner._stop("budget")
    return tuner.result()


# ---------------------------------------------------------------------------
# Fleet priors: aggregate replica journals into one warm start
# ---------------------------------------------------------------------------

def aggregate_fleet(journal_dirs: Sequence[str], wl: Workload, *,
                    source: str = "serve", min_replicas: int = 1,
                    ) -> Dict[str, Tuple[Config, float, int]]:
    """Merge per-replica online journals into fleet-wide config estimates.

    Each serving replica streams its in-traffic EWMAs to its own journal
    directory (``OnlineTuner(journal_dir=...)``); a fleet is just a list
    of those directories on shared storage.  This reads every replica's
    journal for ``wl`` under the online objective identity and merges
    per config: the fleet estimate is the mean of the replicas' final
    EWMAs (journal entries are last-wins per config, so each replica
    contributes at most one number per config).  Configs measured by
    fewer than ``min_replicas`` replicas are dropped — one replica's
    fluke cannot steer the fleet.

    Returns ``{config_key: (config, mean_seconds, replicas)}``.
    """
    wl = wl.canonical()
    identity = OnlineWallClockObjective({}, source=source)
    merged: Dict[str, Tuple[Config, List[float]]] = {}
    for d in journal_dirs:
        journal = SweepJournal.for_workload(d, wl, identity)
        for cfg, t in journal.entries():
            _, ts = merged.setdefault(config_key(cfg), (dict(cfg), []))
            ts.append(float(t))
    return {key: (cfg, sum(ts) / len(ts), len(ts))
            for key, (cfg, ts) in merged.items()
            if len(ts) >= max(min_replicas, 1)}


def fleet_prior(journal_dirs: Sequence[str], wl: Workload, *,
                source: str = "serve", min_replicas: int = 1,
                ) -> Tuple[Optional[Config], List[Config]]:
    """Fleet-aggregated warm start: ``(winner, runner-up candidates)``.

    The winner is the config with the best fleet-mean latency; the other
    measured configs follow ordered by their fleet means, so a fresh
    replica trials the fleet's runner-ups first instead of re-deriving
    the queue analytically.  ``(None, [])`` when no journal has data.
    """
    agg = aggregate_fleet(journal_dirs, wl, source=source,
                          min_replicas=min_replicas)
    if not agg:
        return None, []
    ranked = sorted(agg.values(), key=lambda item: item[1])
    return dict(ranked[0][0]), [dict(cfg) for cfg, _, _ in ranked[1:]]


def promote_fleet_winner(session, wl: Workload, journal_dirs: Sequence[str],
                         *, source: str = "serve", min_replicas: int = 1,
                         ) -> Optional[Tuple[Config, float, int]]:
    """Store the fleet's best config in the TuningDB (``method="fleet"``).

    The stored record seeds ``session.resolve_raw`` for every future
    engine on this device even with no fleet journal in reach.  Like
    ``method="online"``, ``"fleet"`` stays outside the exhaustive dataset
    allowlist — a traffic consensus is not a sweep optimum.  Returns the
    ``(config, mean_seconds, replicas)`` stored, or ``None`` when no
    journal has enough data to promote.
    """
    wl = wl.canonical()
    agg = aggregate_fleet(journal_dirs, wl, source=source,
                          min_replicas=min_replicas)
    if not agg:
        return None
    cfg, t, replicas = min(agg.values(), key=lambda item: item[1])
    session.db.store(wl, cfg, float(t), "fleet", replicas)
    session.invalidate(wl)
    return dict(cfg), float(t), int(replicas)


def warm_tuner(wl: Workload, journal_dirs: Sequence[str], session=None, *,
               source: str = "serve", min_replicas: int = 1,
               **tuner_kwargs) -> OnlineTuner:
    """An :class:`OnlineTuner` warm-started from fleet journals.

    The fleet winner becomes the prior — the new replica serves the
    consensus config from its very first step — and the fleet's
    runner-ups, ordered by their measured means, become the trial queue.
    With no usable fleet data this falls back to the normal cold start
    (session prior + analytically-ranked queue), so callers can pass the
    fleet directories unconditionally.
    """
    prior, candidates = fleet_prior(journal_dirs, wl, source=source,
                                    min_replicas=min_replicas)
    if prior is None:
        return OnlineTuner(wl, session, source=source, **tuner_kwargs)
    return OnlineTuner(wl, session, prior=prior, candidates=candidates,
                       source=source, **tuner_kwargs)


def measurements_to_incumbent(tuner: OnlineTuner) -> int:
    """Trial samples spent before the tuner's final incumbent went live.

    The fleet-prior gate metric: a replica warm-started on the fleet
    winner pays zero (or few) trial samples before serving it; a cold
    replica pays for every trial through the winning promotion.
    Superseded incumbents' samples are incumbent-time serving, not trial
    spend, and are excluded.
    """
    spent = 0
    answer = 0
    for rec in tuner.trials:
        if rec.state == SUPERSEDED:
            continue
        spent += rec.samples
        if rec.state == INCUMBENT:
            answer = spent
    return answer
