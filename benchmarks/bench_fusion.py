"""Chain-fusion gate: the fused chain must actually be cheaper.

For each production chain (docs/kernels.md — *Chain fusion*) this bench
runs the public op fused (``fuse=1``) and unfused (``fuse=0``) on the
same inputs and emits, per arm:

* the **planned HBM pass count** (``plan_for_chain(...).plan.passes`` —
  the same chain-aware quantity journals and the analytical ``pass_rank``
  consume) plus the executed Pallas launch count from
  ``driver.capture_launches`` (conformance: must equal the chain's);
* the **measured wall clock** (median of repeated blocked calls).

Gates:

* both chains: fused planned passes < unfused planned passes, and the
  executed launch list equals the chain plan's;
* rglru: fused wall clock strictly beats unfused — the saved XLA gate
  pass is real measured time, not just model accounting.  (ssd's wall
  clock is emitted ungated: in CPU interpret mode the intra kernel
  dominates both arms, so the 3 -> 2 launch win is asserted on the pass
  rows where it is deterministic.)

Standalone (the CI bench-smoke invocation):

  PYTHONPATH=src:. python benchmarks/bench_fusion.py \
      --smoke --seed 0 --json BENCH_fusion.json

exits non-zero when a gate fails; ``run.py --only fusion`` emits the
same rows as a section.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Optional


def _median_s(fn, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())          # warm (compile + caches)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def run(emit, seed: int = 0, smoke: bool = False) -> List[str]:
    """Emit fused-vs-unfused rows per chain; returns gate failures."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.space import Workload
    from repro.kernels.blocks import driver
    from repro.kernels.blocks.plan import plan_for_chain
    from repro.kernels.rglru.ops import rglru
    from repro.kernels.ssd.ops import ssd

    rng = np.random.default_rng(seed)
    reps = 5 if smoke else 9
    failures: List[str] = []

    def measure(op, wl, cfg, fn, dims=None):
        chain = plan_for_chain(wl, cfg, dims=dims)
        with driver.capture_launches() as rec:
            fn()
        conforms = tuple(rec) == tuple(chain.launches)
        t = _median_s(fn, reps)
        fuse = cfg["fuse"]
        emit(f"fusion,{op},{wl.n},{wl.batch},passes_fuse{fuse},count,"
             f"{chain.plan.passes},launches={len(rec)}")
        emit(f"fusion,{op},{wl.n},{wl.batch},time_fuse{fuse},seconds,"
             f"{t:.5f},median_of_{reps}")
        if not conforms:
            failures.append(
                f"{op} fuse={fuse}: executed launch list diverged from "
                f"the chain plan ({len(rec)} executed vs "
                f"{len(chain.launches)} planned)")
        return chain.plan.passes, t

    # --- ssd: intra -> linrec -> apply ---------------------------------
    B, L, H, P, S = (2, 512, 2, 16, 8) if smoke else (4, 1024, 2, 16, 8)
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.85, 0.999, (B, L, H)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, L, S)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((B, L, S)) * 0.3, jnp.float32)
    wl = Workload(op="ssd", n=L, batch=B * H, variant="chunked")
    res = {}
    for fuse in (0, 1):
        cfg = {"tile_n": 128, "radix": 2, "fuse": fuse}
        res[fuse] = measure(
            "ssd", wl, cfg,
            lambda cfg=cfg: ssd(x, a, b, c, config=cfg, interpret=True,
                                use_pallas=True),
            dims=(S, P))
    if not res[1][0] < res[0][0]:
        failures.append(
            f"ssd fused chain does not save an HBM pass "
            f"({res[1][0]} vs {res[0][0]})")

    # --- rglru: gate -> linrec -----------------------------------------
    B2, L2, D = (2, 512, 16) if smoke else (4, 1024, 32)
    a2 = jnp.asarray(rng.uniform(0.8, 0.99, (B2, L2, D)), jnp.float32)
    u2 = jnp.asarray(rng.standard_normal((B2, L2, D)), jnp.float32)
    wl2 = Workload(op="rglru", n=L2, batch=B2 * D)
    res2 = {}
    for fuse in (0, 1):
        cfg = {"tile_n": 256, "rows_per_program": 8, "radix": 2,
               "fuse": fuse}
        res2[fuse] = measure(
            "rglru", wl2, cfg,
            lambda cfg=cfg: rglru(a2, u2, config=cfg, interpret=True,
                                  use_pallas=True))
    if not res2[1][0] < res2[0][0]:
        failures.append(
            f"rglru fused chain does not save an HBM pass "
            f"({res2[1][0]} vs {res2[0][0]})")
    if not res2[1][1] < res2[0][1]:
        failures.append(
            f"rglru fused chain is not faster on wall clock "
            f"({res2[1][1]:.5f}s fused vs {res2[0][1]:.5f}s unfused) — "
            f"the folded gate should drop a whole elementwise pass")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Fused-vs-unfused chain gate (pass count + wall clock)")
    ap.add_argument("--json", default=None,
                    help="write rows + gate verdict here "
                         "(e.g. BENCH_fusion.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller shapes / fewer timing reps")
    args = ap.parse_args(argv)

    rows: List[str] = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    failures = run(emit, seed=args.seed, smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "fusion", "seed": args.seed,
                       "smoke": bool(args.smoke), "rows": rows,
                       "failures": failures}, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    for failure in failures:
        print(f"[bench-fusion] FAIL: {failure}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
