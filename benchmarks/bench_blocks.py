"""Micro-benchmark: StagePlan construction + plan-aware resolve/dispatch.

The building-block refactor inserted a planner between config resolution
and kernel launch; this bench gates the two acceptance criteria:

  (a) warm plan construction (the memoized ``plan_for`` hit every kernel
      call pays) stays under 50 us;
  (b) the refactored resolve+plan hot path is no slower than the
      pre-refactor bench_resolve bar: still >= 10x faster than the
      seed-style miss path (re-running the analytical model per call).

Emits CSV rows (name,metric,value); ``--json`` writes BENCH_BLOCKS.json
for the CI bench-smoke artifact trail.

    PYTHONPATH=src python benchmarks/bench_blocks.py --json BENCH_BLOCKS.json
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

from repro.core import Workload, build_space
from repro.core.analytical import AnalyticalTuner
from repro.core.space import normalize_config
from repro.kernels.blocks.plan import build_plan, plan_for
from repro.tuning import TunerSession

WORKLOADS = [
    Workload(op="scan", n=512, batch=2**17, variant="lf"),
    Workload(op="scan", n=4096, batch=2**14, variant="linrec"),
    Workload(op="tridiag", n=256, batch=2**14, variant="wm"),
    Workload(op="fft", n=1024, batch=2**12, variant="stockham"),
    Workload(op="large_fft", n=2**20, batch=16, variant="stockham"),
]

PLAN_WARM_BUDGET_US = 50.0


def timeit(fn, reps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(emit) -> dict:
    session = TunerSession(db_path=tempfile.mktemp(suffix="_bench_db.json"))
    worst_speedup = float("inf")
    worst_plan_us = 0.0
    for wl in WORKLOADS:
        tuner = AnalyticalTuner()

        def miss_path(wl=wl, tuner=tuner):
            cfg = tuner.suggest(build_space(wl))
            return normalize_config(cfg, wl)

        cfg = session.resolve(wl)                # prime LRU + plan cache
        plan_for(wl, cfg)

        def hot_path(wl=wl):
            c = session.resolve(wl)
            return plan_for(wl, c)

        t_cold_plan = timeit(lambda wl=wl, cfg=cfg: build_plan(wl, cfg), 20)
        t_warm_plan = timeit(lambda wl=wl, cfg=cfg: plan_for(wl, cfg), 2000)
        t_miss = timeit(miss_path, 5)
        t_hot = timeit(hot_path, 500)
        speedup = t_miss / max(t_hot, 1e-12)
        worst_speedup = min(worst_speedup, speedup)
        worst_plan_us = max(worst_plan_us, t_warm_plan * 1e6)
        tag = f"{wl.op}:{wl.variant}"
        emit(f"blocks,{tag},plan_cold_us,{t_cold_plan*1e6:.1f}")
        emit(f"blocks,{tag},plan_warm_us,{t_warm_plan*1e6:.3f}")
        emit(f"blocks,{tag},resolve_plan_us,{t_hot*1e6:.2f}")
        emit(f"blocks,{tag},miss_us,{t_miss*1e6:.1f}")
        emit(f"blocks,{tag},speedup_vs_miss,{speedup:.0f}")
    return {"worst_speedup": worst_speedup, "worst_plan_warm_us": worst_plan_us}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_BLOCKS.json summary")
    ap.add_argument("--seed", type=int, default=0,
                    help="accepted for CLI uniformity; deterministic bench")
    ap.add_argument("--no-assert", action="store_true",
                    help="record without gating (noisy shared CI runners)")
    args = ap.parse_args()
    rows = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    summary = run(emit)
    if not args.no_assert:
        assert summary["worst_plan_warm_us"] < PLAN_WARM_BUDGET_US, \
            f"warm plan construction {summary['worst_plan_warm_us']:.1f}us " \
            f">= {PLAN_WARM_BUDGET_US}us"
        assert summary["worst_speedup"] >= 10, \
            f"resolve+plan only {summary['worst_speedup']:.1f}x faster " \
            f"than the miss path (pre-refactor bar: 10x)"
        print(f"# acceptance ok: plan warm {summary['worst_plan_warm_us']:.2f}us, "
              f"resolve+plan {summary['worst_speedup']:.0f}x over miss path")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "blocks", "seed": args.seed, "rows": rows,
                       "summary": summary}, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
