"""Checkpoint manager: roundtrip, corruption, pruning, auto-resume."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _state(v=1.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "step": jnp.asarray(7)}


def test_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False)
    st = _state(3.0)
    m.save(10, st)
    like = {"params": {"w": np.zeros((4, 4)), "b": np.zeros((4,))},
            "step": np.asarray(0)}
    out = m.restore(10, like)
    np.testing.assert_allclose(out["params"]["w"], 3.0)
    assert int(out["step"]) == 7


def test_async_write_then_wait(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=True)
    m.save(1, _state())
    m.wait()
    assert m.latest_step() == 1


def test_corruption_detected_and_skipped(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False, keep_last=10)
    m.save(1, _state(1.0))
    m.save(2, _state(2.0))
    # corrupt step 2's first leaf
    d = os.path.join(str(tmp_path), "step_0000000002")
    leaf = os.path.join(d, "leaf_00000.npy")
    arr = np.load(leaf)
    np.save(leaf, arr + 99)
    like = {"params": {"w": np.zeros((4, 4)), "b": np.zeros((4,))},
            "step": np.asarray(0)}
    with pytest.raises(IOError):
        m.restore(2, like)
    # auto-resume falls back to the newest INTACT checkpoint
    step, out = m.restore_latest(like)
    assert step == 1
    np.testing.assert_allclose(out["params"]["w"], 1.0)


def test_partial_write_never_visible(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False)
    os.makedirs(os.path.join(str(tmp_path), "step_0000000099.tmp"))
    assert m.latest_step() is None      # tmp dirs are not checkpoints


def test_keep_last_prunes(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False, keep_last=2)
    for s in [1, 2, 3, 4]:
        m.save(s, _state(float(s)))
    assert m.all_steps() == [3, 4]


def test_shape_mismatch_raises(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False)
    m.save(1, _state())
    like = {"params": {"w": np.zeros((8, 8)), "b": np.zeros((4,))},
            "step": np.asarray(0)}
    with pytest.raises(ValueError):
        m.restore(1, like)
