"""Quickstart: tune a kernel offline, use it online — the paper's flow.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TuningDB, Workload, get_config, tune_offline)
from repro.kernels.scan.ops import prefix_sum
from repro.kernels.scan.ref import scan_add_ref

db = TuningDB(path="/tmp/quickstart_db.json")

# 1. offline: Bayesian-optimization search on the TPU device model
wl = Workload(op="scan", n=1024, batch=65536, variant="ks")
result = tune_offline(wl, method="bayesian", db=db)
print(f"offline BO: best={result.best_config} "
      f"t={result.best_time*1e6:.1f}us evals={result.evaluations}")

# 2. online: the kernel launcher reads the DB (or falls back to the
#    zero-evaluation analytical model for unseen workloads)
cfg = get_config(wl, db=db)
print(f"online config: {cfg}")

# 3. run the tuned kernel (interpret mode validates the Pallas body on CPU)
x = jnp.asarray(np.random.default_rng(0).normal(size=(16, 1024)), jnp.float32)
y = prefix_sum(x, config=cfg, interpret=True)
err = float(jnp.max(jnp.abs(y - scan_add_ref(x))))
print(f"tuned scan matches oracle: max_err={err:.2e}")

# 4. an unseen workload: analytical answer, no evaluations needed
wl2 = Workload(op="scan", n=2048, batch=32768, variant="ks")
print(f"online (analytical, cold): {get_config(wl2, db=db)}")
