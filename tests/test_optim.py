"""Optimizers, schedules, clipping, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adamw, clip_by_global_norm,
                         global_norm, warmup_cosine)
from repro.optim.compression import (compress, decompress, ef_roundtrip,
                                     psum_compressed)


def _quadratic_descends(make_opt):
    init, update = make_opt
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]), "b": jnp.asarray(4.0)}
    state = init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = update(grads, state, params)
    return l0, float(loss(params))


def test_adamw_descends():
    l0, l1 = _quadratic_descends(adamw(0.1, weight_decay=0.0))
    assert l1 < l0 * 0.05


def test_adafactor_descends():
    l0, l1 = _quadratic_descends(adafactor(0.3))
    assert l1 < l0 * 0.3


def test_adafactor_state_is_factored():
    init, _ = adafactor(0.1)
    params = {"w": jnp.zeros((64, 32))}
    st = init(params)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (32,)


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, rel=0.1)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, rel=0.05)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_compression_roundtrip_bounded():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)
    q, s, err = compress(g, jnp.zeros_like(g))
    deq = decompress(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) + 1e-9


def test_error_feedback_accumulates():
    """With EF, the bias of repeated quantization vanishes in aggregate."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    err = jnp.zeros_like(g_true)
    total_applied = jnp.zeros_like(g_true)
    for _ in range(50):
        (applied,), (err,) = (lambda t: (list(t[0].values()),
                                         list(t[1].values())))(
            ef_roundtrip({"g": g_true}, {"g": err}))
        total_applied += applied
    # mean applied gradient ~ true gradient
    np.testing.assert_allclose(total_applied / 50, g_true, atol=1e-3)


def test_psum_compressed_single_device():
    mesh = jax.make_mesh((1,), ("pod",))
    try:                                     # newer jax exports it top-level
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    x = jnp.asarray([1.0, -2.0, 3.0])
    f = shard_map(lambda v: psum_compressed(v, "pod"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    np.testing.assert_allclose(f(x), x, atol=0.05)
