"""Analytic parameter counting for MODEL_FLOPS (no tensor allocation)."""
from __future__ import annotations

from repro.configs.base import ModelConfig


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    return (d * cfg.n_heads * hd          # wq
            + 2 * d * cfg.n_kv_heads * hd  # wk, wv
            + cfg.n_heads * hd * d)        # wo


def _mlp_params(d: int, f: int) -> int:
    return 3 * d * f                       # gate, up, down


def active_param_count(cfg: ModelConfig) -> int:
    """Matmul-active parameters per token (MoE: only routed-in experts)."""
    d = cfg.d_model
    per_layer = 0
    if cfg.family in ("dense", "audio", "vlm"):
        per_layer = _attn_params(cfg) + _mlp_params(d, cfg.d_ff)
        n_layers = cfg.n_layers
        if cfg.family == "audio":
            n_layers = (cfg.n_enc_layers or cfg.n_layers) + \
                (cfg.n_dec_layers or cfg.n_layers)
            # decoder cross-attention
            per_layer += _attn_params(cfg) * (cfg.n_dec_layers or
                                              cfg.n_layers) // max(n_layers, 1)
        total = per_layer * n_layers
        if cfg.family == "vlm" and cfg.cross_attn_every:
            total += _attn_params(cfg) * (cfg.n_layers // cfg.cross_attn_every)
        return total
    if cfg.family == "moe":
        per_layer = _attn_params(cfg)
        per_layer += cfg.moe_top_k * _mlp_params(d, cfg.d_ff_expert)
        per_layer += cfg.n_shared_experts * _mlp_params(d, cfg.d_ff_expert)
        per_layer += d * cfg.n_experts     # router
        return per_layer * cfg.n_layers
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * d
        n_heads = d_inner // cfg.ssm_head_dim
        per_layer = d * (2 * d_inner + 2 * cfg.ssm_state + n_heads)  # in_proj
        per_layer += d_inner * d           # out_proj
        if cfg.d_ff:
            per_layer += _mlp_params(d, cfg.d_ff)
        return per_layer * cfg.n_layers
    if cfg.family == "hybrid":
        w = cfg.lru_width or d
        rec_layer = 2 * d * w + 2 * w * w + w * d + _mlp_params(d, cfg.d_ff)
        attn_layer = _attn_params(cfg) + _mlp_params(d, cfg.d_ff)
        period = cfg.block_pattern
        n_rec = sum(1 for k in period if k == "rec")
        n_att = len(period) - n_rec
        groups = cfg.n_layers // max(len(period), 1)
        return groups * (n_rec * rec_layer + n_att * attn_layer)
    raise ValueError(cfg.family)


def audio_split_params(cfg: ModelConfig):
    """(encoder_params, decoder_params) for enc-dec MODEL_FLOPS."""
    d = cfg.d_model
    enc_layer = _attn_params(cfg) + _mlp_params(d, cfg.d_ff)
    dec_layer = 2 * _attn_params(cfg) + _mlp_params(d, cfg.d_ff)  # + cross
    n_enc = cfg.n_enc_layers or cfg.n_layers
    n_dec = cfg.n_dec_layers or cfg.n_layers
    return enc_layer * n_enc, dec_layer * n_dec


def total_param_count(cfg: ModelConfig) -> int:
    """All parameters incl. embeddings and full expert banks."""
    d = cfg.d_model
    total = cfg.vocab * d                  # tied embedding
    if cfg.family == "moe":
        per_layer = _attn_params(cfg)
        per_layer += cfg.n_experts * _mlp_params(d, cfg.d_ff_expert)
        per_layer += cfg.n_shared_experts * _mlp_params(d, cfg.d_ff_expert)
        per_layer += d * cfg.n_experts
        return total + per_layer * cfg.n_layers
    return total + active_param_count(cfg)
