"""Benchmark harness — one section per paper table/figure.

Prints ``name,...`` CSV rows:
  fig5/fig6/fig7/fig8 — tridiag / scan / FFT / large-FFT throughput per
      tuning methodology (+ `-host` rows: genuine wall-clock on this host);
  table2              — average performance + Phi per (op, methodology);
  fig4 / fig4d        — BO candidate-evaluation counts (+ control vs random);
  roofline            — per (arch x shape) three-term roofline summary;
  resolve             — TunerSession online hot-path vs seed miss path;
  blocks              — StagePlan construction + plan-aware resolve path;
  sweep               — vectorized sweep engine vs seed per-config loop;
  ml_predict          — learned-predictor rank latency + holdout accuracy;
  online              — OnlineTuner per-decode-step overhead vs untimed;
  transfer            — cross-device warm-start vs cold evals-to-optimum
      (the BENCH_transfer gate: warm must halve cold's evaluation bill);
  pareto              — per-policy sweep winners + Pareto-front sizes
      (the BENCH_pareto gate: the energy policy must flip at least one
      winner with strictly lower modeled joules);
  analysis            — static-analysis pass timing per stage
      (the BENCH_analysis gate: the full zero-execution lint — AST rules,
      fingerprints, op x profile invariants — must finish under 10 s and
      come back clean);
  fusion              — fused vs unfused chain execution per chain
      (the BENCH_fusion gate: the fused arm must save a planned HBM pass
      on both chains, conform to its chain plan's launch list, and beat
      unfused wall clock on rglru);
  serving             — multi-tenant trace through the optimized serving
      engine vs the per-token replay baseline (the BENCH_serving gates:
      >= 3x tokens/sec on full runs, prefill dispatches and host
      transfers structurally bounded, fleet warm start strictly cheaper
      than cold).

``--seed`` flows into every stochastic section so CI runs are
reproducible; ``--json-dir`` writes one BENCH_<SECTION>.json per section
(the artifact the CI bench-smoke job uploads).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: prefix_ops,convergence,roofline,"
                         "resolve,blocks,sweep,ml_predict,online,transfer,"
                         "pareto,analysis,fusion,serving")
    ap.add_argument("--no-host-wallclock", action="store_true")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the stochastic sections (reproducible CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workloads/reps where supported")
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_<SECTION>.json files here")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    section_rows = {}
    current = [None]

    def emit(row: str) -> None:
        if current[0] is not None:
            section_rows.setdefault(current[0], []).append(row)
        print(row, flush=True)

    def begin(name: str) -> bool:
        active = only is None or name in only
        current[0] = name if active else None
        return active

    t0 = time.time()
    emit("table,op,variant,N,method,metric,value,extra")
    if begin("prefix_ops"):
        from benchmarks.bench_prefix_ops import run as run_ops
        run_ops(emit, host_wallclock=not args.no_host_wallclock)
    if begin("convergence"):
        from benchmarks.bench_convergence import run as run_conv
        run_conv(emit)
    if begin("roofline"):
        from benchmarks.bench_roofline import run as run_roof
        run_roof(emit)
    if begin("resolve"):
        from benchmarks.bench_resolve import run as run_resolve
        run_resolve(emit)
    if begin("blocks"):
        from benchmarks.bench_blocks import run as run_blocks
        run_blocks(emit)
    if begin("sweep"):
        from benchmarks.bench_sweep import run as run_sweep_bench
        run_sweep_bench(emit)
    if begin("ml_predict"):
        from benchmarks.bench_ml_predict import run as run_ml
        run_ml(emit, seed=args.seed, smoke=args.smoke)
    if begin("online"):
        from benchmarks.bench_online import run as run_online
        run_online(emit, seed=args.seed, smoke=args.smoke)
    gate_failures = []
    if begin("transfer"):
        from benchmarks.bench_transfer import run as run_transfer
        gate_failures += run_transfer(emit, seed=args.seed,
                                      smoke=args.smoke)
    if begin("pareto"):
        from benchmarks.bench_pareto import run as run_pareto
        gate_failures += run_pareto(emit, seed=args.seed, smoke=args.smoke)
    if begin("analysis"):
        from benchmarks.bench_analysis import run as run_analysis
        gate_failures += run_analysis(emit, seed=args.seed,
                                      smoke=args.smoke)
    if begin("fusion"):
        from benchmarks.bench_fusion import run as run_fusion
        gate_failures += run_fusion(emit, seed=args.seed, smoke=args.smoke)
    if begin("serving"):
        from benchmarks.bench_serving import run as run_serving
        gate_failures += run_serving(emit, seed=args.seed, smoke=args.smoke)

    if args.json_dir:
        os.makedirs(args.json_dir, exist_ok=True)
        for name, rows in section_rows.items():
            path = os.path.join(args.json_dir, f"BENCH_{name.upper()}.json")
            with open(path, "w") as f:
                json.dump({"bench": name, "seed": args.seed,
                           "smoke": bool(args.smoke), "rows": rows},
                          f, indent=1, sort_keys=True)
            print(f"# wrote {path}", file=sys.stderr)
    print(f"# benchmarks done in {time.time()-t0:.1f}s", file=sys.stderr)
    for failure in gate_failures:
        print(f"# FAIL: {failure}", file=sys.stderr)
    if gate_failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
