"""Transfer tuning: cross-size AND cross-device warm-starting (paper §IV-B).

The paper uses GPTune, whose Linear Coregionalization Model shares a
surrogate ACROSS tasks (problem sizes), so tuning size N starts from what
sizes N/2 and 2N already taught it. We reproduce the effect with a
transfer-GP: prior observations from neighbouring workloads enter the
training set with a task-distance kernel weight, and the acquisition is
optimized as usual. The practical win mirrors the paper's online story —
amortizing evaluations across repeated invocations of a routine family.

Task encoding: log2(N) normalized over the family's size range; the task
kernel is RBF over that coordinate, so closer sizes transfer more.

With the hardware-profile subsystem the module also earns its name
cross-*device* (Xue & Roy's cross-GPU CFD result, PAPERS.md): sweep
journals recorded on device A become prior histories for device B's
search. Absolute seconds do not transfer between machines, so each source
journal is normalized to per-journal *slowdowns* (t / min t — the
scale-free ranking), then reweighted by profile distance: slowdowns are
flattened toward 1.0 by ``exp(-profile_distance(src, dst))``, so a near
twin transfers its full ranking while a wildly different device
contributes almost nothing. ``transfer_seed`` drives a whole session from
foreign journals; ``transfer_strategy`` is the same path registered as
``strategy="transfer"``.

Histories from a different op family are rejected: the task kernel only
sees log2(N), so an FFT history at the same N would silently pollute a
scan search (regression-tested).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bayesian import GP, TuneResult, expected_improvement
from repro.core.objective import Objective, PENALTY_TIME
from repro.core.space import Config, SearchSpace, Workload, build_space
from repro.hw.profiles import HardwareProfile, get_profile, profile_distance

# ops that share one kernel family (and therefore one knob semantics); a
# history transfers inside a family, never across families
_FAMILY_POOL = {"ssd": "scan", "rglru": "scan"}


def op_family(op: str) -> str:
    return _FAMILY_POOL.get(op, op)


@dataclasses.dataclass
class TaskHistory:
    workload: Workload
    configs: List[Config]
    times: List[float]


class TransferBayesianTuner:
    """BO with cross-size transfer. `histories` hold (workload, config,
    time) observations from already-tuned sizes of the same op family."""

    name = "transfer"

    def __init__(self, n_init: int = 2, patience: int = 5, max_evals: int = 64,
                 seed: int = 0, task_lengthscale: float = 0.75):
        self.n_init = n_init
        self.patience = patience
        self.max_evals = max_evals
        self.seed = seed
        self.task_ls = task_lengthscale

    def _task_coord(self, wl: Workload) -> float:
        return math.log2(max(wl.n, 1)) / 24.0

    def tune(self, space: SearchSpace, objective: Objective,
             histories: Sequence[TaskHistory] = ()) -> TuneResult:
        rng = np.random.default_rng(self.seed)
        candidates = space.enumerate_valid()
        if not candidates:
            raise ValueError("empty space")
        # family guard: the task kernel only sees log2(N) — an FFT history
        # at the same N would otherwise enter a scan search's prior with
        # full weight and steer the bootstrap toward foreign-knob optima
        fam = op_family(space.workload.op)
        histories = [h for h in histories
                     if op_family(h.workload.op) == fam]
        enc = np.array([space.encode(c) for c in candidates])
        t_here = self._task_coord(space.workload)
        enc_aug = np.concatenate(
            [enc, np.full((len(enc), 1), 0.0)], axis=1)  # task delta 0

        # transfer set: neighbour observations, with their encoded config in
        # THIS space's coordinates when compatible, plus task-delta feature
        xs_prior: List[np.ndarray] = []
        ys_prior: List[float] = []
        for hist in histories:
            dt = (self._task_coord(hist.workload) - t_here) / self.task_ls
            for cfg, t in zip(hist.configs, hist.times):
                try:
                    x = space.encode({k: cfg.get(k, 0) for k in
                                      [p.name for p in space.params]})
                except Exception:
                    continue
                xs_prior.append(np.array(x + [dt]))
                ys_prior.append(t)

        history: List[Tuple[Config, float]] = []
        evaluated: Dict[int, float] = {}

        def measure(idx: int) -> float:
            m = objective(space, candidates[idx])
            t = m.time_s if m.valid else PENALTY_TIME
            evaluated[idx] = t
            history.append((candidates[idx], t))
            return t

        # warm bootstrap: rank candidates by the transfer-GP posterior mean
        # (zero fresh evaluations spent on ranking)
        order = rng.permutation(len(candidates))
        if xs_prior:
            gp0 = GP(lengthscale=0.5).fit(np.array(xs_prior),
                                          np.log(np.array(ys_prior)))
            mu0, _ = gp0.predict(enc_aug)
            order = np.argsort(mu0)      # most promising first
        for idx in order[: min(self.n_init, len(candidates))]:
            measure(int(idx))

        best_idx = min(evaluated, key=evaluated.get)
        best_t = evaluated[best_idx]
        since = 0
        stopped = "exhausted"
        while len(evaluated) < min(self.max_evals, len(candidates)):
            if since >= self.patience:
                stopped = "sliding_window"
                break
            xs = [list(enc[i]) + [0.0] for i in evaluated]
            ys = list(np.log(np.array(list(evaluated.values()))))
            xs_all = np.array(xs_prior + [np.array(x) for x in xs]) \
                if xs_prior else np.array(xs)
            ys_log_prior = [float(v) for v in np.log(np.asarray(ys_prior))] \
                if ys_prior else []
            ys_all = ys_log_prior + ys
            gp = GP(lengthscale=0.5).fit(np.asarray(xs_all, float),
                                         np.asarray(ys_all, float))
            remaining = [i for i in range(len(candidates))
                         if i not in evaluated]
            mu, sigma = gp.predict(enc_aug[remaining])
            ei = expected_improvement(mu, sigma, math.log(best_t))
            pick = remaining[int(np.argmax(ei))]
            t = measure(pick)
            if t < best_t * (1 - 1e-9):
                best_t, best_idx = t, pick
                since = 0
            else:
                since += 1
        else:
            # same semantics as BayesianTuner: "max_evals" when the budget
            # bound, "exhausted" only when the space truly ran out
            stopped = "max_evals" if len(evaluated) >= self.max_evals \
                else "exhausted"
        return TuneResult(candidates[best_idx], best_t, len(evaluated),
                          history, stopped)


# ---------------------------------------------------------------------------
# Cross-device transfer (profile-distance-weighted journal seeding)
# ---------------------------------------------------------------------------

def _journal_profile(header: Dict) -> Optional[str]:
    """Source profile of a journal: the v2 header field, else parsed from
    the legacy cost-model signature ("tpu_cost:<name>:noise=...")."""
    name = header.get("profile")
    if name:
        return str(name)
    sig = str(header.get("objective", ""))
    parts = sig.split(":")
    if len(parts) >= 3 and parts[0] in ("tpu_cost", "cost"):
        return parts[1]
    return None


def _journal_workload(header: Dict) -> Optional[Workload]:
    wl = header.get("workload") or {}
    try:
        return Workload(op=wl["op"], n=int(wl["n"]),
                        batch=int(wl.get("batch", 1)),
                        dtype=wl.get("dtype", "float32"),
                        variant=wl.get("variant", ""))
    except (KeyError, TypeError, ValueError):
        return None


def journal_history(path: str, target: HardwareProfile
                    ) -> Optional[Tuple[TaskHistory, float]]:
    """One journal -> (profile-distance-reweighted TaskHistory, weight).

    Times become per-journal slowdowns (t / min t) flattened toward 1.0 by
    ``w = exp(-profile_distance(src, target))``: the scale-free ranking of
    a close device transfers almost fully; a distant one barely at all.
    Returns None for unreadable journals, unknown source profiles, or
    journals measured on ``target`` itself (those are resumable directly —
    nothing to transfer).
    """
    from repro.tuning.sweep import SweepJournal

    j = SweepJournal(path)
    header = j.read_header()
    if header is None:
        return None
    src_name = _journal_profile(header)
    wl = _journal_workload(header)
    if src_name is None or wl is None or src_name == target.name:
        return None
    try:
        src = get_profile(src_name)
    except ValueError:
        return None
    entries = [(c, t) for c, t in j.entries() if t < PENALTY_TIME]
    if not entries:
        return None
    tmin = min(t for _, t in entries)
    w = math.exp(-profile_distance(src, target))
    hist = TaskHistory(
        wl, [c for c, _ in entries],
        [1.0 + (t / tmin - 1.0) * w for _, t in entries])
    return hist, w


def device_histories(journal_dir: str, wl: Workload,
                     target: HardwareProfile) -> List[TaskHistory]:
    """Other devices' sweep histories for ``wl``, reweighted for ``target``.

    Scans ``journal_dir`` for journals of the same workload recorded under
    a different profile (the per-(workload, objective) file naming makes
    them coexist in one directory).
    """
    from repro.tuning.sweep import _safe

    if not journal_dir or not os.path.isdir(journal_dir):
        return []
    prefix = _safe(wl.key) + "__"
    out: List[TaskHistory] = []
    for name in sorted(os.listdir(journal_dir)):
        if not (name.startswith(prefix) and name.endswith(".jsonl")):
            continue
        got = journal_history(os.path.join(journal_dir, name), target)
        if got is None:
            continue
        hist, _ = got
        if hist.workload.key == wl.key:
            out.append(hist)
    return out


def transfer_strategy(space: SearchSpace, objective: Objective, *,
                      seed: int = 0, max_evals: int = 64,
                      journal_dir: Optional[str] = None) -> TuneResult:
    """``strategy="transfer"``: warm-start from other devices' journals.

    With no journal directory (or no foreign journals in it) this is a
    cold Bayesian search — the strategy degrades, it never fails.
    """
    histories: Sequence[TaskHistory] = ()
    if journal_dir:
        histories = device_histories(journal_dir, space.workload, space.spec)
    return TransferBayesianTuner(seed=seed, max_evals=max_evals).tune(
        space, objective, histories)


def transfer_seed(session, journals, *, max_evals: int = 16, seed: int = 0,
                  store: bool = True) -> Dict[str, TuneResult]:
    """Warm-start ``session``'s device from another device's sweep journals.

    ``journals`` is an iterable of journal paths and/or directories (a
    directory contributes every ``*.jsonl`` inside). For each foreign
    journal the workload is rebuilt from its header, the recorded sweep
    becomes a profile-distance-weighted prior, and a short transfer search
    runs on the session's profile; winners land in the session's TuningDB
    under ``method="transfer"``. Returns ``{workload key: TuneResult}``.
    """
    from repro.core.objective import CachedObjective, CostModelObjective
    from repro.tuning.sweep import SweepJournal

    paths: List[str] = []
    for j in journals:
        if os.path.isdir(j):
            paths.extend(os.path.join(j, n) for n in sorted(os.listdir(j))
                         if n.endswith(".jsonl"))
        else:
            paths.append(j)

    out: Dict[str, TuneResult] = {}
    for path in paths:
        header = SweepJournal(path).read_header()
        wl = _journal_workload(header) if header else None
        if wl is None:
            continue
        got = journal_history(path, session.spec)
        if got is None:
            continue
        hist, _ = got
        space = build_space(wl, session.spec)
        cached = CachedObjective(CostModelObjective(session.spec))
        res = TransferBayesianTuner(seed=seed, max_evals=max_evals).tune(
            space, cached, (hist,))
        if store:
            session.db.store(wl, res.best_config, res.best_time, "transfer",
                             res.evaluations)
            session.invalidate(wl)
        out[wl.key] = res
    return out


def tune_family(op: str, variant: str, sizes: Sequence[int],
                batch_of, objective_factory, seed: int = 0
                ) -> Dict[int, TuneResult]:
    """Tune a family of sizes in order, transferring histories forward —
    the amortized online flow the paper describes for iterative callers."""
    histories: List[TaskHistory] = []
    out: Dict[int, TuneResult] = {}
    for n in sizes:
        wl = Workload(op=op, n=n, batch=batch_of(n), variant=variant)
        space = build_space(wl)
        tuner = TransferBayesianTuner(seed=seed)
        res = tuner.tune(space, objective_factory(), histories)
        out[n] = res
        histories.append(TaskHistory(
            wl, [c for c, _ in res.history], [t for _, t in res.history]))
    return out
