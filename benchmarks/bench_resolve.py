"""Micro-benchmark: online config-resolution hot path.

Compares, for a warm workload:
  * seed-style miss path — what every kernel call paid before the
    TunerSession existed on a DB miss: re-run the analytical model over the
    enumerated space, then re-fit the dict;
  * session resolve (warm) — the new hot path: LRU hit + copy.

Emits CSV rows (name,metric,value) and asserts the acceptance criterion
(warm resolve >= 10x faster than the miss path). ``--json`` writes a
BENCH_RESOLVE.json artifact for the CI perf trajectory.

    PYTHONPATH=src python benchmarks/bench_resolve.py --json BENCH_RESOLVE.json
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

from repro.core import Workload, build_space
from repro.core.analytical import AnalyticalTuner
from repro.core.space import normalize_config
from repro.tuning import TunerSession

WORKLOADS = [
    Workload(op="scan", n=512, batch=2**17, variant="lf"),
    Workload(op="tridiag", n=256, batch=2**14, variant="wm"),
    Workload(op="fft", n=1024, batch=2**12, variant="stockham"),
    Workload(op="attention", n=2048, batch=64, variant="flash"),
]


def timeit(fn, reps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(emit) -> float:
    session = TunerSession(db_path=tempfile.mktemp(suffix="_bench_db.json"))
    worst = float("inf")
    for wl in WORKLOADS:
        tuner = AnalyticalTuner()

        def miss_path(wl=wl, tuner=tuner):
            cfg = tuner.suggest(build_space(wl))
            return normalize_config(cfg, wl)

        session.resolve(wl)                      # prime the LRU
        t_miss = timeit(miss_path, 5)
        t_warm = timeit(lambda wl=wl: session.resolve(wl), 200)
        speedup = t_miss / max(t_warm, 1e-12)
        worst = min(worst, speedup)
        emit(f"resolve,{wl.op}:{wl.variant},miss_us,{t_miss*1e6:.1f}")
        emit(f"resolve,{wl.op}:{wl.variant},warm_us,{t_warm*1e6:.2f}")
        emit(f"resolve,{wl.op}:{wl.variant},speedup,{speedup:.0f}")
    return worst


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write a BENCH_RESOLVE.json summary")
    ap.add_argument("--seed", type=int, default=0,
                    help="accepted for CLI uniformity; this bench is "
                         "deterministic apart from timer noise")
    ap.add_argument("--no-assert", action="store_true",
                    help="record the speedup without gating on it (for "
                         "noisy shared CI runners; the pytest suite still "
                         "enforces the 10x criterion)")
    args = ap.parse_args()
    rows = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    worst = run(emit)
    if not args.no_assert:
        assert worst >= 10, \
            f"warm resolve only {worst:.1f}x faster than miss path"
        print(f"# acceptance ok: worst-case speedup {worst:.0f}x (>= 10x)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "resolve", "seed": args.seed, "rows": rows,
                       "summary": {"worst_speedup": worst}},
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
