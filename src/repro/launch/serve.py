"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=args.max_batch,
                         max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        plen = int(rng.integers(4, 16))
        engine.submit(rng.integers(0, cfg.vocab, size=plen),
                      max_new_tokens=args.max_new)
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> out[:8]={r.output[:8]}")


if __name__ == "__main__":
    main()
