"""The full paper workflow: exhaustive vs analytical vs Bayesian tuning on
every prefix-op family, with Table-II-style Phi reporting — driven through
the `repro.tuning` API (strategy registry + TunerSession).

    PYTHONPATH=src python examples/autotune_kernels.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import Workload
from repro.core.metrics import phi
from repro.tuning import TunerSession
from benchmarks.common import tune_all_methods

CASES = [("scan", "lf", [128, 256, 512, 1024]),
         ("scan", "ks", [128, 256, 512, 1024]),
         ("tridiag", "wm", [64, 128, 256, 512]),
         ("tridiag", "pcr", [64, 128, 256, 512]),
         ("fft", "stockham", [64, 256, 1024, 4096])]

# winners land in a session-owned DB: the offline half of the paper's flow
session = TunerSession(db_path=tempfile.mktemp(suffix="_autotune_db.json"))

print(f"{'op':22s} {'PHI_analytical':>15s} {'PHI_bayesian':>13s} "
      f"{'BO evals':>9s}")
for op, variant, sizes in CASES:
    effs = {"analytical": [], "bayesian": []}
    evals = []
    for n in sizes:
        wl = Workload(op=op, n=n, batch=max(2**26 // n, 1), variant=variant)
        res = tune_all_methods(wl)
        session.db.store(wl, res["bayesian"]["config"],
                         res["bayesian"]["time_s"], "bayesian",
                         res["bayesian"]["evals"])
        effs["analytical"].append(res["analytical"]["efficiency"])
        effs["bayesian"].append(res["bayesian"]["efficiency"])
        evals.append(res["bayesian"]["evals"])
    print(f"{op+'-'+variant:22s} {phi(effs['analytical']):15.4f} "
          f"{phi(effs['bayesian']):13.4f} {str(evals):>9s}")

# online half: every stored workload resolves instantly from the session
warm = session.resolve(Workload(op="scan", n=1024, batch=2**26 // 1024,
                                variant="ks"))
print(f"\nwarm online resolve (DB-backed): {warm}")
print(f"session stats: {session.stats()}")
