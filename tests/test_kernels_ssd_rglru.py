"""SSD (Mamba-2) and RG-LRU kernels vs sequential oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rglru.ops import rglru
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_ref

KEY = jax.random.PRNGKey(0)


def _ssd_inputs(B=2, L=256, H=2, P=16, S=8):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, L, H, P))
    a = jax.random.uniform(ks[1], (B, L, H), minval=0.85, maxval=0.999)
    b = jax.random.normal(ks[2], (B, L, S)) * 0.3
    c = jax.random.normal(ks[3], (B, L, S)) * 0.3
    return x, a, b, c


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_ssd_chunked_ref_matches_sequential(chunk):
    x, a, b, c = _ssd_inputs()
    ref = ssd_ref(x, a, b, c)
    got = ssd_chunked_ref(x, a, b, c, chunk=chunk)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [64, 128])
def test_ssd_pallas_pipeline(chunk):
    x, a, b, c = _ssd_inputs()
    ref = ssd_ref(x, a, b, c)
    got = ssd(x, a, b, c, config={"tile_n": chunk}, interpret=True)
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)


def test_ssd_small_decay_no_nan_grads():
    x, a, b, c = _ssd_inputs()
    a = a * 0.01      # strong decay: exercises the masked-exp stability fix
    def loss(x):
        return jnp.sum(ssd_chunked_ref(x, a, b, c, chunk=64) ** 2)
    g = jax.grad(loss)(x)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_rglru_matches_ref():
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (2, 128, 16), minval=0.8, maxval=0.99)
    u = jax.random.normal(ks[1], (2, 128, 16))
    ref = rglru_ref(a, u)
    got = rglru(a, u, config={"rows_per_program": 8, "tile_n": 128,
                              "radix": 4, "unroll": 1}, interpret=True)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Chain fusion + embedded-block config resolution
# ---------------------------------------------------------------------------

def test_ssd_override_reaches_embedded_phase_b():
    """Regression: the enclosing ssd resolution must be threaded into the
    embedded phase-B linrec block.  Before the fix, ``linrec_rows`` ran a
    fresh ``config=None`` resolution, so ``ssd(config=...)`` (and
    ``overrides(ssd=...)``) could never change the phase-B launch — here a
    radix override must flip its in-kernel stage decomposition."""
    from repro.kernels.blocks import driver

    x, a, b, c = _ssd_inputs(L=512)        # chunk 128 -> nc = 4
    traces = {}
    for radix in (2, 4):
        with driver.capture_launches() as rec:
            got = ssd(x, a, b, c,
                      config={"tile_n": 128, "radix": radix, "fuse": 0},
                      interpret=True, use_pallas=True)
        np.testing.assert_allclose(got, ssd_ref(x, a, b, c),
                                   rtol=1e-3, atol=1e-3)
        traces[radix] = [l for l in rec if l.name == "scan"]
    assert traces[2] and traces[4]
    assert traces[2][0].stages == (2, 2)    # nc = 4 under radix 2
    assert traces[4][0].stages == (4,)      # the override reached phase B


def test_ssd_overrides_context_reaches_embedded_phase_b():
    from repro.kernels.blocks import driver
    from repro.tuning import overrides

    x, a, b, c = _ssd_inputs(L=512)
    with overrides(ssd={"tile_n": 128, "radix": 4, "fuse": 0}):
        with driver.capture_launches() as rec:
            ssd(x, a, b, c, interpret=True, use_pallas=True)
    scans = [l for l in rec if l.name == "scan"]
    assert scans and scans[0].stages == (4,)


@pytest.mark.parametrize("op", ["ssd", "rglru"])
def test_fused_chain_issues_strictly_fewer_launches(op):
    """The fused chain must issue strictly fewer launches than the
    unfused one for at least this config (ssd: 3 -> 2 kernel launches;
    rglru: the multipass chain drops the XLA gate pass, counted through
    the plan since XLA ops don't appear in the Pallas launch trace)."""
    from repro.core.space import Workload
    from repro.kernels.blocks import driver
    from repro.kernels.blocks.plan import plan_for_chain

    traces = {}
    if op == "ssd":
        x, a, b, c = _ssd_inputs(L=512)
        for fuse in (0, 1):
            cfg = {"tile_n": 128, "radix": 2, "fuse": fuse}
            with driver.capture_launches() as rec:
                ssd(x, a, b, c, config=cfg, interpret=True, use_pallas=True)
            traces[fuse] = list(rec)
        assert len(traces[1]) < len(traces[0])
    else:
        ks = jax.random.split(KEY, 2)
        a = jax.random.uniform(ks[0], (2, 256, 16), minval=0.8, maxval=0.99)
        u = jax.random.normal(ks[1], (2, 256, 16))
        wl = Workload(op="rglru", n=256, batch=32)
        passes = {}
        for fuse in (0, 1):
            cfg = {"tile_n": 128, "rows_per_program": 8, "radix": 2,
                   "fuse": fuse}
            chain = plan_for_chain(wl, cfg)
            with driver.capture_launches() as rec:
                rglru(a, u, config=cfg, interpret=True, use_pallas=True)
            assert tuple(rec) == tuple(chain.launches)
            passes[fuse] = chain.plan.passes
        assert passes[1] < passes[0]


@pytest.mark.parametrize("fuse", [0, 1])
def test_ssd_executed_launches_equal_chain_plan(fuse):
    """Conformance: the executed launch list is exactly the chain plan's
    (dims pin the embedded phase-B geometry, so equality is structural)."""
    from repro.core.space import Workload
    from repro.kernels.blocks import driver
    from repro.kernels.blocks.plan import plan_for_chain

    x, a, b, c = _ssd_inputs(L=512)
    B, L, H, P = x.shape
    S = b.shape[-1]
    wl = Workload(op="ssd", n=L, batch=B * H, variant="chunked")
    cfg = {"tile_n": 128, "radix": 2, "fuse": fuse}
    chain = plan_for_chain(wl, cfg, dims=(S, P))
    with driver.capture_launches() as rec:
        ssd(x, a, b, c, config=cfg, interpret=True, use_pallas=True)
    assert tuple(rec) == tuple(chain.launches)


def test_ssd_fused_handles_odd_chunk_count():
    """nc = 3: unfused phase B has no valid linrec config (XLA fallback);
    the fused sequential carry runs in-kernel and must still match."""
    x, a, b, c = _ssd_inputs(L=384)
    ref = ssd_ref(x, a, b, c)
    for fuse in (0, 1):
        got = ssd(x, a, b, c, config={"tile_n": 128, "fuse": fuse},
                  interpret=True, use_pallas=True)
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-3)
