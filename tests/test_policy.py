"""Policy layer: vector measurements, scalarization, Pareto fronts,
policy-keyed persistence, and the online power-envelope guard.

The multi-objective contract (docs/tuning.md): objectives answer *what
happened* (a metric vector per config), a Policy answers *what to
optimize*.  One exhaustive sweep journals the vectors once; every policy
then picks its winner from the same measurements.  Everything here pins
that contract — plus the migrations that keep pre-vector artifacts
(schema-3 DBs, v2 journals, version-0 measurements) loading as
time_s-only vectors.
"""
import json
import warnings

import numpy as np
import pytest

from repro.core import Workload, build_space
from repro.core.objective import (MEASUREMENT_VERSION, METRIC_ENERGY,
                                  METRIC_PEAK_VMEM, METRIC_TIME,
                                  PENALTY_TIME, CostModelObjective,
                                  Measurement, metric_penalty)
from repro.core.policy import (POLICY_NAMES, Policy, PolicyObjective,
                               get_policy, pareto_front, pareto_mask,
                               policy_scalar_cols)
from repro.hw.profiles import GPU_SM, TPU_V5E
from repro.tuning.db import SCHEMA_VERSION, TuningDB
from repro.tuning.session import TunerSession
from repro.tuning.sweep import run_sweep

WL = Workload(op="scan", n=256, batch=2**10, variant="lf")


# ---------------------------------------------------------------------------
# Measurement: vector carrier with versioned serialization
# ---------------------------------------------------------------------------

def test_measurement_roundtrip_versioned():
    m = Measurement(1e-3, True, meta={"passes": 2.0},
                    metrics={METRIC_ENERGY: 0.5,
                             METRIC_PEAK_VMEM: float(2**20)})
    d = m.to_dict()
    assert d["version"] == MEASUREMENT_VERSION
    # through JSON (the journal/DB wire format), not just dict identity
    m2 = Measurement.from_dict(json.loads(json.dumps(d)))
    assert m2 == m
    assert m2.energy_j == 0.5 and m2.peak_vmem_bytes == float(2**20)


def test_measurement_version0_loads_time_only():
    """Pre-vector dicts (no ``metrics``) load as time_s-only vectors."""
    m = Measurement.from_dict({"time_s": 2e-3, "valid": True})
    assert m.time_s == 2e-3
    assert m.metrics == {METRIC_TIME: 2e-3}
    assert m.energy_j is None and m.peak_vmem_bytes is None


def test_measurement_mirrors_time_into_vector():
    m = Measurement(3e-3, True)
    assert m.metrics[METRIC_TIME] == 3e-3
    assert m.metric(METRIC_ENERGY) is None
    assert m.metric(METRIC_ENERGY, 7.0) == 7.0


def test_cost_model_emits_energy_and_vmem():
    space = build_space(WL)
    obj = CostModelObjective(TPU_V5E)
    m = obj(space, space.enumerate_valid()[0])
    assert m.valid
    # energy = idle_w*t + peak_compute_w*t_comp + hbm_pj_per_byte*bytes:
    # strictly more than the idle floor, and derived FROM the latency
    # (never an input to it — pinned by the tpu_v5e fixture test)
    assert m.energy_j is not None and m.energy_j > TPU_V5E.idle_w * m.time_s
    assert m.peak_vmem_bytes is not None and m.peak_vmem_bytes > 0


# ---------------------------------------------------------------------------
# Policy scalarization
# ---------------------------------------------------------------------------

def _cols():
    return {METRIC_TIME: np.array([1.0, 3.0, 10.0]),
            METRIC_ENERGY: np.array([30.0, 2.0, 1.0]),
            METRIC_PEAK_VMEM: np.array([100.0, 50.0, 10.0])}


def test_policy_registry_and_prune_safety():
    assert POLICY_NAMES == ("latency", "energy", "edp", "memory_cap")
    assert get_policy("latency").prune_safe
    for name in ("energy", "edp"):
        assert not get_policy(name).prune_safe
    assert not get_policy("memory_cap:1024").prune_safe
    with pytest.raises(ValueError, match="unknown policy"):
        get_policy("throughput")


def test_policy_keys():
    assert get_policy("energy").key == "energy"
    assert get_policy("memory_cap:2048").key == "memory_cap[2048]"
    # no explicit cap: the profile's vmem budget fills in
    pol = get_policy("memory_cap", TPU_V5E)
    assert pol.cap_bytes == float(TPU_V5E.vmem_budget)


def test_scalarize_matches_scalarize_cols_bitwise():
    cols = _cols()
    for name in ("latency", "energy", "edp", "memory_cap:60"):
        pol = get_policy(name)
        s = pol.scalarize_cols(cols)
        for i in range(3):
            vec = {k: float(v[i]) for k, v in cols.items()}
            assert pol.scalarize(vec) == s[i], (name, i)


def test_each_policy_picks_a_different_winner():
    cols = _cols()
    # latency: t=[1,3,10] -> row 0; energy: e=[30,2,1] -> row 2;
    # edp: t*e=[30,6,10] -> row 1
    winners = {n: int(np.argmin(policy_scalar_cols(get_policy(n), cols)))
               for n in ("latency", "energy", "edp")}
    assert winners == {"latency": 0, "energy": 2, "edp": 1}


def test_missing_energy_axis_falls_back_to_time():
    cols = {METRIC_TIME: np.array([2.0, 1.0])}
    pol = get_policy("energy")
    assert list(policy_scalar_cols(pol, cols)) == [2.0, 1.0]
    assert pol.scalarize({METRIC_TIME: 2.0}) == 2.0
    # NaN rows (pre-vector journal resume) fall back per-row
    cols[METRIC_ENERGY] = np.array([np.nan, 5.0])
    assert list(policy_scalar_cols(pol, cols)) == [2.0, 5.0]


def test_memory_cap_clamps_over_budget_rows_to_penalty():
    cols = _cols()                       # vmem [100, 50, 10]
    scal = policy_scalar_cols(get_policy("memory_cap:60"), cols)
    assert scal[0] == PENALTY_TIME       # 100 > 60: clamped
    assert scal[1] == 3.0 and scal[2] == 10.0
    # the unclamped scalar form reports inf (PolicyObjective clamps it)
    assert get_policy("memory_cap:60").scalarize(
        {k: float(v[0]) for k, v in _cols().items()}) == float("inf")


def test_penalty_time_rows_stay_penalty_under_every_policy():
    """A failed measurement must lose under every policy, even when its
    other axes look attractive."""
    cols = {METRIC_TIME: np.array([PENALTY_TIME, 1.0]),
            METRIC_ENERGY: np.array([1e-9, 5.0]),
            METRIC_PEAK_VMEM: np.array([1.0, 10.0])}
    for name in ("latency", "energy", "edp", "memory_cap:1e9"):
        scal = policy_scalar_cols(get_policy(name), cols)
        assert scal[0] == PENALTY_TIME, name
        assert scal[1] != PENALTY_TIME, name


# ---------------------------------------------------------------------------
# Pareto front
# ---------------------------------------------------------------------------

def test_pareto_mask_basic_domination():
    cols = {METRIC_TIME: np.array([1.0, 2.0, 3.0]),
            METRIC_ENERGY: np.array([3.0, 2.0, 1.0])}
    assert list(pareto_mask(cols)) == [True, True, True]   # a real front
    cols[METRIC_ENERGY] = np.array([1.0, 2.0, 3.0])        # row 0 dominates
    assert list(pareto_mask(cols)) == [True, False, False]


def test_pareto_mask_keeps_exact_ties():
    cols = {METRIC_TIME: np.array([1.0, 1.0, 2.0]),
            METRIC_ENERGY: np.array([5.0, 5.0, 5.0])}
    assert list(pareto_mask(cols)) == [True, True, False]


def test_pareto_mask_excludes_failed_rows():
    cols = {METRIC_TIME: np.array([PENALTY_TIME, 1.0]),
            METRIC_ENERGY: np.array([0.5, 2.0])}
    assert list(pareto_mask(cols)) == [False, True]


def test_pareto_front_contains_every_policy_optimum():
    """Whatever scalarization a policy applies, its optimum is always on
    the front — the property that lets resolve() answer any policy from
    one sweep."""
    space = build_space(WL)
    obj = CostModelObjective(TPU_V5E)
    cands = space.enumerate_valid()
    cols = obj.batch_eval_metrics(space, cands, assume_valid=True)
    front = pareto_front(cols, cands, obj.metric_names())
    assert front
    for name in ("latency", "energy", "edp"):
        pol = get_policy(name)
        # the front achieves the global optimum of every policy scalar
        # (by value: an argmin row tied on one axis may be dominated by a
        # same-scalar row that is strictly better elsewhere)
        global_best = float(np.min(policy_scalar_cols(pol, cols)))
        front_best = min(pol.scalarize(vec) for _, vec in front)
        assert front_best == global_best, name


# ---------------------------------------------------------------------------
# The sweep under a policy
# ---------------------------------------------------------------------------

def test_run_sweep_journals_vectors_and_serves_every_policy(tmp_path):
    from repro.tuning.sweep import SweepJournal
    space = build_space(WL)
    obj = CostModelObjective(TPU_V5E)
    journal = SweepJournal.for_workload(str(tmp_path), WL, obj)
    res = run_sweep(space, obj, journal=journal)
    assert res.policy is None and res.metrics is not None
    assert set(res.metrics) == set(obj.metric_names())
    assert res.pareto                       # non-empty front rides along

    # the journal holds full vectors ...
    vecs = journal.load_metrics(WL, obj)
    assert vecs and all(METRIC_ENERGY in v for v in vecs.values())

    # ... so a policy re-run resumes 100% (zero fresh evaluations) and
    # picks its own winner from the same measurements
    res_e = run_sweep(space, obj, journal=SweepJournal(journal.path),
                      policy="energy")
    assert res_e.evaluations == 0 and res_e.resumed == res.total
    assert res_e.policy == "energy"
    scal = policy_scalar_cols(get_policy("energy"), res.metrics)
    assert res_e.best_scalar == float(np.min(scal))
    # best_time stays the winner's real seconds, not the scalar
    i = int(np.argmin(scal))
    assert res_e.best_time == res.metrics[METRIC_TIME][i]


def test_run_sweep_policy_winner_differs_from_latency():
    space = build_space(Workload(op="scan", n=1024, batch=512, variant="lf"))
    obj = CostModelObjective(TPU_V5E)
    lat = run_sweep(space, obj)
    edp = run_sweep(space, obj, policy="edp")
    scal = policy_scalar_cols(get_policy("edp"), lat.metrics)
    assert edp.best_scalar == float(np.min(scal))
    # as_tune_result reports the scalar the search minimized
    tr = edp.as_tune_result()
    assert tr.best_time == edp.best_scalar
    assert tr.best_config == edp.best_config


def test_prune_refuses_non_latency_policy():
    space = build_space(WL)
    obj = CostModelObjective(TPU_V5E)
    with pytest.raises(ValueError, match="prune"):
        run_sweep(space, obj, prune="analytical", policy="energy")
    # latency composes fine (explicitly and by default)
    assert run_sweep(space, obj, prune="analytical",
                     policy="latency").best_config


# ---------------------------------------------------------------------------
# PolicyObjective: any strategy tunes any policy
# ---------------------------------------------------------------------------

def test_policy_objective_scalar_protocol():
    space = build_space(WL)
    inner = CostModelObjective(TPU_V5E)
    pobj = PolicyObjective(inner, "energy")
    cfg = space.enumerate_valid()[0]
    m_in, m_out = inner(space, cfg), pobj(space, cfg)
    # time_s IS the policy scalar; the vector keeps the real seconds
    assert m_out.time_s == m_in.energy_j
    assert m_out.metrics[METRIC_TIME] == m_in.time_s
    assert pobj.signature() == inner.signature() + "|policy=energy"
    assert pobj.spec is TPU_V5E


def test_policy_objective_latency_is_numeric_noop():
    space = build_space(WL)
    inner = CostModelObjective(TPU_V5E)
    pobj = PolicyObjective(inner, "latency")
    cfgs = space.enumerate_valid()[:8]
    assert np.array_equal(pobj.batch_eval(space, cfgs),
                          inner.batch_eval(space, cfgs))


def test_policy_objective_rejects_over_cap_on_every_axis():
    pol = Policy("memory_cap", cap_bytes=1.0)    # nothing fits
    space = build_space(WL)
    pobj = PolicyObjective(CostModelObjective(TPU_V5E), pol)
    cfg = space.enumerate_valid()[0]
    m = pobj(space, cfg)
    assert not m.valid and m.time_s == PENALTY_TIME
    cols = pobj.batch_eval_metrics(space, [cfg], assume_valid=True)
    for n in pobj.metric_names():
        assert cols[n][0] == metric_penalty(n)


# ---------------------------------------------------------------------------
# Policy-keyed persistence (DB schema 4) and session resolution
# ---------------------------------------------------------------------------

def test_db_keys_policies_separately(tmp_path):
    db = TuningDB(path=str(tmp_path / "db.json"), platform="tpu_v5e")
    db.store(WL, {"radix": 4}, 1e-3, "exhaustive", 5)
    db.store(WL, {"radix": 8}, 2e-3, "exhaustive", 5, policy="energy",
             metrics={METRIC_TIME: 2e-3, METRIC_ENERGY: 0.1})
    assert db.lookup(WL) == {"radix": 4}
    assert db.lookup(WL, policy="latency") == {"radix": 4}
    assert db.lookup(WL, policy="energy") == {"radix": 8}
    assert db.lookup(WL, policy="edp") is None


def test_db_schema3_scalar_entries_migrate_to_vectors(tmp_path):
    path = str(tmp_path / "db.json")
    legacy = {"schema": 3, "entries": {
        f"tpu_v5e|{WL.key}": {"config": {"radix": 4}, "time_s": 1e-3,
                              "method": "bayesian", "evaluations": 5,
                              "profile": "tpu_v5e"}}}
    with open(path, "w") as f:
        json.dump(legacy, f)
    db = TuningDB(path=path, platform="tpu_v5e")
    # the scalar entry resolves as the latency winner, vectorized
    assert db.lookup(WL) == {"radix": 4}
    entry = db.entries()[f"tpu_v5e|{WL.key}"]
    assert entry["policy"] == "latency"
    assert entry["metrics"] == {METRIC_TIME: 1e-3}
    # and persists under the current schema on the next store
    db.store(WL, {"radix": 8}, 5e-4, "bayesian", 3)
    with open(path) as f:
        assert json.load(f)["schema"] == SCHEMA_VERSION


def test_session_resolves_per_policy(tmp_path):
    path = str(tmp_path / "db.json")
    lat = TunerSession(db_path=path)
    lat.tune(WL, method="exhaustive")
    cfg_lat = lat.resolve_raw(WL)
    assert cfg_lat == lat.lookup(WL)
    # a second session (fresh DB load: the store caches per instance)
    # tunes the same workload for energy; both winners coexist on disk
    eng = TunerSession(db_path=path, policy="energy")
    eng.tune(WL, method="exhaustive")
    cfg_eng = eng.resolve_raw(WL)
    assert cfg_eng == eng.lookup(WL, policy="energy")
    assert eng.lookup(WL, policy="latency") == cfg_lat
    fresh = TunerSession(db_path=path)
    assert fresh.lookup(WL) == cfg_lat
    assert fresh.lookup(WL, policy="energy") == cfg_eng
    # and they are the true per-policy optima of the same space
    space = build_space(WL)
    obj = CostModelObjective(TPU_V5E)
    cands = space.enumerate_valid()
    cols = obj.batch_eval_metrics(space, cands, assume_valid=True)
    assert cfg_lat == cands[int(np.argmin(cols[METRIC_TIME]))]
    scal = policy_scalar_cols(get_policy("energy"), cols)
    assert cfg_eng == cands[int(np.argmin(scal))]


def test_session_tune_stores_real_seconds_under_policy(tmp_path):
    session = TunerSession(db_path=str(tmp_path / "db.json"),
                           policy="energy")
    session.tune(WL, method="exhaustive")
    entry = next(iter(session.db.entries().values()))
    assert entry["policy"] == "energy"
    # time_s in the DB is wall-clock seconds, never the policy scalar
    assert entry["time_s"] == entry["metrics"][METRIC_TIME]
    assert entry["time_s"] < 1.0


@pytest.mark.parametrize("method", ["bayesian", "random", "analytical"])
def test_non_exhaustive_strategies_accept_policies(tmp_path, method):
    session = TunerSession(db_path=str(tmp_path / "db.json"), policy="edp")
    res = session.tune(WL, method=method, max_evals=16)
    assert res.best_config
    assert session.resolve_raw(WL) == session.lookup(WL, policy="edp")


# ---------------------------------------------------------------------------
# Online tuning: the power-envelope guard
# ---------------------------------------------------------------------------

def _watts(session, cfg):
    space = build_space(WL)
    m = CostModelObjective(session.spec)(space, cfg)
    return m.energy_j / m.time_s


def test_online_power_envelope_vetoes_hot_candidates(tmp_path):
    from repro.tuning import OnlineTuner
    from repro.tuning.online import ranked_candidates
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    prior = session.resolve_raw(WL)
    space = build_space(WL)
    cands = ranked_candidates(space, 8)
    incumbent_w = _watts(session, prior)

    tuner = OnlineTuner(WL, session, prior=prior, candidates=list(cands),
                        power_envelope=1.0, store=False)
    # drive enough steady traffic to walk the candidate list
    for _ in range(4000):
        tuner.observe(1e-3)
        if tuner.finished:
            break
    assert tuner.power_vetoed, "no candidate was hotter than the incumbent"
    for cfg in tuner.power_vetoed:
        assert _watts(session, cfg) > incumbent_w
    # vetoed configs never spent production traffic as trials
    vetoed_keys = {json.dumps(c, sort_keys=True) for c in tuner.power_vetoed}
    trialed = {json.dumps(t.config, sort_keys=True) for t in tuner.trials}
    assert not (vetoed_keys & trialed)
    assert tuner.summary()["power_vetoed"] == len(tuner.power_vetoed)


def test_online_power_envelope_off_by_default(tmp_path):
    from repro.tuning import OnlineTuner
    session = TunerSession(db_path=str(tmp_path / "db.json"))
    tuner = OnlineTuner(WL, session, store=False)
    assert tuner.power_envelope is None and tuner.power_vetoed == []
    with pytest.raises(ValueError):
        OnlineTuner(WL, session, power_envelope=0.0, store=False)


# ---------------------------------------------------------------------------
# spec= -> profile= deprecation
# ---------------------------------------------------------------------------

def test_build_space_spec_kwarg_warns():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        build_space(WL, GPU_SM)                       # canonical: silent
    with pytest.warns(DeprecationWarning, match="profile"):
        space = build_space(WL, spec=GPU_SM)
    assert space.spec is GPU_SM


def test_plan_for_spec_kwarg_warns():
    from repro.kernels.blocks.plan import plan_for
    space = build_space(WL)
    cfg = space.enumerate_valid()[0]
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        canonical = plan_for(WL, cfg, profile=TPU_V5E)
    with pytest.warns(DeprecationWarning, match="profile"):
        legacy = plan_for(WL, cfg, spec=TPU_V5E)
    assert legacy.stages == canonical.stages


def test_cost_model_spec_kwarg_warns():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        canonical = CostModelObjective(profile=TPU_V5E)
    with pytest.warns(DeprecationWarning, match="profile"):
        legacy = CostModelObjective(spec=TPU_V5E)
    assert legacy.signature() == canonical.signature()


# ---------------------------------------------------------------------------
# ML dataset: metric-aware labels
# ---------------------------------------------------------------------------

def test_dataset_labels_follow_policy(tmp_path):
    """``policy=`` relabels the same sweep with that policy's scalars —
    journaled once under the raw objective, consumed by every policy."""
    from repro.tuning.ml.dataset import dataset_from_journal, sweep_workload
    obj = CostModelObjective(TPU_V5E)
    cfgs, _, t_lat = sweep_workload(WL, obj, journal_dir=str(tmp_path))
    cfgs_e, _, t_eng = sweep_workload(WL, obj, journal_dir=str(tmp_path),
                                      policy="energy")
    assert cfgs_e == cfgs                     # same sweep, same order
    space = build_space(WL)
    cols = obj.batch_eval_metrics(space, cfgs, assume_valid=True)
    assert np.array_equal(np.asarray(t_lat), cols[METRIC_TIME])
    assert np.array_equal(
        np.asarray(t_eng),
        policy_scalar_cols(get_policy("energy"), cols))

    # the journal path agrees: one file on disk serves both labelings
    files = [f for f in __import__("os").listdir(str(tmp_path))
             if f.endswith(".jsonl")]
    assert len(files) == 1
    import os
    path = os.path.join(str(tmp_path), files[0])
    ds_lat = dataset_from_journal(path)
    ds_eng = dataset_from_journal(path, policy="energy")
    assert len(ds_lat.y) == len(ds_eng.y) == len(cfgs)
    assert not np.array_equal(ds_lat.y, ds_eng.y)
    # rows are labeled log(slowdown vs the group's best) of the policy
    # scalar — recompute from the raw metric columns
    logs = np.log(np.maximum(
        policy_scalar_cols(get_policy("energy"), cols), 1e-12))
    assert np.allclose(np.sort(ds_eng.y), np.sort(logs - logs.min()))
