"""Hardware-profile subsystem conformance (docs/hardware.md).

Three contracts:

  1. **tpu_v5e is bit-identical to the pre-profile stack** — every scalar
     and batched cost reproduces the fixture captured before the
     refactor, down to the float bit pattern (``float.hex``).
  2. **Every registered profile is usable end to end** — for each op the
     registry knows, the profile-bounded space is non-empty and every
     sampled StagePlan / cost-model quantity is finite.
  3. **Persistence never crosses devices** — TuningDB entries and sweep
     journals recorded under one profile are invisible (DB) or rejected
     (journal) under another, and legacy records migrate to tpu_v5e.
"""
import json
import os

import numpy as np
import pytest

from repro.core.objective import CostModelObjective, TPUCostModelObjective
from repro.core.space import Workload, build_space
from repro.hw.profiles import (CPU_INTERPRET, GPU_SM, TPU_V5E,
                               HardwareProfile, active_profile, get_profile,
                               profile_distance, profiles, register_profile)
from repro.kernels.blocks.plan import plan_for
from repro.tuning.db import SCHEMA_VERSION, TuningDB
from repro.tuning.ml.dataset import SUITE
from repro.tuning.registry import known_ops
from repro.tuning.sweep import SweepJournal, run_sweep

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "cost_model_tpu_v5e.json")


def _fixture():
    with open(FIXTURE) as f:
        return json.load(f)


def _wl(rec) -> Workload:
    w = rec["workload"]
    return Workload(op=w["op"], n=w["n"], batch=w["batch"],
                    dtype=w["dtype"], variant=w["variant"])


# ---------------------------------------------------------------------------
# 1. tpu_v5e bit-identity vs the pre-refactor fixture
# ---------------------------------------------------------------------------

def test_fixture_signature_unchanged(monkeypatch):
    monkeypatch.delenv("REPRO_HW_PROFILE", raising=False)
    fx = _fixture()
    assert TPUCostModelObjective(noise=0.0).signature() == fx["signature"]
    # the alias and the profile-parameterized class are the same object
    assert TPUCostModelObjective is CostModelObjective
    assert CostModelObjective(TPU_V5E, noise=0.0).signature() \
        == fx["signature"]


@pytest.mark.parametrize("rec", _fixture()["records"],
                         ids=lambda r: r["workload"]["op"] + "_n"
                         + str(r["workload"]["n"]))
def test_tpu_v5e_costs_bit_identical(rec):
    wl = _wl(rec)
    space = build_space(wl, TPU_V5E)
    obj = CostModelObjective(TPU_V5E, noise=rec["noise"])
    cands = space.enumerate_valid()
    assert len(cands) == rec["space_size"]

    # scalar path: each sampled config reproduces its captured bits
    cfgs = [s["cfg"] for s in rec["scalar"]]
    for s in rec["scalar"]:
        assert obj(space, s["cfg"]).time_s.hex() == s["t_hex"]

    # batch path: same samples through batch_eval, plus whole-space
    # sum/min (any arithmetic drift anywhere in the space moves these)
    ts = obj.batch_eval(space, cfgs, assume_valid=True)
    assert [float(t).hex() for t in ts] == rec["batch_sample_hex"]
    all_ts = obj.batch_eval(space, cands, assume_valid=True)
    assert float(np.sum(all_ts)).hex() == rec["batch_sum_hex"]
    assert float(np.min(all_ts)).hex() == rec["batch_min_hex"]


def test_default_profile_is_tpu_v5e(monkeypatch):
    monkeypatch.delenv("REPRO_HW_PROFILE", raising=False)
    assert active_profile() is TPU_V5E
    # and the default-constructed objective/space bind to it
    assert CostModelObjective().spec is TPU_V5E
    wl = Workload(op="scan", n=256, batch=256, variant="lf")
    assert build_space(wl).spec is TPU_V5E


def test_active_profile_env_retargets(monkeypatch):
    monkeypatch.setenv("REPRO_HW_PROFILE", "gpu_sm")
    assert active_profile() is GPU_SM
    wl = Workload(op="scan", n=256, batch=256, variant="lf")
    assert build_space(wl).spec is GPU_SM
    assert CostModelObjective().signature().startswith("cost:gpu_sm:")


def test_legacy_tpu_shim_is_retired():
    """repro.hw.tpu is gone: importing it fails with a pointer at
    repro.hw.profiles (the machine model as data)."""
    with pytest.raises(ImportError, match="repro.hw.profiles"):
        import repro.hw.tpu  # noqa: F401


# ---------------------------------------------------------------------------
# 2. Every profile x every registered op: valid space, finite costs
# ---------------------------------------------------------------------------

def _representative(op: str) -> Workload:
    spec = SUITE[op]
    n = spec["train"][len(spec["train"]) // 2]
    batch = int(spec.get("batch") or max(2 ** 20 // n, 1))
    return Workload(op=op, n=n, batch=batch, variant=spec["variants"][0])


@pytest.mark.parametrize("profile_name", profiles())
@pytest.mark.parametrize("op", known_ops())
def test_profile_yields_valid_space_and_finite_plans(profile_name, op):
    prof = get_profile(profile_name)
    wl = _representative(op)
    space = build_space(wl, prof)
    assert space.spec is prof
    cands = space.enumerate_valid()
    assert cands, f"{op} space empty under {profile_name}"

    obj = CostModelObjective(prof)
    sample = cands[:: max(len(cands) // 8, 1)]
    ts = obj.batch_eval(space, sample, assume_valid=True)
    assert np.all(np.isfinite(ts)) and np.all(np.asarray(ts) > 0)
    for cfg in sample[:4]:
        plan = plan_for(wl, cfg, profile=prof)
        res = plan.resources()
        for key, val in res.items():
            assert np.isfinite(val), (op, profile_name, key, val)
        assert plan.passes >= 1
        m = obj(space, cfg)
        assert m.valid and np.isfinite(m.time_s) and m.time_s > 0


def test_profiles_produce_distinct_costs():
    """The profile actually reaches the arithmetic: the same workload is
    costed differently on different machines."""
    wl = _representative("scan")
    times = {}
    for name in profiles():
        prof = get_profile(name)
        space = build_space(wl, prof)
        cfg = space.enumerate_valid()[0]
        times[name] = CostModelObjective(prof)(space, cfg).time_s
    assert len(set(times.values())) == len(times), times


def test_profile_distance_properties():
    assert profile_distance(TPU_V5E, TPU_V5E) == 0.0
    assert profile_distance(GPU_SM, GPU_SM) == 0.0
    d = profile_distance(TPU_V5E, GPU_SM)
    assert d > 0
    assert profile_distance(GPU_SM, TPU_V5E) == pytest.approx(d)
    # the CI host model is "farther" from the TPU than the server GPU is
    assert profile_distance(TPU_V5E, CPU_INTERPRET) > d


def test_register_profile_roundtrip():
    custom = HardwareProfile(name="test_dev", lane_count=16)
    register_profile(custom)
    try:
        assert get_profile("test_dev") is custom
        assert "test_dev" in profiles()
        wl = _representative("scan")
        assert build_space(wl, custom).enumerate_valid()
    finally:
        import sys
        sys.modules["repro.hw.profiles"]._PROFILES.pop("test_dev", None)


def test_unknown_profile_raises():
    with pytest.raises(ValueError, match="unknown hardware profile"):
        get_profile("nonexistent_device")


# ---------------------------------------------------------------------------
# 3. Cross-profile persistence isolation
# ---------------------------------------------------------------------------

def test_db_entries_never_resolve_across_profiles(tmp_path):
    path = str(tmp_path / "db.json")
    wl = Workload(op="scan", n=256, batch=256, variant="lf")
    gpu_db = TuningDB(path=path, platform="gpu_sm")
    gpu_db.store(wl, {"radix": 4}, 1e-3, "bayesian", 5)

    assert TuningDB(path=path, platform="gpu_sm").lookup(wl) is not None
    assert TuningDB(path=path, platform="tpu_v5e").lookup(wl) is None
    assert TuningDB(path=path, platform="cpu_interpret").lookup(wl) is None

    # both devices' winners coexist in one file (lookup returns the config)
    tpu_db = TuningDB(path=path, platform="tpu_v5e")
    tpu_db.store(wl, {"radix": 8}, 2e-3, "bayesian", 5)
    assert TuningDB(path=path, platform="gpu_sm").lookup(wl) == {"radix": 4}
    assert TuningDB(path=path, platform="tpu_v5e").lookup(wl) == {"radix": 8}


def test_db_schema2_migrates_to_tpu_v5e(tmp_path):
    path = str(tmp_path / "db.json")
    wl = Workload(op="scan", n=256, batch=256, variant="lf")
    legacy = {"schema": 2, "entries": {
        f"tpu_v5e|{wl.key}": {"config": {"radix": 4}, "time_s": 1e-3,
                              "method": "bayesian", "evaluations": 5}}}
    with open(path, "w") as f:
        json.dump(legacy, f)

    db = TuningDB(path=path, platform="tpu_v5e")
    assert db.lookup(wl) == {"radix": 4}
    assert all(e["profile"] == "tpu_v5e" for e in db.entries().values())
    assert TuningDB(path=path, platform="gpu_sm").lookup(wl) is None
    # the next store persists the migrated envelope
    db.store(wl, {"radix": 8}, 5e-4, "bayesian", 3)
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk["schema"] == SCHEMA_VERSION
    assert all("profile" in e for e in on_disk["entries"].values())


def test_db_bare_legacy_key_rekeys_under_tpu_v5e(tmp_path):
    """Pre-platform entries had no device prefix at all; they must re-key
    under tpu_v5e on load or ``lookup`` (which always prefixes the
    session platform) could never resolve them."""
    path = str(tmp_path / "db.json")
    wl = Workload(op="scan", n=256, batch=256, variant="lf")
    with open(path, "w") as f:
        json.dump({wl.key: {"config": {"radix": 2}, "time_s": 1e-3,
                            "method": "bayesian", "evaluations": 5}}, f)

    db = TuningDB(path=path, platform="tpu_v5e")
    assert db.lookup(wl) == {"radix": 2}
    entry = db.entries()[f"tpu_v5e|{wl.key}"]
    assert entry["profile"] == "tpu_v5e"
    assert TuningDB(path=path, platform="gpu_sm").lookup(wl) is None


def test_journal_rejects_cross_profile_resume(tmp_path):
    wl = Workload(op="scan", n=128, batch=512, variant="lf")
    tpu_obj = CostModelObjective(TPU_V5E)
    space = build_space(wl, TPU_V5E)
    journal = SweepJournal.for_workload(str(tmp_path), wl, tpu_obj)
    run_sweep(space, tpu_obj, journal=journal)

    header = journal.read_header()
    assert header["profile"] == "tpu_v5e"

    # same path, different device: the header check refuses to resume
    gpu_obj = CostModelObjective(GPU_SM)
    with pytest.raises(ValueError):
        SweepJournal(journal.path).load(wl, gpu_obj)

    # the natural flow never collides: signatures differ, so the gpu
    # sweep journals to a different file in the same directory
    gpu_space = build_space(wl, GPU_SM)
    gpu_journal = SweepJournal.for_workload(str(tmp_path), wl, gpu_obj)
    assert gpu_journal.path != journal.path
    res = run_sweep(gpu_space, gpu_obj, journal=gpu_journal)
    assert res.evaluations > 0 and gpu_journal.read_header()["profile"] \
        == "gpu_sm"


def test_session_is_profile_keyed(tmp_path):
    from repro.tuning.session import TunerSession

    path = str(tmp_path / "db.json")
    wl = Workload(op="scan", n=256, batch=256, variant="lf")
    gpu = TunerSession(db_path=path, platform="gpu_sm")
    assert gpu.spec is GPU_SM
    gpu.tune(wl, method="analytical")
    assert gpu.db.lookup(wl) is not None

    tpu = TunerSession(db_path=path, platform="tpu_v5e")
    assert tpu.db.lookup(wl) is None           # other device's winner
    # resolve still answers (analytical fallback on its own profile)
    assert tpu.resolve(wl)
