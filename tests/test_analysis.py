"""repro.analysis: AST lint rules, fingerprints, invariants, baseline.

Three layers (docs/analysis.md):

  * per-rule good/bad snippets through ``lint_source`` — every rule must
    both fire on its target pattern and stay silent on the sanctioned
    alternative;
  * version-drift fingerprints — a contract edit without a version bump
    is a finding, a bump without a fixture refresh is a different one;
  * semantic invariants — every plan invariant holds for every
    ``known_ops()`` op under every registered profile, and the dead-knob
    detector rediscovers the pruned attention ``unroll`` when a fixture
    space reintroduces it;

plus the self-clean gate: the shipped tree, checked against the shipped
(empty) baseline, produces zero findings.
"""
import dataclasses
import json
import os

import pytest

from repro.analysis import (
    apply_baseline,
    check_fingerprints,
    check_invariants,
    check_space,
    current_fingerprints,
    default_fixture_path,
    find_dead_knobs,
    lint_source,
    load_baseline,
    report_dict,
    run_lint,
    suite_grid,
    write_fingerprints,
)
from repro.analysis.findings import Finding
from repro.core.space import ParamSpec, SearchSpace, Workload, build_space
from repro.hw.profiles import TPU_V5E, profiles
from repro.tuning.registry import known_ops


def rules_of(findings):
    return sorted({f.rule for f in findings})


def lint_rule(relpath, source, rule):
    """Findings of one rule for one snippet."""
    return [f for f in lint_source(relpath, source, rules=[rule])
            if f.rule == f"ast.{rule}"]


# ---------------------------------------------------------------------------
# AST rules: each fires on the bad snippet, stays silent on the good one
# ---------------------------------------------------------------------------

class TestAstRules:
    def test_retired_shim_import(self):
        bad = "import repro.core.tuner\n"
        assert lint_rule("x.py", bad, "retired-shim-import")
        bad = "from repro.hw.tpu import TPU_SPEC\n"
        assert lint_rule("x.py", bad, "retired-shim-import")
        good = "from repro.core.space import build_space\n"
        assert not lint_rule("x.py", good, "retired-shim-import")

    def test_deprecated_alias(self):
        bad = "from repro.core import TPUCostModelObjective\n"
        assert lint_rule("tuning/x.py", bad, "deprecated-alias")
        bad = "obj = objective.TPUCostModelObjective()\n"
        assert lint_rule("tuning/x.py", bad, "deprecated-alias")
        # the definition site and the compat re-export stay importable
        assert not lint_rule("core/objective.py", bad, "deprecated-alias")
        good = "from repro.core import CostModelObjective\n"
        assert not lint_rule("tuning/x.py", good, "deprecated-alias")

    def test_deprecated_spec_kwarg(self):
        bad = "space = build_space(wl, spec=profile)\n"
        assert lint_rule("x.py", bad, "deprecated-spec-kwarg")
        good = "space = build_space(wl, profile=profile)\n"
        assert not lint_rule("x.py", good, "deprecated-spec-kwarg")
        # functions whose canonical parameter IS `spec` are not targeted
        good = "t = micro_step_overhead_s(spec=profile)\n"
        assert not lint_rule("x.py", good, "deprecated-spec-kwarg")

    def test_raw_clock_scoped_to_measurement_paths(self):
        bad = "import time\nt0 = time.time()\n"
        assert lint_rule("tuning/x.py", bad, "raw-clock")
        assert lint_rule("serve/engine.py", bad, "raw-clock")
        assert lint_rule("launch/serve.py", bad, "raw-clock")
        # non-measurement paths may use wall clocks (e.g. launch/dryrun.py)
        assert not lint_rule("launch/dryrun.py", bad, "raw-clock")
        bad = "from time import perf_counter\ndt = perf_counter() - t0\n"
        assert lint_rule("tuning/x.py", bad, "raw-clock")
        # references without a call (e.g. storing the injectable default)
        good = "import time\nclock = time.monotonic\n"
        assert not lint_rule("tuning/x.py", good, "raw-clock")

    def test_objective_batch_eval(self):
        bad = ("class FancyObjective(Objective):\n"
               "    def batch_eval(self, space, cfgs):\n"
               "        return []\n")
        assert lint_rule("x.py", bad, "objective-batch-eval")
        good = ("class FancyObjective(Objective):\n"
                "    def batch_eval_metrics(self, space, cfgs):\n"
                "        return []\n"
                "    def batch_eval(self, space, cfgs):\n"
                "        return []\n")
        assert not lint_rule("x.py", good, "objective-batch-eval")
        # unrelated base classes are not objectives
        other = ("class Helper(Base):\n"
                 "    def batch_eval(self):\n"
                 "        return []\n")
        assert not lint_rule("x.py", other, "objective-batch-eval")

    def test_mutable_default(self):
        assert lint_rule("x.py", "def f(x=[]):\n    pass\n",
                         "mutable-default")
        assert lint_rule("x.py", "def f(x={}):\n    pass\n",
                         "mutable-default")
        assert lint_rule("x.py", "def f(*, x=dict()):\n    pass\n",
                         "mutable-default")
        assert not lint_rule("x.py", "def f(x=None):\n    pass\n",
                             "mutable-default")
        assert not lint_rule("x.py", "def f(x=()):\n    pass\n",
                             "mutable-default")

    def test_journal_open_append(self):
        assert lint_rule("x.py", "f = open(p, 'a')\n",
                         "journal-open-append")
        assert lint_rule("x.py", "f = open(p, mode='ab')\n",
                         "journal-open-append")
        assert not lint_rule("x.py", "f = open(p)\n", "journal-open-append")
        assert not lint_rule("x.py", "f = open(p, 'w')\n",
                             "journal-open-append")
        # the O_APPEND helper itself goes through os.open
        assert not lint_rule("x.py", "fd = os.open(p, flags)\n",
                             "journal-open-append")

    def test_allow_comment_suppresses_one_line(self):
        src = "t0 = time.time()  # lint: allow[raw-clock]\nt1 = time.time()\n"
        hits = lint_rule("tuning/x.py", src, "raw-clock")
        assert [f.line for f in hits] == [2]

    def test_syntax_error_is_a_finding(self):
        hits = lint_source("x.py", "def f(:\n")
        assert rules_of(hits) == ["ast.syntax-error"]


# ---------------------------------------------------------------------------
# Findings / baseline mechanics
# ---------------------------------------------------------------------------

class TestBaseline:
    def test_key_is_line_independent(self):
        a = Finding(rule="r", path="p", message="m", line=3)
        b = Finding(rule="r", path="p", message="m", line=99)
        assert a.key() == b.key()
        assert a.key() != dataclasses.replace(a, message="other").key()

    def test_apply_baseline_splits(self):
        a = Finding(rule="r", path="p", message="m")
        b = Finding(rule="r", path="p", message="other")
        fresh, quiet = apply_baseline([a, b], [a.key()])
        assert fresh == [b] and quiet == [a]

    def test_report_dict_counts(self):
        a = Finding(rule="r1", path="p", message="m")
        b = Finding(rule="r1", path="p", message="o")
        rep = report_dict([a, b], suppressed=[])
        assert rep["total"] == 2 and rep["counts"] == {"r1": 2}
        assert all("key" in f for f in rep["findings"])

    def test_load_baseline(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"version": 1, "suppress": ["k"]}))
        assert load_baseline(str(p)) == ["k"]
        assert load_baseline(str(tmp_path / "absent.json")) == []
        p.write_text(json.dumps({"oops": []}))
        with pytest.raises(ValueError):
            load_baseline(str(p))

    def test_shipped_baseline_is_empty(self):
        path = os.path.join(os.path.dirname(default_fixture_path()),
                            "analysis_baseline.json")
        assert load_baseline(path) == []


# ---------------------------------------------------------------------------
# Version-drift fingerprints
# ---------------------------------------------------------------------------

class TestFingerprints:
    def test_shipped_fixture_matches_live_tree(self):
        assert check_fingerprints(default_fixture_path()) == []

    def test_missing_fixture(self, tmp_path):
        hits = check_fingerprints(str(tmp_path / "absent.json"))
        assert rules_of(hits) == ["fingerprint.missing-fixture"]

    def test_content_change_without_version_bump(self, monkeypatch):
        import repro.tuning.ml.features as feats
        monkeypatch.setattr(feats, "FEATURE_NAMES",
                            tuple(feats.FEATURE_NAMES) + ("sneaky_col",))
        hits = check_fingerprints(default_fixture_path())
        assert rules_of(hits) == ["fingerprint.feature_columns"]
        assert "bump the matching *_VERSION" in hits[0].message

    def test_version_bump_with_stale_fixture(self, monkeypatch):
        import repro.tuning.ml.features as feats
        monkeypatch.setattr(feats, "FEATURE_NAMES",
                            tuple(feats.FEATURE_NAMES) + ("sneaky_col",))
        monkeypatch.setattr(feats, "FEATURE_VERSION",
                            feats.FEATURE_VERSION + 1)
        hits = check_fingerprints(default_fixture_path())
        assert rules_of(hits) == ["fingerprint.feature_columns"]
        assert "stale" in hits[0].message

    def test_write_then_check_roundtrip(self, tmp_path):
        p = str(tmp_path / "fp.json")
        pins = write_fingerprints(p)
        assert pins == current_fingerprints()
        assert check_fingerprints(p) == []

    def test_unknown_pinned_contract(self, tmp_path):
        p = str(tmp_path / "fp.json")
        pins = write_fingerprints(p)
        pins["phlogiston"] = {"version": 1, "hash": "0" * 64}
        with open(p, "w") as f:
            json.dump(pins, f)
        hits = check_fingerprints(p)
        assert rules_of(hits) == ["fingerprint.phlogiston"]


# ---------------------------------------------------------------------------
# Semantic invariants
# ---------------------------------------------------------------------------

class TestInvariants:
    def test_all_ops_all_profiles_clean(self):
        # the acceptance sweep: every plan invariant, model agreement,
        # and feasibility check for every op x profile x suite workload
        assert check_invariants() == []

    def test_suite_grid_covers_every_op(self):
        for op in known_ops():
            grid = suite_grid(op)
            assert grid, op
            assert all(wl.op == op for wl in grid)

    def test_profiles_registry_has_three(self):
        assert {"tpu_v5e", "gpu_sm", "cpu_interpret"} <= set(profiles())

    def test_empty_space_is_a_finding(self):
        wl = Workload(op="attention", n=2048, batch=64, dtype="bfloat16",
                      variant="flash")
        base = build_space(wl, TPU_V5E)
        empty = SearchSpace(wl, base.params,
                            constraints=(lambda c, w: False,), spec=TPU_V5E)
        hits = check_space(empty)
        assert rules_of(hits) == ["invariant.empty-space"]

    def test_dead_knob_detector_finds_reintroduced_unroll(self):
        # PR 5 pruned `unroll` from the linrec space and this PR pruned it
        # from attention; the detector must rediscover that class of bug
        # when a fixture space sneaks the knob back in
        spaces = []
        for wl in suite_grid("attention"):
            base = build_space(wl, TPU_V5E)
            spaces.append(SearchSpace(
                base.workload,
                list(base.params) + [ParamSpec("unroll", (1, 2))],
                constraints=base.constraints, spec=base.spec))
        dead = find_dead_knobs(spaces)
        assert "unroll" in dead
        # the live block knobs must NOT be reported dead
        assert "block_q" not in dead and "block_k" not in dead

    def test_shipped_spaces_have_no_dead_knobs(self):
        # subsumed by test_all_ops_all_profiles_clean but pinned
        # explicitly: the per-op aggregate liveness sweep is the contract
        for op in ("attention", "scan"):
            spaces = [build_space(wl, TPU_V5E) for wl in suite_grid(op)]
            assert find_dead_knobs(spaces) == []


# ---------------------------------------------------------------------------
# Self-clean gate + CLI
# ---------------------------------------------------------------------------

class TestSelfClean:
    def test_full_lint_is_clean(self):
        # AST lint + fingerprints + full invariant sweep over the shipped
        # tree: zero findings, matching the empty shipped baseline
        assert run_lint() == []

    def test_cli_lint_json_report(self, tmp_path, capsys):
        from repro.launch.tune import main
        report = tmp_path / "report.json"
        rc = main(["lint", "--json", str(report), "--no-invariants"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "0 finding(s)" in out
        rep = json.loads(report.read_text())
        assert rep["total"] == 0 and rep["version"] == 1

    def test_cli_lint_fails_on_finding(self, tmp_path, capsys):
        # point the AST lint at a tree with a violation: non-zero exit,
        # finding in the report
        from repro.launch.tune import main
        pkg = tmp_path / "pkg"
        pkg.mkdir()
        (pkg / "bad.py").write_text("import repro.core.tuner\n")
        report = tmp_path / "report.json"
        rc = main(["lint", "--root", str(pkg), "--json", str(report),
                   "--no-invariants"])
        assert rc == 1
        rep = json.loads(report.read_text())
        assert rep["counts"].get("ast.retired-shim-import") == 1

    def test_cli_baseline_suppresses(self, tmp_path, capsys):
        from repro.launch.tune import main
        pkg = tmp_path / "pkg"
        pkg.mkdir()
        (pkg / "bad.py").write_text("import repro.core.tuner\n")
        hits = [f for f in run_lint(pkg_root=str(pkg), invariants=False)
                if f.rule.startswith("ast.")]
        baseline = tmp_path / "baseline.json"
        baseline.write_text(json.dumps(
            {"version": 1, "suppress": [f.key() for f in hits]}))
        rc = main(["lint", "--root", str(pkg), "--baseline", str(baseline),
                   "--no-invariants"])
        assert rc == 0
        assert "1 baselined" in capsys.readouterr().out
