"""Oracle for the tiled matmul kernel."""
import jax.numpy as jnp


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=a.dtype)
