"""Static-analysis wall-clock gate: the full lint must stay cheap.

``tune.py lint`` only stays on every push while it stays fast, so this
bench times each stage of the zero-execution pass (docs/analysis.md) —
the AST rule sweep over ``src/repro``, the contract fingerprints, and
the complete op x profile invariant sweep (plan soundness, model
agreement, feasibility, dead knobs over the whole suite grid) — and
**gates the total under 10 s**. The stage split makes regressions
attributable: a new lint rule shows up in the ast row, a space-growth
blowup in the invariants row.

The run must also come back *clean* (gate): a finding here means the
tree no longer lints — CI's lint-analysis job would fail anyway, but the
bench failing too keeps bench-smoke honest about what it timed (an
early-erroring pass times nothing).

Standalone (the CI bench-smoke invocation):

  PYTHONPATH=src:. python benchmarks/bench_analysis.py \
      --json BENCH_analysis.json

exits non-zero when a gate fails; ``run.py --only analysis`` emits the
same rows as a section.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Optional

BUDGET_S = 10.0


def run(emit, seed: int = 0, smoke: bool = False) -> List[str]:
    """Emit analysis timing rows; returns gate-failure strings."""
    from repro.analysis import (check_fingerprints, check_invariants,
                                default_fixture_path, lint_tree)

    t0 = time.perf_counter()
    ast_findings = lint_tree()
    t_ast = time.perf_counter() - t0

    t0 = time.perf_counter()
    fp_findings = check_fingerprints(default_fixture_path())
    t_fp = time.perf_counter() - t0

    t0 = time.perf_counter()
    inv_findings = check_invariants()
    t_inv = time.perf_counter() - t0

    total = t_ast + t_fp + t_inv
    emit(f"analysis,ALL,,,ast_lint,seconds,{t_ast:.3f},"
         f"findings={len(ast_findings)}")
    emit(f"analysis,ALL,,,fingerprints,seconds,{t_fp:.3f},"
         f"findings={len(fp_findings)}")
    emit(f"analysis,ALL,,,invariants,seconds,{t_inv:.3f},"
         f"findings={len(inv_findings)}")
    emit(f"analysis,ALL,,,full_lint,seconds,{total:.3f},"
         f"gate<{BUDGET_S:g}s")

    failures: List[str] = []
    if total >= BUDGET_S:
        failures.append(
            f"full static-analysis pass took {total:.2f}s "
            f"(budget {BUDGET_S:g}s) — too slow to gate every push; find "
            f"the regressed stage in the per-stage rows")
    n_findings = len(ast_findings) + len(fp_findings) + len(inv_findings)
    if n_findings:
        failures.append(
            f"{n_findings} finding(s) on the shipped tree — run "
            f"`python -m repro.launch.tune lint` for the list")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Full static-analysis pass wall-clock gate")
    ap.add_argument("--json", default=None,
                    help="write the rows + gate verdict here "
                         "(e.g. BENCH_analysis.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for harness uniformity (the full pass "
                         "is already the smoke-sized workload)")
    args = ap.parse_args(argv)

    rows: List[str] = []

    def emit(row: str) -> None:
        rows.append(row)
        print(row, flush=True)

    failures = run(emit, seed=args.seed, smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "analysis", "seed": args.seed,
                       "smoke": bool(args.smoke), "budget_s": BUDGET_S,
                       "rows": rows, "failures": failures},
                      f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    for failure in failures:
        print(f"[bench-analysis] FAIL: {failure}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
