"""recurrentgemma-9b: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention (window 2048), pattern
(rec, rec, attn) [arXiv:2402.19427]. Sub-quadratic: runs long_500k."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch="recurrentgemma-9b", family="hybrid",
    n_layers=36,  # 38 rounded to the (rec,rec,attn) period per block pattern;
    # the two extra layers of the published config do not fit the strict 1:2
    # pattern — recorded in DESIGN.md (scan-over-groups requires uniformity)
    d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000, activation="geglu",
    activation_strategy="sp",
    block_pattern=("rec", "rec", "attn"), attn_window=2048, lru_width=4096,
    sub_quadratic=True,
))
