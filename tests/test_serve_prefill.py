"""Prefill/replay differential: the optimized engine vs the reference.

The single-dispatch batched prefill and the donated decode loop must be
*bit-identical* to the seed's per-token replay path, per lane:

* cache differential — after admitting one prompt, the target slot's
  cache lanes must match the :class:`ReferenceEngine`'s replay bitwise,
  across model families (dense KV / SSM state / hybrid ring-buffer) and
  cache dtypes, including odd prompt lengths and chunk-boundary prompts;
* output differential — full greedy runs must decode bit-identical
  tokens (and finish reasons).  Configurations are chosen so the
  *reference* is well-defined: its padding steps advance recurrent/SSM
  state on every lane (the seed pollution the optimized engine's lane
  masking removes), so state-carrying archs compare single-request runs
  and the dense arch compares a no-lane-reuse batch.  MoE archs are
  excluded outright: expert capacity couples lanes inside a batch, so
  per-lane bit-identity is not even defined for them.

Also here: the dispatch-count contract (prefill issues ceil(need/chunk)
device calls, not one per token) and the steady-state host-transfer
contract (at most one small transfer per decode step, batched every
``harvest_every`` steps), asserted via a transfer-counting test double.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.model import build_model
from repro.serve import ReferenceEngine, ServeEngine

KEY = jax.random.PRNGKey(0)

# (arch, cache dtype) axes for the cache differential; one state-space,
# one hybrid/ring-buffer, and the dense arch in both cache dtypes
CACHE_CASES = [
    ("qwen1.5-0.5b", jnp.float32),
    ("qwen1.5-0.5b", jnp.bfloat16),
    ("mamba2-130m", jnp.float32),
    ("recurrentgemma-9b", jnp.float32),
]

_BUILT = {}


def built(arch: str):
    """Module-level (cfg, model, params) cache — params are expensive."""
    if arch not in _BUILT:
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        _BUILT[arch] = (cfg, model, model.init(KEY))
    return _BUILT[arch]


def assert_lane_bitwise_equal(cache_a, cache_b, lane: int) -> None:
    la = jax.tree.leaves(cache_a)
    lb = jax.tree.leaves(cache_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        # batch is axis 1 of every cache leaf
        np.testing.assert_array_equal(np.asarray(a[:, lane]),
                                      np.asarray(b[:, lane]))


# ---------------------------------------------------------------------------
# Cache differential: batched prefill == per-token replay, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,dtype", CACHE_CASES,
                         ids=[f"{a}-{jnp.dtype(d).name}"
                              for a, d in CACHE_CASES])
@pytest.mark.parametrize("plen", [1, 2, 7, 9, 12])
def test_prefill_cache_bit_identical_to_replay(arch, dtype, plen):
    """plen axis: 1 (no prefill at all), 2 (single write), 7 (odd,
    mid-chunk tail), 9 (exactly chunk+1: a full chunk of writes), 12
    (chunk boundary + tail) — all with prefill_chunk=8."""
    cfg, model, params = built(arch)
    rng = np.random.default_rng(plen)
    prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)

    eng = ServeEngine(model, params, max_batch=3, max_len=32,
                      prefill_chunk=8, cache_dtype=dtype)
    eng.submit(prompt, max_new_tokens=4)
    eng._admit()                       # prefill only, no decode steps
    ref = ReferenceEngine(model, params, max_batch=3, max_len=32,
                          cache_dtype=dtype)
    ref.submit(prompt, max_new_tokens=4)
    ref._admit()

    assert eng.slot_pos[0] == ref.slot_pos[0] == plen - 1
    assert_lane_bitwise_equal(eng.cache, ref.cache, lane=0)
    # the dispatch contract: ceil((plen-1)/chunk) device calls, not plen-1
    assert eng.prefill_calls == math.ceil((plen - 1) / 8)


def test_prefill_chunk_one_matches_replay():
    """chunk=1 degenerates to one dispatch per token — same bits."""
    cfg, model, params = built("qwen1.5-0.5b")
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    eng = ServeEngine(model, params, max_batch=2, max_len=32,
                      prefill_chunk=1)
    eng.submit(prompt)
    eng._admit()
    ref = ReferenceEngine(model, params, max_batch=2, max_len=32)
    ref.submit(prompt)
    ref._admit()
    assert eng.prefill_calls == len(prompt) - 1
    assert_lane_bitwise_equal(eng.cache, ref.cache, lane=0)


def test_batched_prefill_group_shares_dispatches():
    """Co-admitted prompts share the scan: the whole group costs
    ceil(max(plen-1)/chunk) dispatches, and every lane still matches the
    reference's per-prompt replay bitwise."""
    cfg, model, params = built("qwen1.5-0.5b")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (13, 7, 2)]

    eng = ServeEngine(model, params, max_batch=3, max_len=32,
                      prefill_chunk=4)
    for p in prompts:
        eng.submit(p)
    eng._admit()
    assert eng.prefill_calls == math.ceil((13 - 1) / 4)   # 3, not 12+6+1

    for lane, p in enumerate(prompts):
        # reference: each prompt admitted alone into a fresh engine, so
        # its replay lane is unpolluted by the other admissions
        ref = ReferenceEngine(model, params, max_batch=3, max_len=32)
        ref.submit(p)
        ref._admit()
        la = jax.tree.leaves(eng.cache)
        lb = jax.tree.leaves(ref.cache)
        for a, b in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(a[:, lane]),
                                          np.asarray(b[:, 0]))


# ---------------------------------------------------------------------------
# Output differential: decoded tokens bit-identical end to end
# ---------------------------------------------------------------------------

def test_dense_multi_request_outputs_match_reference():
    """Dense arch, no lane reuse (requests <= slots): the full continuous
    batching run must emit the reference's tokens exactly."""
    cfg, model, params = built("qwen1.5-0.5b")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (6, 1, 11, 3)]
    eng = ServeEngine(model, params, max_batch=4, max_len=64,
                      prefill_chunk=4)
    ref = ReferenceEngine(model, params, max_batch=4, max_len=64)
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
        ref.submit(p, max_new_tokens=5)
    de, dr = eng.run(), ref.run()
    assert [r.output for r in de] == [r.output for r in dr]
    assert [r.finish_reason for r in de] == [r.finish_reason for r in dr]


@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-9b"])
def test_state_arch_outputs_match_reference(arch):
    """State-carrying archs: single-request runs (the reference's padding
    steps would advance other lanes' state — the seed pollution bug the
    optimized engine fixes — so multi-lane comparisons are undefined)."""
    cfg, model, params = built(arch)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=9).astype(np.int32)
    eng = ServeEngine(model, params, max_batch=1, max_len=32,
                      prefill_chunk=4)
    ref = ReferenceEngine(model, params, max_batch=1, max_len=32)
    eng.submit(prompt, max_new_tokens=6)
    ref.submit(prompt, max_new_tokens=6)
    de, dr = eng.run(), ref.run()
    assert de[0].output == dr[0].output
    assert de[0].finish_reason == dr[0].finish_reason


def test_lane_reuse_does_not_leak_state():
    """Two tenants through the same slot, one after the other: the second
    must decode exactly as if it had the engine to itself (the lane is
    reset on admission; padding steps are lane-masked)."""
    cfg, model, params = built("mamba2-130m")
    rng = np.random.default_rng(4)
    first = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    second = rng.integers(0, cfg.vocab, size=7).astype(np.int32)

    eng = ServeEngine(model, params, max_batch=1, max_len=32,
                      prefill_chunk=4)
    eng.submit(first, max_new_tokens=4)
    eng.submit(second, max_new_tokens=4)
    reused = {r.rid: r for r in eng.run()}

    solo = ServeEngine(model, params, max_batch=1, max_len=32,
                       prefill_chunk=4)
    solo.submit(second, max_new_tokens=4)
    assert reused[1].output == solo.run()[0].output


# ---------------------------------------------------------------------------
# Host-transfer contract (transfer-counting test double)
# ---------------------------------------------------------------------------

def test_steady_state_decode_single_transfer_per_step():
    cfg, model, params = built("qwen1.5-0.5b")
    eng = ServeEngine(model, params, max_batch=4, max_len=64,
                      harvest_every=4)
    fetched = []
    real_fetch = eng._fetch

    def counting_fetch(x):
        arr = real_fetch(x)
        fetched.append(arr.shape)
        return arr

    eng._fetch = counting_fetch
    rng = np.random.default_rng(5)
    for _ in range(4):
        eng.submit(rng.integers(0, cfg.vocab, size=6).astype(np.int32),
                   max_new_tokens=16)
    done = eng.run()
    assert len(done) == 4
    steps = eng._step_index
    # hard bound: at most ONE host transfer per decode step...
    assert len(fetched) <= steps
    # ...and with no listeners, the harvest batches k steps per transfer
    assert len(fetched) <= steps // eng.harvest_every + 2
    # each transfer is the small (k, B, 2) token/finish-code block, never
    # logits or cache-sized payloads
    assert all(s[-1] == 2 and s[-2] == 4 for s in fetched)
    assert eng.host_transfers == len(fetched)


def test_timed_engine_harvests_every_step():
    """With a step listener the harvest is forced inside the timed window
    (the tuner's samples must cover real device work) — still exactly one
    transfer per step."""
    cfg, model, params = built("qwen1.5-0.5b")
    ticks = iter(range(10_000))
    eng = ServeEngine(model, params, max_batch=2, max_len=64,
                      step_timer=lambda: float(next(ticks)))
    records = []
    eng.add_step_listener(records.append)
    eng.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=5)
    eng.run()
    assert records
    assert eng.host_transfers <= eng._step_index


# ---------------------------------------------------------------------------
# Prefill budget: long prompts cannot starve active decoders
# ---------------------------------------------------------------------------

def test_prefill_budget_lets_decoders_progress():
    cfg, model, params = built("qwen1.5-0.5b")
    eng = ServeEngine(model, params, max_batch=2, max_len=128,
                      prefill_chunk=8, max_prefill_tokens=8,
                      harvest_every=1)
    short = np.asarray([5, 3], np.int32)
    long = np.arange(1, 70, dtype=np.int32) % cfg.vocab
    eng.submit(short, max_new_tokens=40)
    eng.run(max_steps=2)               # short is admitted and decoding
    eng.submit(long, max_new_tokens=2)
    eng.run(max_steps=4)
    # the long prompt (68 writes at <= 8/step) is still mid-prefill...
    assert eng._prefilling
    # ...while the short request kept emitting a token every step
    short_req = next(r for r in eng.slot_req if r is not None
                     and len(r.prompt) == 2)
    assert len(short_req.output) >= 5
    done = eng.run(max_steps=10_000)
    assert len(done) == 2

    # and the budgeted, interleaved prefill decoded the same tokens as an
    # unconstrained engine given the same prompt
    solo = ServeEngine(model, params, max_batch=2, max_len=128,
                       prefill_chunk=8)
    solo.submit(long, max_new_tokens=2)
    assert done[1].output == solo.run()[0].output
